//! End-to-end serving harness: train → tables → backend service →
//! coordinator, in one call. Shared by the launcher (`main.rs`), the
//! examples and the Table 3 / serving benches so every consumer measures
//! the exact same stack.

use crate::automl::{self, PipelineConfig};
use crate::config::ServeConfig;
use crate::coordinator::Coordinator;
use crate::datagen;
use crate::lrwbins::ServingTables;
use crate::rpc::netsim::{NetSim, NetSimConfig};
use crate::rpc::server::{Backend, BatcherConfig, NativeBackend, RpcServer};
use crate::rpc::RpcClient;
use crate::tabular::{split, Dataset};
use crate::telemetry::ServeMetrics;
#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Duration;

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct StackConfig {
    /// Dataset preset name (`datagen::PRESET_NAMES`).
    pub dataset: String,
    /// Row cap (0 = preset size).
    pub rows: usize,
    pub seed: u64,
    /// AutoML pipeline (quick() for tests/CI).
    pub pipeline: PipelineConfig,
    /// "pjrt" or "native".
    pub backend: String,
    pub netsim: NetSimConfig,
    pub batcher: BatcherConfig,
    /// Artifacts dir (for pjrt backend).
    pub artifacts_dir: std::path::PathBuf,
    /// Forced stage-1 kernel tier (`None` = runtime auto-detection; see
    /// `ServeConfig::stage1_simd`).
    pub stage1_dispatch: Option<crate::lrwbins::Stage1Dispatch>,
}

impl Default for StackConfig {
    fn default() -> Self {
        StackConfig {
            dataset: "aci".into(),
            rows: 0,
            seed: 1,
            pipeline: PipelineConfig::default(),
            backend: "pjrt".into(),
            netsim: NetSimConfig::default(),
            batcher: BatcherConfig::default(),
            artifacts_dir: default_artifacts_dir(),
            stage1_dispatch: None,
        }
    }
}

impl StackConfig {
    pub fn quick(dataset: &str, rows: usize) -> StackConfig {
        StackConfig {
            dataset: dataset.into(),
            rows,
            pipeline: PipelineConfig::quick(),
            ..Default::default()
        }
    }

    pub fn from_serve_config(sc: &ServeConfig) -> StackConfig {
        StackConfig {
            backend: sc.backend.clone(),
            netsim: NetSimConfig {
                base_us: sc.netsim_base_us,
                sigma: sc.netsim_sigma,
                max_us: sc.netsim_base_us * 20.0,
            },
            batcher: BatcherConfig {
                max_batch: sc.max_batch,
                max_wait: Duration::from_micros(sc.max_wait_us),
                workers: sc.workers,
                reactor: sc.reactor,
                reactor_loops: sc.reactor_loops,
                write_queue_frames: sc.write_queue_frames,
                admission: sc.admission_config(),
                sojourn_slo: Duration::from_micros(sc.sojourn_slo_us),
                ..Default::default()
            },
            artifacts_dir: sc.artifacts_dir.clone(),
            // `ServeConfig::validate` already rejects bad strings on the
            // load path; a hand-built config that skipped validation
            // degrades to auto-detection, loudly.
            stage1_dispatch: match sc.stage1_dispatch() {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("serve config: {e}; using auto stage-1 dispatch");
                    None
                }
            },
            ..Default::default()
        }
    }
}

/// Locate `artifacts/` relative to the crate root (works from benches,
/// examples and tests).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A fully-wired serving stack.
pub struct Stack {
    pub coordinator: Coordinator,
    /// Keep-alive for the backend service.
    pub server: RpcServer,
    pub metrics: Arc<ServeMetrics>,
    /// Held-out test data (never seen at training time).
    pub test: Dataset,
    /// Training artifacts for inspection.
    pub pipeline: automl::Pipeline,
    /// True if the PJRT backend is live (vs native fallback).
    pub pjrt: bool,
}

/// Build the full stack: data → AutoML pipeline → serving tables → backend
/// service (PJRT or native) → coordinator.
pub fn build(cfg: &StackConfig) -> Result<Stack> {
    let Some(mut spec) = datagen::preset(&cfg.dataset) else {
        bail!(
            "unknown dataset '{}'; presets: {}",
            cfg.dataset,
            datagen::PRESET_NAMES.join(", ")
        );
    };
    if cfg.rows > 0 {
        spec = spec.with_rows(cfg.rows);
    }
    let data = datagen::generate(&spec, cfg.seed);
    let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xABCD);
    let s = split::three_way_split(&data, (0.6, 0.2, 0.2), &mut rng);

    let pipeline = automl::run_pipeline(&s.train, &s.val, &cfg.pipeline);
    let mut tables = ServingTables::from_model(&pipeline.first);
    if let Some(d) = cfg.stage1_dispatch {
        let applied = tables.set_dispatch(d);
        if applied != d {
            // A forced tier this machine cannot run must not pass silently:
            // A/B numbers attributed to `d` would really be `applied`'s.
            eprintln!(
                "stage1_simd: requested {} unavailable on this machine; serving on {}",
                d.name(),
                applied.name()
            );
        }
    }

    let metrics = Arc::new(ServeMetrics::new());
    let netsim = Arc::new(NetSim::new(cfg.netsim.clone(), cfg.seed ^ 0x7777));

    let (backend, rpc_row_len, pjrt): (Arc<dyn Backend>, usize, bool) = match cfg.backend.as_str() {
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            use crate::rpc::server::PjrtBackend;
            use crate::runtime::{EngineWorker, ForestParams, Graph};
            let shapes = manifest_shapes(&cfg.artifacts_dir)?;
            let ft = pipeline.second.to_forest_tensors_at(shapes.depth);
            let worker = EngineWorker::spawn(
                &cfg.artifacts_dir,
                vec![Graph::SecondStage],
                Some(
                    ForestParams::from_tensors(&ft, &shapes)
                        .context("padding forest to artifact shapes")?,
                ),
                None,
            )
            .context("spawning PJRT engine worker — run `make artifacts`")?;
            let f_max = worker.f_max;
            (
                Arc::new(PjrtBackend::new(Arc::new(worker))),
                f_max,
                true,
            )
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!("this build has no PJRT runtime (rebuild with --features pjrt)"),
        "native" => (
            Arc::new(NativeBackend::new(pipeline.second.clone())),
            data.n_features(),
            false,
        ),
        other => bail!("backend must be pjrt|native, got '{other}'"),
    };

    let server = RpcServer::start(
        "127.0.0.1:0",
        backend,
        netsim,
        cfg.batcher.clone(),
        metrics.clone(),
    )?;
    let client = RpcClient::connect(server.addr)?;
    let coordinator = Coordinator::new(tables, Some(client), rpc_row_len, metrics.clone());

    Ok(Stack {
        coordinator,
        server,
        metrics,
        test: s.test,
        pipeline,
        pjrt,
    })
}

/// Outcome of one scripted guarded rollout (`lrwbins rollout`).
pub struct RolloutRun {
    /// True if the candidate walked Shadow → Canary → Promoted (and was
    /// finalized as the incumbent); false means a guard rolled it back.
    pub promoted: bool,
    /// The typed rollback reason, when a guard tripped.
    pub reason: Option<crate::coordinator::RollbackReason>,
    /// Pool-side version now serving (promoted runs only; 0 otherwise).
    pub version: u32,
    /// The retired rollout, for stats inspection
    /// ([`RolloutStats`](crate::telemetry::RolloutStats)).
    pub rollout: Arc<crate::coordinator::Rollout>,
}

/// Build an EMBEDDED stack (shared shard pool, no RPC hop) and walk one
/// candidate through the guarded rollout state machine under live test
/// traffic — Shadow → Canary → Promoted, or automatic rollback. The
/// candidate is the incumbent forest with every leaf shifted by
/// `leaf_shift` (`0.0` = a bit-identical candidate, the good-rollout
/// drill; a large shift trips the score-delta guard). `requests` bounds
/// the traffic driven; the rollout is ticked (unescalated) every 64
/// requests, standing in for the SLO controller's cadence.
pub fn run_rollout(
    cfg: &StackConfig,
    rcfg: crate::coordinator::RolloutConfig,
    leaf_shift: f32,
    requests: usize,
) -> Result<RolloutRun> {
    use crate::coordinator::RolloutPhase;
    let Some(mut spec) = datagen::preset(&cfg.dataset) else {
        bail!(
            "unknown dataset '{}'; presets: {}",
            cfg.dataset,
            datagen::PRESET_NAMES.join(", ")
        );
    };
    if cfg.rows > 0 {
        spec = spec.with_rows(cfg.rows);
    }
    let data = datagen::generate(&spec, cfg.seed);
    let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xABCD);
    let s = split::three_way_split(&data, (0.6, 0.2, 0.2), &mut rng);
    let pipeline = automl::run_pipeline(&s.train, &s.val, &cfg.pipeline);
    let tables = ServingTables::from_model(&pipeline.first);
    let incumbent = pipeline.second.flatten();

    let pool = Arc::new(crate::runtime::ShardPool::new(2));
    let model = pool.register(incumbent.clone());
    let mut coord =
        Coordinator::new_embedded(tables, pool, model, Arc::new(ServeMetrics::new()));

    let mut cand = incumbent;
    if leaf_shift != 0.0 {
        for (i, v) in cand.value.iter_mut().enumerate() {
            if cand.feat[i] == crate::gbdt::LEAF {
                *v += leaf_shift;
            }
        }
    }
    let snap =
        crate::snapshot::Snapshot::parse(&crate::snapshot::Snapshot::write(&coord.tables, &cand))
            .map_err(|e| anyhow::anyhow!("candidate snapshot: {e}"))?;
    let ro = coord
        .begin_rollout(&snap, rcfg)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Serve held-out traffic in small batches until the rollout reaches a
    // terminal phase or the request budget is spent.
    let batch = 16usize;
    let mut served = 0usize;
    let mut r = 0usize;
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(batch);
    while served < requests {
        rows.clear();
        for _ in 0..batch {
            rows.push(s.test.row(r % s.test.n_rows()));
            r += 1;
        }
        coord
            .predict_batch(&rows)
            .map_err(|e| anyhow::anyhow!("serving during rollout: {e}"))?;
        served += batch;
        if served % 64 == 0 {
            coord.rollout_tick(false);
        }
        if matches!(ro.phase(), RolloutPhase::Promoted | RolloutPhase::RolledBack) {
            break;
        }
    }

    let (promoted, version) = if ro.phase() == RolloutPhase::Promoted {
        (
            true,
            coord
                .finalize_rollout()
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        )
    } else {
        coord.end_rollout();
        (false, 0)
    };
    Ok(RolloutRun {
        promoted,
        reason: ro.rollback_reason(),
        version,
        rollout: ro,
    })
}

/// Dump a built stack's trained models (stage-1 tables + flattened
/// second-stage forest) as one binary snapshot — the artifact that
/// `lrwbins predict --snapshot`, `ServeConfig::snapshot_path` and
/// [`Coordinator::reload`] consume.
pub fn dump_snapshot(stack: &Stack, path: &std::path::Path) -> std::io::Result<()> {
    crate::snapshot::Snapshot::write_file(
        path,
        &stack.coordinator.tables,
        &stack.pipeline.second.flatten(),
    )
}

/// Load the serving pair back from a snapshot file — the load half of
/// [`dump_snapshot`]. Corrupt or truncated bytes are an `Err`, never a
/// panic (see [`crate::snapshot`]).
pub fn load_snapshot(
    path: &std::path::Path,
) -> std::result::Result<(ServingTables, crate::gbdt::FlatForest), String> {
    let s = crate::snapshot::Snapshot::read_file(path)?;
    Ok((s.tables()?, s.forest()))
}

#[cfg(feature = "pjrt")]
fn manifest_shapes(dir: &std::path::Path) -> Result<crate::runtime::Shapes> {
    // Engine::load parses these; we need them before the worker spawns to
    // pad the forest, so parse the manifest cheaply here.
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .context("reading artifacts/manifest.json — run `make artifacts`")?;
    let j = crate::util::json::Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let s = j
        .get("shapes")
        .ok_or_else(|| anyhow::anyhow!("manifest missing shapes"))?;
    let get = |k: &str| {
        s.get(k)
            .and_then(crate::util::json::Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing shapes.{k}"))
    };
    Ok(crate::runtime::Shapes {
        f_max: get("f_max")?,
        nb_max: get("nb_max")?,
        q_max: get("q_max")?,
        nf_max: get("nf_max")?,
        bins_max: get("bins_max")?,
        t_max: get("t_max")?,
        depth: get("depth")?,
    })
}
