//! The multistage coordinator — the paper's system contribution, embedded
//! in "product code".
//!
//! Per request: evaluate the embedded first-stage LRwBins tables (pure Rust,
//! config-table driven, no ML library — the paper's PHP-embedded model);
//! on a route miss, pad the row and call the second-stage RPC service.
//! Batched product requests send ONE coalesced RPC for all missed rows.
//! Every request is timed (wall + CPU) and accounted per stage so Table 3 /
//! §5.2 quantities (mean latency, CPU, coverage, feature-fetch and network
//! bytes) fall out of `ServeMetrics`.

use crate::lrwbins::ServingTables;
use crate::rpc::RpcClient;
use crate::telemetry::{CpuTimer, ServeMetrics};
use std::sync::Arc;
use std::time::Instant;

/// Routing override, used by the Table 3 bench to measure each mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Paper's multistage: embedded stage 1, RPC fallback.
    Multistage,
    /// Always call the RPC service (the conventional architecture).
    AlwaysRpc,
    /// Always answer with stage 1 (even unrouted bins — shadow mode).
    AlwaysStage1,
}

/// Which stage produced a prediction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Served {
    Stage1,
    Rpc,
}

/// Feature-fetch cost model (paper §5.2: feature fetching is a CPU
/// bottleneck; LRwBins fetches only the top-n subset, giving the 1.2×
/// speedup / 70% resource claim). Busy-waits `per_feature_us` per fetched
/// feature so both wall latency AND CPU accounting see the cost, like a
/// real feature-store deserialization would.
#[derive(Clone, Copy, Debug)]
pub struct FetchSim {
    pub per_feature_us: f64,
}

impl FetchSim {
    pub fn fetch(&self, n_features: usize) {
        if self.per_feature_us <= 0.0 || n_features == 0 {
            return;
        }
        let deadline = Instant::now()
            + std::time::Duration::from_nanos((self.per_feature_us * 1000.0) as u64 * n_features as u64);
        while Instant::now() < deadline {
            std::hint::spin_loop();
        }
    }
}

/// The product-code front-end.
pub struct Coordinator {
    pub tables: ServingTables,
    rpc: Option<RpcClient>,
    /// Padded row width expected by the RPC backend (PJRT f_max, or the raw
    /// feature count for the native backend).
    rpc_row_len: usize,
    pub metrics: Arc<ServeMetrics>,
    pub mode: Mode,
    /// Optional feature-fetch cost model (None = features already in hand).
    pub fetch: Option<FetchSim>,
}

impl Coordinator {
    pub fn new(
        tables: ServingTables,
        rpc: Option<RpcClient>,
        rpc_row_len: usize,
        metrics: Arc<ServeMetrics>,
    ) -> Coordinator {
        let rpc_row_len = if rpc_row_len == 0 {
            tables.n_features
        } else {
            rpc_row_len
        };
        assert!(rpc_row_len >= tables.n_features);
        Coordinator {
            tables,
            rpc,
            rpc_row_len,
            metrics,
            mode: Mode::Multistage,
            fetch: None,
        }
    }

    fn pad_for_rpc(&self, row: &[f32], buf: &mut Vec<f32>) {
        buf.extend_from_slice(row);
        buf.resize(buf.len() + (self.rpc_row_len - row.len()), 0.0);
    }

    fn rpc_predict(&self, rows: &[f32], n: usize) -> std::io::Result<Vec<f32>> {
        let client = self.rpc.as_ref().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::NotConnected, "no RPC backend configured")
        })?;
        let probs = client.predict(rows, self.rpc_row_len)?;
        debug_assert_eq!(probs.len(), n);
        Ok(probs)
    }

    /// Serve one inference. Returns `(probability, stage)`.
    pub fn predict(&self, row: &[f32]) -> std::io::Result<(f32, Served)> {
        debug_assert_eq!(row.len(), self.tables.n_features);
        let t0 = Instant::now();
        let cpu = CpuTimer::start();

        // Feature fetch for the stage-1 attempt: only the top-n subset
        // (paper: the first-stage fetches the most important features).
        // AlwaysRpc skips the attempt entirely and fetches everything.
        if let Some(f) = &self.fetch {
            match self.mode {
                Mode::AlwaysRpc => f.fetch(self.tables.n_features),
                _ => f.fetch(self.tables.n_infer()),
            }
        }

        // Embedded stage-1 evaluation (also the router decision).
        let (p1, routed) = self.tables.evaluate(row);
        let stage1_wall = t0.elapsed().as_nanos() as u64;
        let use_stage1 = match self.mode {
            Mode::Multistage => routed,
            Mode::AlwaysRpc => false,
            Mode::AlwaysStage1 => true,
        };
        if use_stage1 {
            self.metrics
                .hit_stage1(stage1_wall, cpu.elapsed_ns(), self.tables.n_infer() as u64);
            self.metrics.e2e.record(t0.elapsed().as_nanos() as u64);
            return Ok((p1, Served::Stage1));
        }

        // Fallback: fetch the remaining features, pad + RPC.
        if let Some(f) = &self.fetch {
            if self.mode != Mode::AlwaysRpc {
                f.fetch(self.tables.n_features.saturating_sub(self.tables.n_infer()));
            }
        }
        let mut padded = Vec::with_capacity(self.rpc_row_len);
        self.pad_for_rpc(row, &mut padded);
        let probs = self.rpc_predict(&padded, 1)?;
        let wall = t0.elapsed().as_nanos() as u64;
        self.metrics.hit_rpc(
            wall,
            cpu.elapsed_ns(),
            self.tables.n_features as u64,
            RpcClient::wire_bytes(1, self.rpc_row_len),
        );
        self.metrics.e2e.record(wall);
        Ok((probs[0], Served::Rpc))
    }

    /// Serve a batched product request: stage-1 for routed rows, one
    /// coalesced RPC for the rest. Returns per-row `(prob, stage)`.
    pub fn predict_batch(&self, rows: &[Vec<f32>]) -> std::io::Result<Vec<(f32, Served)>> {
        let t0 = Instant::now();
        let cpu = CpuTimer::start();
        let mut out: Vec<(f32, Served)> = Vec::with_capacity(rows.len());
        let mut miss_idx = Vec::new();
        let mut miss_rows: Vec<f32> = Vec::new();
        for (i, row) in rows.iter().enumerate() {
            let (p1, routed) = self.tables.evaluate(row);
            let use_stage1 = match self.mode {
                Mode::Multistage => routed,
                Mode::AlwaysRpc => false,
                Mode::AlwaysStage1 => true,
            };
            if use_stage1 {
                out.push((p1, Served::Stage1));
            } else {
                miss_idx.push(i);
                self.pad_for_rpc(row, &mut miss_rows);
                out.push((0.0, Served::Rpc)); // placeholder
            }
        }
        let stage1_cpu = cpu.elapsed_ns();
        let n_hits = rows.len() - miss_idx.len();
        if n_hits > 0 {
            let per = t0.elapsed().as_nanos() as u64 / rows.len().max(1) as u64;
            for _ in 0..n_hits {
                self.metrics.hit_stage1(
                    per,
                    stage1_cpu / rows.len().max(1) as u64,
                    self.tables.n_infer() as u64,
                );
            }
        }
        if !miss_idx.is_empty() {
            let t_rpc = Instant::now();
            let cpu_rpc = CpuTimer::start();
            let probs = self.rpc_predict(&miss_rows, miss_idx.len())?;
            let rpc_wall = t_rpc.elapsed().as_nanos() as u64;
            let rpc_cpu = cpu_rpc.elapsed_ns();
            for (k, &i) in miss_idx.iter().enumerate() {
                out[i].0 = probs[k];
                self.metrics.hit_rpc(
                    rpc_wall / miss_idx.len() as u64,
                    rpc_cpu / miss_idx.len() as u64,
                    self.tables.n_features as u64,
                    RpcClient::wire_bytes(1, self.rpc_row_len),
                );
            }
        }
        let wall = t0.elapsed().as_nanos() as u64;
        for _ in 0..rows.len() {
            self.metrics.e2e.record(wall / rows.len().max(1) as u64);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::features::{rank_features, RankMethod};
    use crate::lrwbins::{LrwBinsModel, LrwBinsParams};
    use crate::rpc::netsim::{NetSim, NetSimConfig};
    use crate::rpc::server::{BatcherConfig, NativeBackend, RpcServer};

    fn setup() -> (crate::tabular::Dataset, Coordinator, RpcServer) {
        let spec = datagen::preset("aci").unwrap().with_rows(4000);
        let data = datagen::generate(&spec, 5);
        let ranking = rank_features(&data, RankMethod::GbdtGain, 1);
        let mut first = LrwBinsModel::train(
            &data,
            &ranking.order,
            &LrwBinsParams {
                b: 2,
                n_bin_features: 3,
                n_infer_features: 6,
                ..Default::default()
            },
        );
        // Route half the bins.
        let route: std::collections::HashSet<u32> =
            first.weights.keys().copied().filter(|b| b % 2 == 0).collect();
        first.set_route(route);
        let second = crate::gbdt::train(&data, &crate::gbdt::GbdtParams::quick());

        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(NativeBackend { model: second }),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig::default(),
            metrics.clone(),
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();
        let tables = ServingTables::from_model(&first);
        let coord = Coordinator::new(tables, Some(client), 0, metrics);
        (data, coord, server)
    }

    #[test]
    fn multistage_conservation_every_row_answered() {
        let (data, coord, _server) = setup();
        let mut s1 = 0;
        let mut rpc = 0;
        let mut row = Vec::new();
        for r in 0..500 {
            data.row_into(r, &mut row);
            let (p, served) = coord.predict(&row).unwrap();
            assert!((0.0..=1.0).contains(&p), "p={p}");
            match served {
                Served::Stage1 => s1 += 1,
                Served::Rpc => rpc += 1,
            }
        }
        assert_eq!(s1 + rpc, 500);
        assert!(s1 > 0, "some rows must be stage-1");
        assert!(rpc > 0, "some rows must fall back");
        assert!((coord.metrics.coverage() - s1 as f64 / 500.0).abs() < 1e-9);
    }

    #[test]
    fn batch_matches_single_row_path() {
        let (data, coord, _server) = setup();
        let rows: Vec<Vec<f32>> = (0..64).map(|r| data.row(r)).collect();
        let batch = coord.predict_batch(&rows).unwrap();
        for (i, row) in rows.iter().enumerate() {
            let (p, served) = coord.predict(row).unwrap();
            assert_eq!(batch[i].1, served, "row {i}");
            assert!((batch[i].0 - p).abs() < 1e-6, "row {i}");
        }
    }

    #[test]
    fn always_rpc_mode_never_uses_stage1() {
        let (data, mut coord, _server) = setup();
        coord.mode = Mode::AlwaysRpc;
        let mut row = Vec::new();
        for r in 0..50 {
            data.row_into(r, &mut row);
            let (_, served) = coord.predict(&row).unwrap();
            assert_eq!(served, Served::Rpc);
        }
    }

    #[test]
    fn always_stage1_mode_never_calls_rpc() {
        let (data, mut coord, _server) = setup();
        coord.mode = Mode::AlwaysStage1;
        let mut row = Vec::new();
        for r in 0..50 {
            data.row_into(r, &mut row);
            let (_, served) = coord.predict(&row).unwrap();
            assert_eq!(served, Served::Stage1);
        }
        assert_eq!(
            coord
                .metrics
                .rpc_calls
                .load(std::sync::atomic::Ordering::Relaxed),
            0
        );
    }

    #[test]
    fn no_rpc_configured_errors_on_miss() {
        let (data, coord, server) = setup();
        let tables = coord.tables.clone();
        let metrics = Arc::new(ServeMetrics::new());
        drop(coord);
        drop(server);
        let lone = Coordinator::new(tables, None, 0, metrics);
        let mut row = Vec::new();
        let mut saw_error = false;
        for r in 0..200 {
            data.row_into(r, &mut row);
            match lone.predict(&row) {
                Ok((_, Served::Stage1)) => {}
                Ok((_, Served::Rpc)) => panic!("cannot serve rpc without client"),
                Err(_) => {
                    saw_error = true;
                    break;
                }
            }
        }
        assert!(saw_error, "expected an error on the first miss");
    }
}
