//! The multistage coordinator — the paper's system contribution, embedded
//! in "product code".
//!
//! Per request: evaluate the embedded first-stage LRwBins tables (pure Rust,
//! config-table driven, no ML library — the paper's PHP-embedded model);
//! on a route miss, pad the row and call the second-stage RPC service.
//! Batched product requests send ONE coalesced RPC for all missed rows.
//! Every request is timed (wall + CPU) and accounted per stage so Table 3 /
//! §5.2 quantities (mean latency, CPU, coverage, feature-fetch and network
//! bytes) fall out of `ServeMetrics`.
//!
//! ## Pipelined block serving
//!
//! The block path is asynchronous at its core:
//! [`Coordinator::predict_block_async`] runs the embedded stage-1 pass,
//! records the stage-1 hits, launches the coalesced fallback RPC for the
//! misses, and returns a [`BlockPending`] — stage-1 results are readable
//! from it **while the RPC is still in flight**, and further blocks can be
//! issued immediately (block N+1's stage-1 pass overlaps block N's
//! outstanding RPC; the pipelined [`RpcClient`] multiplexes the frames on
//! pooled connections). [`BlockPending::wait`] joins the RPC and yields the
//! complete per-row results. The synchronous [`Coordinator::predict_block`]
//! is a thin `async → wait()` wrapper, so the bit-identity property tests
//! pin both paths at once.
//!
//! The fallback itself is **streamed**: the server answers the coalesced
//! miss RPC in sub-batch `CHUNK` frames as its shards complete them, and
//! [`BlockPending::poll_fallback`] surfaces each span's rows the moment its
//! frame lands — callers consume early fallback rows while later spans are
//! still in flight, the per-span analogue of reading stage-1 hits under the
//! outstanding RPC. [`BlockPipeline`] stacks this with **adaptive depth**:
//! it keeps as many blocks in flight as the live stage1-done/rpc-done
//! completion gap ([`ServeMetrics::suggested_pipeline_depth`]) says the
//! network can hide, instead of a hardwired depth.
//!
//! The embedded stage-1 pass itself runs the lane-tiled/AVX2 block kernels
//! of [`crate::lrwbins::tables`] (runtime-dispatched at table construction,
//! forceable per coordinator via [`Coordinator::set_stage1_dispatch`]);
//! every tier is bit-identical, so routing decisions and Table 3 numbers
//! cannot depend on which machine served the block.
//!
//! Per-row accounting matches the scalar path: a hit's latency is the time
//! until the stage-1 pass delivered it; a miss's latency is the time until
//! the fallback delivered **its span** (streamed spans complete at their
//! chunk's arrival, monolithic responses at the response's — never an
//! amortized share of one wall clock); the coalesced RPC's wire bytes are
//! the ACTUAL frames moved (one k-row request plus the response frames,
//! chunked or not), split across the k missed rows.

use crate::gbdt::ForestScratch;
use crate::lrwbins::{BlockScratch, ServingTables, Stage1Dispatch};
use crate::rpc::client::PendingPredict;
use crate::rpc::fault::is_breaker_open;
use crate::rpc::{PredictOptions, RpcClient};
use crate::runtime::{ModelId, ShardPool};
use crate::snapshot::Snapshot;
use crate::tabular::RowBlock;
use crate::telemetry::{CpuTimer, ServeMetrics};
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

mod rollout;

pub use rollout::{RollbackReason, Rollout, RolloutConfig, RolloutPhase};

/// Where route-missed rows go for second-stage scoring.
///
/// * `Rpc` — the paper's architecture: a coalesced call to the remote
///   dynamic-batched service.
/// * `Embedded` — the in-process **multi-tenant** mode: the coordinator
///   registered its second-stage forest in a shared shard-per-core
///   [`ShardPool`] and scores misses on it directly — no wire, no frames,
///   several tenants (coordinators) sharing one pool of cores. Rows served
///   this way still report [`Served::Rpc`] ("second stage"), with zero
///   network bytes accounted.
pub enum SecondStage {
    Rpc(RpcClient),
    Embedded {
        pool: Arc<ShardPool>,
        model: ModelId,
    },
}

/// Routing override, used by the Table 3 bench to measure each mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Paper's multistage: embedded stage 1, RPC fallback.
    Multistage,
    /// Always call the RPC service (the conventional architecture).
    AlwaysRpc,
    /// Always answer with stage 1 (even unrouted bins — shadow mode).
    AlwaysStage1,
}

/// Which stage produced a prediction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Served {
    Stage1,
    Rpc,
    /// The second stage was unavailable (breaker open, deadline spent, or a
    /// transport failure that outlived the retry policy) and the row was
    /// answered with its **stage-1 prior** under
    /// [`DegradeMode::Stage1Prior`]. An explicit outcome, never silently
    /// conflated with a real second-stage answer: degraded rows are counted
    /// in [`ServeMetrics::degraded_rows`](crate::telemetry::ServeMetrics),
    /// not `rpc_calls`.
    Degraded,
}

/// What a route-missed row gets when the second stage cannot serve it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DegradeMode {
    /// Propagate the failure to the caller (an error instead of results).
    /// The default — degradation is an explicit opt-in.
    #[default]
    Fail,
    /// Answer missed rows with their stage-1 prior, marked
    /// [`Served::Degraded`]; stage-1-amenable rows are unaffected.
    Stage1Prior,
    /// Wait out an open breaker (bounded by the request deadline, or
    /// [`BLOCK_MODE_CAP`] without one) and try again; transport failures
    /// still propagate.
    Block,
}

/// Upper bound on how long [`DegradeMode::Block`] waits for the breaker to
/// re-admit when the request carries no deadline.
pub const BLOCK_MODE_CAP: Duration = Duration::from_secs(1);

/// Sleep quantum while [`DegradeMode::Block`] waits on an open breaker.
const BLOCK_MODE_POLL: Duration = Duration::from_millis(5);

/// Feature-fetch cost model (paper §5.2: feature fetching is a CPU
/// bottleneck; LRwBins fetches only the top-n subset, giving the 1.2×
/// speedup / 70% resource claim). Busy-waits `per_feature_us` per fetched
/// feature so both wall latency AND CPU accounting see the cost, like a
/// real feature-store deserialization would.
#[derive(Clone, Copy, Debug)]
pub struct FetchSim {
    pub per_feature_us: f64,
}

impl FetchSim {
    /// Total simulated fetch cost for `n_features`. Computed in f64 *before*
    /// truncating to integer nanoseconds — casting the per-feature cost
    /// first would silently drop fractional-ns costs (e.g. 0.5ns/feature
    /// over 1000 features is 500ns, not 0).
    pub fn duration(&self, n_features: usize) -> Duration {
        if self.per_feature_us <= 0.0 || n_features == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.per_feature_us * 1000.0 * n_features as f64) as u64)
    }

    pub fn fetch(&self, n_features: usize) {
        let cost = self.duration(n_features);
        if cost.is_zero() {
            return;
        }
        let deadline = Instant::now() + cost;
        while Instant::now() < deadline {
            std::hint::spin_loop();
        }
    }
}

/// Reusable per-coordinator scratch for the batched path: the transposed
/// request block, stage-1 outputs, and the coalesced RPC gather buffer all
/// persist across requests, so a steady-state batch costs zero allocations
/// beyond the caller-visible result vector.
#[derive(Default)]
struct CoordScratch {
    block: RowBlock,
    tab: BlockScratch,
    probs: Vec<f32>,
    routed: Vec<bool>,
    miss_idx: Vec<usize>,
    miss_rows: Vec<f32>,
    row: Vec<f32>,
}

/// The product-code front-end.
pub struct Coordinator {
    pub tables: ServingTables,
    fallback: Option<SecondStage>,
    /// Padded row width expected by the second-stage backend (PJRT f_max,
    /// or the raw feature count for the native/embedded backends).
    rpc_row_len: usize,
    pub metrics: Arc<ServeMetrics>,
    pub mode: Mode,
    /// What route-missed rows get when the second stage cannot serve them
    /// (breaker open, deadline spent, transport failure past the retry
    /// policy). Default: [`DegradeMode::Fail`].
    pub degrade: DegradeMode,
    /// Optional feature-fetch cost model (None = features already in hand).
    pub fetch: Option<FetchSim>,
    /// Brownout rung (see [`Coordinator::set_brownout`]): 0 = off,
    /// 1 = low-priority misses answer their stage-1 prior preemptively,
    /// 2 = every miss does. Only effective under
    /// [`DegradeMode::Stage1Prior`] — brownout IS that degradation,
    /// applied before the second stage is even asked.
    brownout: AtomicU8,
    /// The guarded rollout in flight, if any (see [`Rollout`] and
    /// [`Coordinator::begin_rollout`]). `rollout_on` is the hot paths' fast
    /// gate: with no rollout active they pay one relaxed load, never the
    /// mutex.
    rollout: Mutex<Option<Arc<Rollout>>>,
    rollout_on: AtomicBool,
    scratch: Mutex<CoordScratch>,
}

/// Brownout rung: low-priority requests are browned out, full-priority
/// traffic still gets the second stage.
pub const BROWNOUT_LOW_PRIORITY: u8 = 1;
/// Brownout rung: every route-missed request answers its stage-1 prior.
pub const BROWNOUT_ALL: u8 = 2;

impl Coordinator {
    pub fn new(
        tables: ServingTables,
        rpc: Option<RpcClient>,
        rpc_row_len: usize,
        metrics: Arc<ServeMetrics>,
    ) -> Coordinator {
        Coordinator::with_fallback(tables, rpc.map(SecondStage::Rpc), rpc_row_len, metrics)
    }

    /// Embedded multi-tenant mode: this coordinator's second-stage forest
    /// was registered (by the caller) in `pool` — possibly shared with
    /// other tenants — and misses are scored in-process on it. See the
    /// crate docs.
    pub fn new_embedded(
        tables: ServingTables,
        pool: Arc<ShardPool>,
        model: ModelId,
        metrics: Arc<ServeMetrics>,
    ) -> Coordinator {
        let row_len = pool.n_features(model).max(tables.n_features);
        Coordinator::with_fallback(
            tables,
            Some(SecondStage::Embedded { pool, model }),
            row_len,
            metrics,
        )
    }

    /// General form: any [`SecondStage`] (or none — stage-1-only serving).
    pub fn with_fallback(
        tables: ServingTables,
        fallback: Option<SecondStage>,
        rpc_row_len: usize,
        metrics: Arc<ServeMetrics>,
    ) -> Coordinator {
        let rpc_row_len = if rpc_row_len == 0 {
            tables.n_features
        } else {
            rpc_row_len
        };
        assert!(rpc_row_len >= tables.n_features);
        Coordinator {
            tables,
            fallback,
            rpc_row_len,
            metrics,
            mode: Mode::Multistage,
            degrade: DegradeMode::default(),
            fetch: None,
            brownout: AtomicU8::new(0),
            rollout: Mutex::new(None),
            rollout_on: AtomicBool::new(false),
            scratch: Mutex::new(CoordScratch::default()),
        }
    }

    /// Set the brownout rung — the intermediate step of the overload
    /// ladder, between full service and admission rejection: under
    /// measured pressure the SLO controller degrades *before* dropping.
    /// `0` = off; [`BROWNOUT_LOW_PRIORITY`] answers low-priority misses
    /// (see [`PredictOptions::low_priority`]) with their stage-1 prior as
    /// [`Served::Degraded`] without spending second-stage capacity;
    /// [`BROWNOUT_ALL`] does that for every miss. Levels past 2 clamp.
    /// No-op unless `degrade == DegradeMode::Stage1Prior` — brownout
    /// must never silently degrade a coordinator that promised errors.
    pub fn set_brownout(&self, level: u8) {
        self.brownout.store(level.min(BROWNOUT_ALL), Ordering::Relaxed);
    }

    /// The current brownout rung (0 = off).
    pub fn brownout(&self) -> u8 {
        self.brownout.load(Ordering::Relaxed)
    }

    /// Does the ladder shed this request's second-stage work right now?
    fn browned_out(&self, opts: &PredictOptions) -> bool {
        self.degrade == DegradeMode::Stage1Prior
            && match self.brownout.load(Ordering::Relaxed) {
                0 => false,
                BROWNOUT_LOW_PRIORITY => opts.low_priority,
                _ => true,
            }
    }

    /// The second-stage RPC client, when that is the configured fallback
    /// (breaker drills, failure telemetry).
    pub fn rpc_client(&self) -> Option<&RpcClient> {
        match &self.fallback {
            Some(SecondStage::Rpc(client)) => Some(client),
            _ => None,
        }
    }

    /// Mirror the client's retry/breaker counters into [`ServeMetrics`] so
    /// one report covers the whole failure model. Called on every second-
    /// stage completion and every degradation.
    fn sync_rpc_failure_counters(&self) {
        if let Some(client) = self.rpc_client() {
            use std::sync::atomic::Ordering;
            self.metrics
                .rpc_retries
                .store(client.retries(), Ordering::Relaxed);
            self.metrics.breaker_trips.store(
                client.breaker().trips.load(Ordering::Relaxed),
                Ordering::Relaxed,
            );
        }
    }

    /// [`DegradeMode::Block`]: sleep out an open breaker, bounded by the
    /// request deadline (or [`BLOCK_MODE_CAP`] without one). Returns false
    /// once the bound is spent — the caller then propagates the error.
    fn block_on_breaker(&self, opts: &PredictOptions, waited: &mut Duration) -> bool {
        let cap = opts
            .deadline
            .map_or(BLOCK_MODE_CAP, |d| d.remaining().min(BLOCK_MODE_CAP));
        if *waited >= cap {
            return false;
        }
        std::thread::sleep(BLOCK_MODE_POLL);
        *waited += BLOCK_MODE_POLL;
        true
    }

    /// Force the stage-1 block-kernel tier (`ServeConfig::stage1_simd`,
    /// A/B benching — see [`crate::lrwbins::tables`] for the tiers and the
    /// bit-identity guarantee). Returns the tier actually installed
    /// (unavailable requests clamp).
    pub fn set_stage1_dispatch(&mut self, d: Stage1Dispatch) -> Stage1Dispatch {
        self.tables.set_dispatch(d)
    }

    /// Live model reload from a parsed [`crate::snapshot::Snapshot`]: swap
    /// this tenant's stage-1 tables and — in embedded mode — hot-swap its
    /// second-stage forest in the shared [`ShardPool`], under traffic
    /// (in-flight batches finish on the version they were stamped with; see
    /// [`ShardPool::swap`]).
    ///
    /// The snapshot must serve the same feature width as the current tables:
    /// `rpc_row_len` and every caller's row layout were sized against it at
    /// construction, so a width change is a redeploy, not a reload, and is
    /// rejected before anything is touched. On any error the coordinator is
    /// unchanged. Returns the pool-side model version now serving (0 when
    /// the second stage is RPC or absent — those backends own their own
    /// model lifecycle and only the stage-1 tables are swapped).
    pub fn reload(&mut self, snapshot: &Snapshot) -> Result<u32, String> {
        let mut tables = snapshot.tables()?;
        if tables.n_features != self.tables.n_features {
            return Err(format!(
                "reload: snapshot serves {} features, coordinator was built for {} \
                 (feature-width changes require a new coordinator)",
                tables.n_features, self.tables.n_features
            ));
        }
        // Preserve a forced kernel tier across the reload (A/B runs pin it).
        tables.set_dispatch(self.tables.dispatch());
        let version = match &self.fallback {
            Some(SecondStage::Embedded { pool, model }) => pool.swap(*model, snapshot.forest())?,
            _ => 0,
        };
        self.tables = tables;
        self.metrics
            .model_reloads
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(version)
    }

    /// Start a **guarded rollout** of `snapshot` (see [`Rollout`] and the
    /// crate docs' "Model rollout" section): the candidate enters **Shadow**
    /// — served bits stay bit-identical to pre-rollout while the divergence
    /// monitor compares sampled traffic against it — and is walked to
    /// promotion (or automatic rollback) by [`Coordinator::rollout_tick`].
    ///
    /// Embedded mode STAGES the candidate forest in the shard pool
    /// (versioned next to the incumbent, pinned by a lease for the
    /// rollout's lifetime); RPC / stage-1-only coordinators score the
    /// candidate in-process from the snapshot. Same feature-width rule as
    /// [`Coordinator::reload`]; at most one rollout may be in flight.
    pub fn begin_rollout(
        &self,
        snapshot: &Snapshot,
        cfg: RolloutConfig,
    ) -> Result<Arc<Rollout>, String> {
        let mut tables = snapshot.tables()?;
        if tables.n_features != self.tables.n_features {
            return Err(format!(
                "rollout: snapshot serves {} features, coordinator was built for {} \
                 (feature-width changes require a new coordinator)",
                tables.n_features, self.tables.n_features
            ));
        }
        tables.set_dispatch(self.tables.dispatch());
        let mut slot = self.rollout.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(active) = &*slot {
            if matches!(active.phase(), RolloutPhase::Shadow | RolloutPhase::Canary) {
                return Err(
                    "rollout: another candidate is already in flight (end_rollout first)".into(),
                );
            }
        }
        let stage2 = match &self.fallback {
            Some(SecondStage::Embedded { pool, model }) => {
                let version = pool.stage(*model, snapshot.forest())?;
                let lease = pool.pin_version(*model, version).ok_or_else(|| {
                    "rollout: staged version vanished before it could be pinned".to_string()
                })?;
                rollout::CandidateStage2::Pool {
                    pool: pool.clone(),
                    model: *model,
                    version,
                    _lease: lease,
                }
            }
            _ => rollout::CandidateStage2::Local {
                forest: Arc::new(snapshot.forest()),
                scratch: Mutex::new(ForestScratch::default()),
            },
        };
        let ro = Arc::new(Rollout::new(cfg, tables, stage2));
        *slot = Some(ro.clone());
        drop(slot);
        self.rollout_on.store(true, Ordering::Release);
        Ok(ro)
    }

    /// The rollout currently installed (any phase), if one exists.
    pub fn rollout(&self) -> Option<Arc<Rollout>> {
        if !self.rollout_on.load(Ordering::Acquire) {
            return None;
        }
        self.rollout
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .as_ref()
            .cloned()
    }

    /// Deliver one SLO-controller tick to the in-flight rollout.
    /// `escalated` = the controller is in brownout or throttling admission:
    /// the ramp freezes instead of advancing — an overloaded system must
    /// not widen a model experiment. No-op without an active rollout.
    pub fn rollout_tick(&self, escalated: bool) {
        if let Some(ro) = self.rollout() {
            ro.tick(escalated);
        }
    }

    /// Retire the rollout (any phase): canary routing and shadow sampling
    /// stop immediately, and a candidate that did not promote is unstaged
    /// from the pool (its lease keeps in-flight work resolvable until the
    /// returned handle drops). Returns the rollout for post-mortem reads.
    pub fn end_rollout(&self) -> Option<Arc<Rollout>> {
        self.rollout_on.store(false, Ordering::Release);
        let ro = self
            .rollout
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .take()?;
        if ro.phase() != RolloutPhase::Promoted {
            if let rollout::CandidateStage2::Pool { pool, model, .. } = &ro.stage2 {
                pool.unstage(*model);
            }
        }
        Some(ro)
    }

    /// Complete a PROMOTED rollout: install the candidate stage-1 tables as
    /// the incumbent, promote the staged forest in the pool, and retire the
    /// rollout — serving returns to the plain (non-canary) path on the new
    /// model. While promoted-but-unfinalized the candidate already serves
    /// 100% of traffic through the canary route, so there is no serving
    /// gap; this retires the bookkeeping. Returns the pool-side version now
    /// serving (0 for RPC / stage-1-only coordinators, as in
    /// [`Coordinator::reload`]).
    pub fn finalize_rollout(&mut self) -> Result<u32, String> {
        let ro = self.rollout().ok_or("rollout: nothing to finalize")?;
        if ro.phase() != RolloutPhase::Promoted {
            return Err(format!(
                "rollout: candidate is {:?}, not Promoted",
                ro.phase()
            ));
        }
        let version = match &ro.stage2 {
            rollout::CandidateStage2::Pool { pool, model, .. } => pool.promote(*model)?,
            rollout::CandidateStage2::Local { .. } => 0,
        };
        self.tables = ro.tables.clone();
        self.rollout_on.store(false, Ordering::Release);
        *self.rollout.lock().unwrap_or_else(PoisonError::into_inner) = None;
        self.metrics
            .model_reloads
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(version)
    }

    /// The active rollout while it is canary-routing (Canary or Promoted
    /// with a nonzero slice) — the hot paths' entry check.
    fn canary_rollout(&self) -> Option<Arc<Rollout>> {
        if !self.rollout_on.load(Ordering::Acquire) {
            return None;
        }
        let slot = self.rollout.lock().unwrap_or_else(PoisonError::into_inner);
        let ro = slot.as_ref()?;
        if matches!(ro.phase(), RolloutPhase::Canary | RolloutPhase::Promoted)
            && ro.canary_permille() > 0
        {
            Some(ro.clone())
        } else {
            None
        }
    }

    /// Route this request to the candidate? Deterministic on the rollout
    /// key, and only if the error budget admits `n` more candidate-answered
    /// rows.
    fn canary_claim(&self, ro: &Rollout, n: usize, opts: &PredictOptions) -> bool {
        let key = opts.rollout_key.unwrap_or_else(|| ro.next_key());
        ro.routes(key) && ro.try_reserve_budget(n as u64)
    }

    /// The active rollout iff it is shadow-monitoring AND sampled THIS
    /// batch into the comparison.
    fn rollout_shadow_sample(&self) -> Option<Arc<Rollout>> {
        if !self.rollout_on.load(Ordering::Acquire) {
            return None;
        }
        let slot = self.rollout.lock().unwrap_or_else(PoisonError::into_inner);
        let ro = slot.as_ref()?;
        if ro.samples_shadow() {
            ro.stats.shadow_batches.fetch_add(1, Ordering::Relaxed);
            Some(ro.clone())
        } else {
            None
        }
    }

    /// Serve one whole claimed batch on the CANDIDATE: its stage-1 tables
    /// route, its second stage scores the misses — never mixing versions
    /// within the batch. `None` means the candidate failed mid-serve: the
    /// failure guard has tripped, the budget reservation was returned, and
    /// the caller must serve the batch on the incumbent (the candidate
    /// never answered it).
    fn canary_serve_flat(
        &self,
        ro: &Arc<Rollout>,
        flat: &[f32],
        n: usize,
        opts: &PredictOptions,
        t0: Instant,
        cpu: CpuTimer,
    ) -> Option<std::io::Result<Vec<(f32, Served)>>> {
        let nf = self.tables.n_features;
        debug_assert_eq!(flat.len(), n * nf);
        // Stage-1 feature fetch for the candidate tables' subset — the
        // same mode shape as the incumbent path.
        if let Some(f) = &self.fetch {
            match self.mode {
                Mode::AlwaysRpc => f.fetch(n * nf),
                _ => f.fetch(n * ro.tables.n_infer()),
            }
        }
        let mut out: Vec<(f32, Served)> = Vec::with_capacity(n);
        let mut miss_idx: Vec<usize> = Vec::new();
        for r in 0..n {
            let (p1, routed) = ro.tables.evaluate(&flat[r * nf..(r + 1) * nf]);
            let use_stage1 = match self.mode {
                Mode::Multistage => routed,
                Mode::AlwaysRpc => false,
                Mode::AlwaysStage1 => true,
            };
            if use_stage1 {
                out.push((p1, Served::Stage1));
            } else {
                miss_idx.push(r);
                out.push((p1, Served::Rpc));
            }
        }
        let stage1_wall = t0.elapsed().as_nanos() as u64;
        let stage1_cpu_total = cpu.elapsed_ns();
        let per_row_cpu = stage1_cpu_total / n.max(1) as u64;
        if !miss_idx.is_empty() {
            if self.mode != Mode::AlwaysRpc {
                if let Some(f) = &self.fetch {
                    let rest = nf.saturating_sub(ro.tables.n_infer());
                    f.fetch(miss_idx.len() * rest);
                }
            }
            let mut padded = Vec::with_capacity(miss_idx.len() * self.rpc_row_len);
            for &i in &miss_idx {
                self.pad_for_rpc(&flat[i * nf..(i + 1) * nf], &mut padded);
            }
            let mut probs = vec![0f32; miss_idx.len()];
            let deadline = opts.deadline.map(|d| d.instant());
            if ro
                .score_candidate(&padded, self.rpc_row_len, &mut probs, deadline)
                .is_err()
            {
                // Candidate failure on real traffic: maximal divergence.
                // Return the budget (the candidate did NOT answer these
                // rows), trip the guard, and let the caller serve the whole
                // batch on the incumbent — no mixed batch ever existed.
                ro.release_budget(n as u64);
                ro.stats.candidate_failures.fetch_add(1, Ordering::Relaxed);
                ro.trip(RollbackReason::CandidateFailure, &self.metrics);
                return None;
            }
            for (j, &i) in miss_idx.iter().enumerate() {
                out[i].0 = probs[j];
            }
        }
        // Accounting mirrors the incumbent path: hits book at the stage-1
        // wall, misses at the batch wall — with ZERO wire bytes, the
        // candidate always scores in-process.
        let wall = t0.elapsed().as_nanos() as u64;
        let k = miss_idx.len();
        for _ in 0..n - k {
            self.metrics
                .hit_stage1(stage1_wall, per_row_cpu, ro.tables.n_infer() as u64);
            self.metrics.e2e.record(stage1_wall);
        }
        if n > 0 {
            self.metrics.block_stage1_complete.record(stage1_wall);
        }
        if k > 0 {
            let cpu_share =
                per_row_cpu + cpu.elapsed_ns().saturating_sub(stage1_cpu_total) / k as u64;
            self.record_miss_completion(k, wall, cpu_share, 0);
        }
        ro.note_canary_batch(n as u64, wall, &self.metrics);
        Some(Ok(out))
    }

    fn pad_for_rpc(&self, row: &[f32], buf: &mut Vec<f32>) {
        buf.reserve(self.rpc_row_len);
        buf.extend_from_slice(row);
        buf.resize(buf.len() + (self.rpc_row_len - row.len()), 0.0);
    }

    /// Score `n` padded rows on the configured second stage, blocking.
    fn second_stage_predict(
        &self,
        rows: &[f32],
        n: usize,
        opts: &PredictOptions,
    ) -> std::io::Result<Vec<f32>> {
        match &self.fallback {
            None => Err(no_second_stage()),
            Some(SecondStage::Rpc(client)) => {
                let mut waited = Duration::ZERO;
                loop {
                    match client.predict_opts(rows, self.rpc_row_len, opts) {
                        Ok(probs) => {
                            debug_assert_eq!(probs.len(), n);
                            return Ok(probs);
                        }
                        Err(e)
                            if self.degrade == DegradeMode::Block
                                && is_breaker_open(&e)
                                && self.block_on_breaker(opts, &mut waited) => {}
                        Err(e) => return Err(e),
                    }
                }
            }
            Some(SecondStage::Embedded { pool, model }) => {
                let mut probs = vec![0f32; n];
                pool.predict(*model, rows, self.rpc_row_len, &mut probs)
                    .map_err(std::io::Error::other)?;
                Ok(probs)
            }
        }
    }

    /// Wire bytes a k-row miss batch moves — zero for the embedded
    /// (in-process) second stage.
    fn miss_wire_bytes(&self, k: usize) -> u64 {
        match &self.fallback {
            Some(SecondStage::Rpc(_)) => RpcClient::wire_bytes(k, self.rpc_row_len),
            _ => 0,
        }
    }

    /// Book the completion of a block's misses, one wall clock per miss row
    /// — the ONE implementation of the Table-3 miss accounting, shared by
    /// the RPC join ([`BlockPending::wait`]) and the embedded in-process
    /// path: per miss, second-stage latency/CPU/features plus an even byte
    /// split of the coalesced traffic (remainder on the first row), and the
    /// per-block rpc-complete timestamp (the LAST row's completion — the
    /// block is done when its slowest span is).
    fn record_miss_rows(&self, walls: &[u64], cpu_share: u64, total_bytes: u64) {
        let k = walls.len();
        debug_assert!(k > 0);
        let byte_share = total_bytes / k as u64;
        let byte_rem = total_bytes % k as u64;
        let mut max_wall = 0u64;
        for (j, &wall) in walls.iter().enumerate() {
            self.metrics.hit_rpc(
                wall,
                cpu_share,
                self.tables.n_features as u64,
                byte_share + if j == 0 { byte_rem } else { 0 },
            );
            self.metrics.e2e.record(wall);
            max_wall = max_wall.max(wall);
        }
        self.metrics.block_rpc_complete.record(max_wall);
        self.sync_rpc_failure_counters();
    }

    /// Uniform-wall shorthand for [`Coordinator::record_miss_rows`] (the
    /// embedded path, where all misses complete together in-process).
    fn record_miss_completion(&self, k: usize, wall: u64, cpu_share: u64, total_bytes: u64) {
        self.record_miss_rows(&vec![wall; k], cpu_share, total_bytes);
    }

    /// Serve one inference. Returns `(probability, stage)`.
    pub fn predict(&self, row: &[f32]) -> std::io::Result<(f32, Served)> {
        self.predict_with(row, &PredictOptions::default())
    }

    /// [`Coordinator::predict`] with per-request options: the deadline
    /// budget rides every downstream hop (client send, server batcher,
    /// shard pool), and the degrade policy decides what a miss gets when
    /// the second stage cannot serve it.
    pub fn predict_with(
        &self,
        row: &[f32],
        opts: &PredictOptions,
    ) -> std::io::Result<(f32, Served)> {
        debug_assert_eq!(row.len(), self.tables.n_features);
        // Guarded rollout, canary phase: the row either routes to the
        // candidate wholesale or serves the incumbent exactly as before.
        if let Some(ro) = self.canary_rollout() {
            if self.canary_claim(&ro, 1, opts) {
                let t0 = Instant::now();
                let cpu = CpuTimer::start();
                if let Some(res) = self.canary_serve_flat(&ro, row, 1, opts, t0, cpu) {
                    return res.map(|mut v| v.pop().expect("one row"));
                }
            }
        }
        let t0 = Instant::now();
        let cpu = CpuTimer::start();

        // Feature fetch for the stage-1 attempt: only the top-n subset
        // (paper: the first-stage fetches the most important features).
        // AlwaysRpc skips the attempt entirely and fetches everything.
        if let Some(f) = &self.fetch {
            match self.mode {
                Mode::AlwaysRpc => f.fetch(self.tables.n_features),
                _ => f.fetch(self.tables.n_infer()),
            }
        }

        // Embedded stage-1 evaluation (also the router decision).
        let (p1, routed) = self.tables.evaluate(row);
        let stage1_wall = t0.elapsed().as_nanos() as u64;
        // Guarded rollout, shadow monitor: a sampled row compares stage-1
        // decisions inline; a sampled MISS also shadow-scores on the
        // candidate's second stage once its live score is known (below).
        let shadow = self.rollout_shadow_sample();
        if let Some(ro) = &shadow {
            ro.compare_stage1_row(&self.tables, row, &self.metrics);
        }
        let use_stage1 = match self.mode {
            Mode::Multistage => routed,
            Mode::AlwaysRpc => false,
            Mode::AlwaysStage1 => true,
        };
        if use_stage1 {
            self.metrics
                .hit_stage1(stage1_wall, cpu.elapsed_ns(), self.tables.n_infer() as u64);
            self.metrics.e2e.record(t0.elapsed().as_nanos() as u64);
            return Ok((p1, Served::Stage1));
        }

        // Brownout rung: shed this miss's second-stage work PREEMPTIVELY
        // (no remaining-feature fetch, no RPC) and answer the stage-1
        // prior, explicitly marked and counted as degraded.
        if self.browned_out(opts) {
            self.metrics.degraded_rows.fetch_add(1, Ordering::Relaxed);
            self.metrics.degraded_requests.fetch_add(1, Ordering::Relaxed);
            self.metrics.e2e.record(t0.elapsed().as_nanos() as u64);
            return Ok((p1, Served::Degraded));
        }

        // Fallback: fetch the remaining features, pad + RPC.
        if let Some(f) = &self.fetch {
            if self.mode != Mode::AlwaysRpc {
                f.fetch(self.tables.n_features.saturating_sub(self.tables.n_infer()));
            }
        }
        let mut padded = Vec::with_capacity(self.rpc_row_len);
        self.pad_for_rpc(row, &mut padded);
        let probs = match self.second_stage_predict(&padded, 1, opts) {
            Ok(probs) => probs,
            Err(e) => {
                self.sync_rpc_failure_counters();
                if self.degrade != DegradeMode::Stage1Prior {
                    return Err(e);
                }
                // Graceful degradation: answer with the stage-1 prior,
                // explicitly marked — and counted — as degraded.
                use std::sync::atomic::Ordering;
                self.metrics.degraded_rows.fetch_add(1, Ordering::Relaxed);
                self.metrics.degraded_requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.e2e.record(t0.elapsed().as_nanos() as u64);
                return Ok((p1, Served::Degraded));
            }
        };
        let wall = t0.elapsed().as_nanos() as u64;
        self.metrics.hit_rpc(
            wall,
            cpu.elapsed_ns(),
            self.tables.n_features as u64,
            self.miss_wire_bytes(1),
        );
        self.metrics.e2e.record(wall);
        self.sync_rpc_failure_counters();
        if let Some(ro) = &shadow {
            Rollout::shadow_score_misses(
                ro,
                &padded,
                self.rpc_row_len,
                vec![probs[0]],
                wall,
                &self.metrics,
            );
        }
        Ok((probs[0], Served::Rpc))
    }

    /// Serve a batched product request: stage-1 for routed rows, one
    /// coalesced RPC for the rest. Returns per-row `(prob, stage)`.
    ///
    /// Transposes `rows` into the reusable columnar scratch block and runs
    /// the block path ([`Coordinator::predict_block`]); results are
    /// bit-identical to the scalar per-row path.
    pub fn predict_batch(&self, rows: &[Vec<f32>]) -> std::io::Result<Vec<(f32, Served)>> {
        self.predict_batch_opts(rows, &PredictOptions::default())
    }

    /// [`Coordinator::predict_batch`] with per-request options (deadline
    /// budget, low-priority marking for the brownout ladder).
    pub fn predict_batch_opts(
        &self,
        rows: &[Vec<f32>],
        opts: &PredictOptions,
    ) -> std::io::Result<Vec<(f32, Served)>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        // Guarded rollout, canary phase: a routed batch serves WHOLE on the
        // candidate — versions are never mixed within a batch.
        if let Some(ro) = self.canary_rollout() {
            if self.canary_claim(&ro, rows.len(), opts) {
                let t0 = Instant::now();
                let cpu = CpuTimer::start();
                let nf = self.tables.n_features;
                let mut flat = Vec::with_capacity(rows.len() * nf);
                for r in rows {
                    debug_assert_eq!(r.len(), nf);
                    flat.extend_from_slice(r);
                }
                if let Some(res) = self.canary_serve_flat(&ro, &flat, rows.len(), opts, t0, cpu) {
                    return res;
                }
            }
        }
        let t0 = Instant::now();
        let cpu = CpuTimer::start();
        self.fetch_stage1(rows.len());
        let mut guard = self.lock_scratch();
        let mut block = std::mem::take(&mut guard.block);
        block.fill_from_rows(rows);
        let pending = self.serve_block_async(&block, Some(rows), guard, t0, cpu, opts);
        self.lock_scratch().block = block;
        pending?.wait()
    }

    /// Serve a columnar request block synchronously: one batched stage-1
    /// evaluation over the whole block, then one coalesced RPC carrying
    /// every route-missed row. Per-row results are bit-identical to
    /// [`Coordinator::predict`]. Thin blocking wrapper over
    /// [`Coordinator::predict_block_async`].
    pub fn predict_block(&self, block: &RowBlock) -> std::io::Result<Vec<(f32, Served)>> {
        self.predict_block_async(block)?.wait()
    }

    /// Serve a columnar request block, pipelined: when this returns, the
    /// embedded stage-1 pass has run, its hits are readable from the
    /// [`BlockPending`] (and recorded in the metrics), and the coalesced
    /// fallback RPC for the misses is in flight. Call
    /// [`BlockPending::wait`] for the complete results; issue further
    /// blocks before waiting to overlap their stage-1 passes with this
    /// block's RPC.
    pub fn predict_block_async(&self, block: &RowBlock) -> std::io::Result<BlockPending<'_>> {
        self.predict_block_async_opts(block, &PredictOptions::default())
    }

    /// [`Coordinator::predict_block_async`] with per-request options — the
    /// deadline budget rides the coalesced miss RPC and the degrade policy
    /// governs what missed rows get when the second stage fails.
    pub fn predict_block_async_opts(
        &self,
        block: &RowBlock,
        opts: &PredictOptions,
    ) -> std::io::Result<BlockPending<'_>> {
        // Guarded rollout, canary phase: a routed block serves WHOLE on the
        // candidate (completed inline — its second stage is in-process, so
        // there is no RPC to overlap) and returns an already-joined
        // pending, bit-identical to waiting on the normal path.
        if block.n_rows() > 0 {
            if let Some(ro) = self.canary_rollout() {
                if self.canary_claim(&ro, block.n_rows(), opts) {
                    let t0 = Instant::now();
                    let cpu = CpuTimer::start();
                    let nf = self.tables.n_features;
                    let mut flat = Vec::with_capacity(block.n_rows() * nf);
                    let mut row = Vec::new();
                    for i in 0..block.n_rows() {
                        block.row_into(i, &mut row);
                        flat.extend_from_slice(&row);
                    }
                    if let Some(res) =
                        self.canary_serve_flat(&ro, &flat, block.n_rows(), opts, t0, cpu)
                    {
                        let out = res?;
                        return Ok(BlockPending {
                            coord: self,
                            out,
                            miss_idx: Vec::new(),
                            miss_rows: Vec::new(),
                            rpc: None,
                            t0,
                            miss_cpu_base: 0,
                            span_walls: Vec::new(),
                            delivered: Vec::new(),
                            shadow: None,
                        });
                    }
                }
            }
        }
        let t0 = Instant::now();
        let cpu = CpuTimer::start();
        self.fetch_stage1(block.n_rows());
        let guard = self.lock_scratch();
        self.serve_block_async(block, None, guard, t0, cpu, opts)
    }

    /// Simulated feature fetch for a whole block's stage-1 attempt,
    /// amortized into one busy-wait: every row pays for its top-n subset;
    /// AlwaysRpc skips the attempt and fetches everything up front — the
    /// same mode shape as the scalar path, so scalar and block Table 3
    /// wall/CPU accounting agree. Runs BEFORE the scratch lock is taken:
    /// concurrent blocks must only serialize on the embedded pass, never on
    /// the (ms-scale) simulated fetch.
    fn fetch_stage1(&self, n: usize) {
        if let Some(f) = &self.fetch {
            match self.mode {
                Mode::AlwaysRpc => f.fetch(n * self.tables.n_features),
                _ => f.fetch(n * self.tables.n_infer()),
            }
        }
    }

    /// Scratch contents are cleared before every use, so a poisoned lock
    /// (a panicking request) must not take serving down — recover it.
    fn lock_scratch(&self) -> MutexGuard<'_, CoordScratch> {
        self.scratch.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Stage-1 + gather under the scratch lock, then RELEASE it before
    /// launching the fallback RPC, so concurrent batched requests only
    /// serialize on the (cheap) embedded pass, never on the network.
    /// `src_rows`, when available (the row-major `predict_batch` input),
    /// avoids re-gathering missed rows out of the columnar block with
    /// strided reads.
    fn serve_block_async<'a>(
        &'a self,
        block: &RowBlock,
        src_rows: Option<&[Vec<f32>]>,
        mut guard: MutexGuard<'_, CoordScratch>,
        t0: Instant,
        cpu: CpuTimer,
        opts: &PredictOptions,
    ) -> std::io::Result<BlockPending<'a>> {
        debug_assert!(block.is_empty() || block.n_features() == self.tables.n_features);
        let n = block.n_rows();

        // One batched stage-1 pass over the whole block (also routing).
        // `t0`/`cpu` started in the caller, before the (lock-free) stage-1
        // feature fetch, so the fetch cost is in every row's accounting.
        let (mut out, miss_idx, miss_rows) = {
            let s = &mut *guard;
            self.tables
                .evaluate_block(block, &mut s.tab, &mut s.probs, &mut s.routed);
            let mut out: Vec<(f32, Served)> = Vec::with_capacity(n);
            s.miss_idx.clear();
            s.miss_rows.clear();
            for (i, (&p1, &routed)) in s.probs.iter().zip(&s.routed).enumerate() {
                let use_stage1 = match self.mode {
                    Mode::Multistage => routed,
                    Mode::AlwaysRpc => false,
                    Mode::AlwaysStage1 => true,
                };
                if use_stage1 {
                    out.push((p1, Served::Stage1));
                } else {
                    s.miss_idx.push(i);
                    // Placeholder carries the stage-1 prior so a degraded
                    // join can keep it without re-running stage 1.
                    out.push((p1, Served::Rpc));
                }
            }
            if s.miss_idx.is_empty() {
                // Leave the scratch buffers in place for the next request.
                (out, Vec::new(), Vec::new())
            } else {
                // Gather all missed rows into ONE padded, coalesced RPC
                // buffer.
                s.miss_rows.reserve(s.miss_idx.len() * self.rpc_row_len);
                match src_rows {
                    Some(rows) => {
                        for &i in &s.miss_idx {
                            self.pad_for_rpc(&rows[i], &mut s.miss_rows);
                        }
                    }
                    None => {
                        for &i in &s.miss_idx {
                            block.row_into(i, &mut s.row);
                            self.pad_for_rpc(&s.row, &mut s.miss_rows);
                        }
                    }
                }
                (
                    out,
                    std::mem::take(&mut s.miss_idx),
                    std::mem::take(&mut s.miss_rows),
                )
            }
        };
        drop(guard);

        // Stage-1 results are available from this instant: that IS the hit
        // rows' latency (not an n-th share of the final wall clock).
        let stage1_wall = t0.elapsed().as_nanos() as u64;
        let stage1_cpu_total = cpu.elapsed_ns();
        let stage1_cpu_per_row = stage1_cpu_total / n.max(1) as u64;
        for _ in 0..n - miss_idx.len() {
            self.metrics
                .hit_stage1(stage1_wall, stage1_cpu_per_row, self.tables.n_infer() as u64);
            self.metrics.e2e.record(stage1_wall);
        }
        if n > 0 {
            self.metrics.block_stage1_complete.record(stage1_wall);
        }

        // Guarded rollout, shadow monitor: a sampled batch compares every
        // row's stage-1 decision against the candidate tables inline (cost
        // bounded by the sampling rate); its route-missed rows shadow-score
        // on the candidate's second stage once their live scores land —
        // right below for the embedded fallback, at the join for RPC.
        let shadow = self.rollout_shadow_sample();
        if let Some(ro) = &shadow {
            let mut row = Vec::new();
            for i in 0..n {
                block.row_into(i, &mut row);
                ro.compare_stage1_row(&self.tables, &row, &self.metrics);
            }
        }

        // Misses: fetch the features the stage-1 attempt did not cover
        // (AlwaysRpc already fetched everything), then hand them to the
        // second stage — launched without waiting for the RPC fallback,
        // scored in-process for the embedded (multi-tenant pool) fallback.
        let rpc = if miss_idx.is_empty() {
            None
        } else if self.browned_out(opts) {
            // Brownout rung: shed the whole coalesced second-stage call
            // preemptively — every missed row keeps its stage-1 prior
            // (already in the placeholder), marked and counted degraded.
            // No remaining-feature fetch, no RPC launch: browning out must
            // COST less than serving, or the ladder doesn't shed load.
            let wall = t0.elapsed().as_nanos() as u64;
            for &i in &miss_idx {
                out[i].1 = Served::Degraded;
                self.metrics.e2e.record(wall);
            }
            self.metrics
                .degraded_rows
                .fetch_add(miss_idx.len() as u64, Ordering::Relaxed);
            self.metrics.degraded_requests.fetch_add(1, Ordering::Relaxed);
            return Ok(BlockPending {
                coord: self,
                out,
                miss_idx,
                miss_rows,
                rpc: None,
                t0,
                miss_cpu_base: 0,
                span_walls: Vec::new(),
                delivered: Vec::new(),
                shadow: None,
            });
        } else {
            if self.mode != Mode::AlwaysRpc {
                if let Some(f) = &self.fetch {
                    let rest = self.tables.n_features.saturating_sub(self.tables.n_infer());
                    f.fetch(miss_idx.len() * rest);
                }
            }
            let launched: std::io::Result<Option<PendingPredict<'_>>> = match &self.fallback {
                None => Err(no_second_stage()),
                Some(SecondStage::Rpc(client)) => {
                    let mut waited = Duration::ZERO;
                    loop {
                        match client.predict_async_opts(&miss_rows, self.rpc_row_len, opts) {
                            Ok(p) => break Ok(Some(p)),
                            Err(e)
                                if self.degrade == DegradeMode::Block
                                    && is_breaker_open(&e)
                                    && self.block_on_breaker(opts, &mut waited) => {}
                            Err(e) => break Err(e),
                        }
                    }
                }
                Some(SecondStage::Embedded { pool, model }) => {
                    // In-process second stage: complete the misses right
                    // here (no wire to overlap) and account them exactly
                    // as `BlockPending::wait` would — with zero bytes.
                    let k = miss_idx.len();
                    let mut probs = vec![0f32; k];
                    match pool.predict(*model, &miss_rows, self.rpc_row_len, &mut probs) {
                        Err(e) => Err(std::io::Error::other(e)),
                        Ok(()) => {
                            for (j, &i) in miss_idx.iter().enumerate() {
                                out[i].0 = probs[j];
                            }
                            let wall = t0.elapsed().as_nanos() as u64;
                            let cpu_share = stage1_cpu_per_row
                                + cpu.elapsed_ns().saturating_sub(stage1_cpu_total) / k as u64;
                            // miss_wire_bytes is 0 for the embedded stage.
                            self.record_miss_completion(k, wall, cpu_share, self.miss_wire_bytes(k));
                            if let Some(ro) = &shadow {
                                let live: Vec<f32> =
                                    miss_idx.iter().map(|&i| out[i].0).collect();
                                Rollout::shadow_score_misses(
                                    ro,
                                    &miss_rows,
                                    self.rpc_row_len,
                                    live,
                                    wall,
                                    &self.metrics,
                                );
                            }
                            Ok(None)
                        }
                    }
                }
            };
            match launched {
                Ok(pending) => pending,
                Err(e) => {
                    self.sync_rpc_failure_counters();
                    if self.degrade == DegradeMode::Stage1Prior {
                        // Second stage unreachable (breaker open, deadline
                        // spent, dead connection): every missed row keeps
                        // its stage-1 prior, explicitly marked degraded.
                        use std::sync::atomic::Ordering;
                        let wall = t0.elapsed().as_nanos() as u64;
                        for &i in &miss_idx {
                            out[i].1 = Served::Degraded;
                            self.metrics.e2e.record(wall);
                        }
                        self.metrics
                            .degraded_rows
                            .fetch_add(miss_idx.len() as u64, Ordering::Relaxed);
                        self.metrics.degraded_requests.fetch_add(1, Ordering::Relaxed);
                        return Ok(BlockPending {
                            coord: self,
                            out,
                            miss_idx,
                            miss_rows,
                            rpc: None,
                            t0,
                            miss_cpu_base: 0,
                            span_walls: Vec::new(),
                            delivered: Vec::new(),
                            shadow: None,
                        });
                    }
                    // Hand the gather buffers back before surfacing.
                    let mut g = self.lock_scratch();
                    g.miss_idx = miss_idx;
                    g.miss_rows = miss_rows;
                    return Err(e);
                }
            }
        };
        // CPU spent after the stage-1 snapshot (the remaining-feature fetch
        // and the RPC launch) belongs to the missed rows, like the scalar
        // path's single CPU clock would attribute it.
        let miss_cpu_base = if miss_idx.is_empty() {
            0
        } else {
            stage1_cpu_per_row
                + (cpu.elapsed_ns().saturating_sub(stage1_cpu_total)) / miss_idx.len() as u64
        };
        let delivered = vec![false; miss_idx.len()];
        Ok(BlockPending {
            coord: self,
            out,
            miss_idx,
            miss_rows,
            rpc,
            t0,
            miss_cpu_base,
            span_walls: Vec::new(),
            delivered,
            shadow,
        })
    }
}

fn no_second_stage() -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::NotConnected,
        "no second-stage backend configured",
    )
}

/// An in-flight block request: stage-1 results are already available (and
/// recorded) while the coalesced miss RPC — if any — is still on the wire.
///
/// Dropping a `BlockPending` abandons the RPC (the client discards the late
/// response) and recycles the gather buffers.
pub struct BlockPending<'a> {
    coord: &'a Coordinator,
    /// Per-row results; missed rows hold a placeholder until `wait` (or
    /// their span's [`BlockPending::poll_fallback`] delivery, whichever
    /// comes first).
    out: Vec<(f32, Served)>,
    miss_idx: Vec<usize>,
    miss_rows: Vec<f32>,
    rpc: Option<PendingPredict<'a>>,
    t0: Instant,
    /// Per-miss CPU share accrued before the RPC wait.
    miss_cpu_base: u64,
    /// Streamed-span completions drained so far: `(miss-order start, len,
    /// wall ns since t0)` — the per-row walls `wait` books.
    span_walls: Vec<(usize, usize, u64)>,
    /// Per-miss (miss-order) delivery flags: true once a streamed span
    /// actually wrote the row's second-stage probability — the rows a
    /// degraded join keeps as `Served::Rpc` instead of falling back.
    delivered: Vec<bool>,
    /// Guarded-rollout shadow monitor for this (sampled) batch, consumed
    /// at the join once the misses' live scores are known.
    shadow: Option<Arc<Rollout>>,
}

impl BlockPending<'_> {
    pub fn n_rows(&self) -> usize {
        self.out.len()
    }

    pub fn n_misses(&self) -> usize {
        self.miss_idx.len()
    }

    pub fn n_hits(&self) -> usize {
        self.out.len() - self.miss_idx.len()
    }

    /// True while the coalesced fallback RPC has not been joined.
    pub fn rpc_in_flight(&self) -> bool {
        self.rpc.is_some()
    }

    /// Rows already served by the embedded stage 1, as `(row_index, prob)`
    /// — readable immediately, while the miss RPC is in flight.
    pub fn stage1_hits(&self) -> impl Iterator<Item = (usize, f32)> + '_ {
        self.out
            .iter()
            .enumerate()
            .filter(|(_, (_, served))| *served == Served::Stage1)
            .map(|(i, (p, _))| (i, *p))
    }

    /// Drain — without blocking — any fallback sub-spans the streamed miss
    /// RPC has delivered so far: each newly completed row is written into
    /// the pending results and returned as `(block_row_index, prob)`, so
    /// callers consume early fallback rows while later spans are still on
    /// the wire. Empty when nothing new arrived, the fallback is embedded
    /// or monolithic, or there were no misses. A failed span is recorded
    /// (telemetry) but surfaces as the block's error at
    /// [`BlockPending::wait`], exactly like the monolithic path.
    pub fn poll_fallback(&mut self) -> Vec<(usize, f32)> {
        let Some(rpc) = self.rpc.as_mut() else {
            return Vec::new();
        };
        let mut ready = Vec::new();
        for s in rpc.poll_spans() {
            let wall = s.arrived.saturating_duration_since(self.t0).as_nanos() as u64;
            self.coord
                .metrics
                .stream_chunks
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.coord.metrics.block_span_complete.record(wall);
            self.span_walls.push((s.span.start, s.span.len(), wall));
            if s.failed {
                continue;
            }
            for (k, &p) in s.probs.iter().enumerate() {
                let i = self.miss_idx[s.span.start + k];
                self.out[i].0 = p;
                self.delivered[s.span.start + k] = true;
                ready.push((i, p));
            }
        }
        ready
    }

    /// Join the fallback RPC and return the complete per-row results,
    /// bit-identical to [`Coordinator::predict_block`]. Missed rows are
    /// accounted here: each row's latency runs from block arrival to the
    /// arrival of the frame that delivered IT — its chunk when the server
    /// streamed, the response otherwise (the scalar path's semantics, never
    /// an amortized share of one wall clock) — and the coalesced traffic's
    /// ACTUAL wire bytes (request + response frames, chunked or not) are
    /// split across the k rows.
    pub fn wait(mut self) -> std::io::Result<Vec<(f32, Served)>> {
        if let Some(rpc) = self.rpc.take() {
            let cpu = CpuTimer::start();
            let k = self.miss_idx.len();
            // Frame ARRIVAL instants are the miss rows' completion times: a
            // pipelined caller joins late, and that slack is the overlap
            // win — it must not be booked back into miss latency.
            let outcome = match rpc.wait_outcome() {
                Ok(o) => o,
                Err(e) => return self.degraded_join(e, cpu),
            };
            debug_assert_eq!(outcome.probs.len(), k);
            if outcome.retried {
                // Spans polled off the aborted first attempt belong to a
                // dead stream: the delivered probabilities are the fresh
                // attempt's, so only ITS span arrivals (below) may shape
                // the per-row walls.
                self.span_walls.clear();
            }
            // Spans that streamed in during the join (not drained earlier
            // by poll_fallback).
            for (span, at, _failed) in &outcome.spans {
                let wall = at.saturating_duration_since(self.t0).as_nanos() as u64;
                self.coord
                    .metrics
                    .stream_chunks
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.coord.metrics.block_span_complete.record(wall);
                self.span_walls.push((span.start, span.len(), wall));
            }
            let final_wall = outcome
                .arrived
                .saturating_duration_since(self.t0)
                .as_nanos() as u64;
            for (j, &i) in self.miss_idx.iter().enumerate() {
                self.out[i].0 = outcome.probs[j];
            }
            // Per-row walls: streamed rows completed at their span's
            // arrival; anything else (monolithic) at the terminal frame's.
            let mut walls = vec![final_wall; k];
            for &(start, len, wall) in &self.span_walls {
                walls[start..start + len].fill(wall);
            }
            let cpu_share = self.miss_cpu_base + cpu.elapsed_ns() / k as u64;
            self.coord
                .record_miss_rows(&walls, cpu_share, outcome.req_bytes + outcome.resp_bytes);
            // Guarded rollout: a sampled batch's misses shadow-score on the
            // candidate now that their live scores are known.
            if let Some(ro) = self.shadow.take() {
                let live: Vec<f32> = self.miss_idx.iter().map(|&i| self.out[i].0).collect();
                Rollout::shadow_score_misses(
                    &ro,
                    &self.miss_rows,
                    self.coord.rpc_row_len,
                    live,
                    final_wall,
                    &self.coord.metrics,
                );
            }
        }
        Ok(std::mem::take(&mut self.out))
    }

    /// The block's RPC join failed. Under [`DegradeMode::Stage1Prior`] the
    /// block still completes: rows a streamed span already delivered keep
    /// their real second-stage probability (accounted as `Served::Rpc`,
    /// zero extra wire bytes — the coalesced traffic never finished, so no
    /// byte total exists to split); the rest answer with their stage-1
    /// prior as [`Served::Degraded`]. Every other mode surfaces the error.
    fn degraded_join(
        mut self,
        e: std::io::Error,
        cpu: CpuTimer,
    ) -> std::io::Result<Vec<(f32, Served)>> {
        use std::sync::atomic::Ordering;
        let coord = self.coord;
        coord.sync_rpc_failure_counters();
        if coord.degrade != DegradeMode::Stage1Prior {
            return Err(e);
        }
        let k = self.miss_idx.len();
        let wall = self.t0.elapsed().as_nanos() as u64;
        let cpu_share = self.miss_cpu_base + cpu.elapsed_ns() / k.max(1) as u64;
        // Per-miss walls for delivered rows: their span's arrival.
        let mut walls = vec![wall; k];
        for &(start, len, w) in &self.span_walls {
            walls[start..start + len].fill(w);
        }
        let mut degraded = 0u64;
        for (j, &i) in self.miss_idx.iter().enumerate() {
            if self.delivered[j] {
                coord.metrics.hit_rpc(
                    walls[j],
                    cpu_share,
                    coord.tables.n_features as u64,
                    0,
                );
                coord.metrics.e2e.record(walls[j]);
            } else {
                self.out[i].1 = Served::Degraded;
                coord.metrics.e2e.record(wall);
                degraded += 1;
            }
        }
        coord.metrics.degraded_rows.fetch_add(degraded, Ordering::Relaxed);
        if degraded > 0 {
            coord.metrics.degraded_requests.fetch_add(1, Ordering::Relaxed);
        }
        Ok(std::mem::take(&mut self.out))
    }
}

/// Adaptive-depth block pipeline (ROADMAP "adaptive pipeline depth"): keeps
/// up to [`ServeMetrics::suggested_pipeline_depth`] blocks in flight —
/// re-evaluated live per submission from the stage1-done/rpc-done
/// completion gap — instead of a hardwired depth. With a fast (or embedded)
/// fallback the window collapses to 1 and the pipeline degenerates to the
/// synchronous path; with a slow network hop it widens to 4.
///
/// [`ServeMetrics::suggested_pipeline_depth`]:
/// crate::telemetry::ServeMetrics::suggested_pipeline_depth
pub struct BlockPipeline<'a> {
    coord: &'a Coordinator,
    pending: std::collections::VecDeque<BlockPending<'a>>,
}

impl<'a> BlockPipeline<'a> {
    pub fn new(coord: &'a Coordinator) -> BlockPipeline<'a> {
        BlockPipeline {
            coord,
            pending: std::collections::VecDeque::new(),
        }
    }

    /// The overlap window currently in force (live, metrics-driven).
    pub fn depth(&self) -> usize {
        self.coord.metrics.suggested_pipeline_depth()
    }

    /// Blocks currently in flight.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Submit one block; returns the results of any blocks that fell out of
    /// the live overlap window (possibly none), oldest first, each
    /// bit-identical to its synchronous [`Coordinator::predict_block`].
    pub fn submit(&mut self, block: &RowBlock) -> std::io::Result<Vec<Vec<(f32, Served)>>> {
        self.pending.push_back(self.coord.predict_block_async(block)?);
        let mut done = Vec::new();
        while self.pending.len() > self.depth() {
            done.push(self.pending.pop_front().expect("non-empty").wait()?);
        }
        Ok(done)
    }

    /// Join every block still in flight, oldest first.
    pub fn finish(mut self) -> std::io::Result<Vec<Vec<(f32, Served)>>> {
        let mut done = Vec::new();
        while let Some(p) = self.pending.pop_front() {
            done.push(p.wait()?);
        }
        Ok(done)
    }
}

impl Drop for BlockPending<'_> {
    /// Recycle the gather buffers (best effort — under contention another
    /// request may already have fresh ones).
    fn drop(&mut self) {
        if self.miss_idx.capacity() == 0 && self.miss_rows.capacity() == 0 {
            return;
        }
        self.miss_idx.clear();
        self.miss_rows.clear();
        let mut g = self.coord.lock_scratch();
        g.miss_idx = std::mem::take(&mut self.miss_idx);
        g.miss_rows = std::mem::take(&mut self.miss_rows);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::features::{rank_features, RankMethod};
    use crate::lrwbins::{LrwBinsModel, LrwBinsParams};
    use crate::rpc::netsim::{NetSim, NetSimConfig};
    use crate::rpc::server::{BatcherConfig, NativeBackend, RpcServer};

    fn setup_with_netsim(netsim: NetSimConfig) -> (crate::tabular::Dataset, Coordinator, RpcServer) {
        let spec = datagen::preset("aci").unwrap().with_rows(4000);
        let data = datagen::generate(&spec, 5);
        let ranking = rank_features(&data, RankMethod::GbdtGain, 1);
        let mut first = LrwBinsModel::train(
            &data,
            &ranking.order,
            &LrwBinsParams {
                b: 2,
                n_bin_features: 3,
                n_infer_features: 6,
                ..Default::default()
            },
        );
        // Route half the bins.
        let route: std::collections::HashSet<u32> =
            first.weights.keys().copied().filter(|b| b % 2 == 0).collect();
        first.set_route(route);
        let second = crate::gbdt::train(&data, &crate::gbdt::GbdtParams::quick());

        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(NativeBackend::new(second)),
            Arc::new(NetSim::new(netsim, 1)),
            BatcherConfig::default(),
            metrics.clone(),
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();
        let tables = ServingTables::from_model(&first);
        let coord = Coordinator::new(tables, Some(client), 0, metrics);
        (data, coord, server)
    }

    fn setup() -> (crate::tabular::Dataset, Coordinator, RpcServer) {
        setup_with_netsim(NetSimConfig::off())
    }

    /// Like `setup`, but the server's shard pool splits at 8-row tasks so
    /// block-sized miss RPCs really stream in several chunks.
    fn setup_streaming() -> (crate::tabular::Dataset, Coordinator, RpcServer) {
        let spec = datagen::preset("aci").unwrap().with_rows(4000);
        let data = datagen::generate(&spec, 5);
        let ranking = rank_features(&data, RankMethod::GbdtGain, 1);
        let mut first = LrwBinsModel::train(
            &data,
            &ranking.order,
            &LrwBinsParams {
                b: 2,
                n_bin_features: 3,
                n_infer_features: 6,
                ..Default::default()
            },
        );
        let route: std::collections::HashSet<u32> =
            first.weights.keys().copied().filter(|b| b % 2 == 0).collect();
        first.set_route(route);
        let second = crate::gbdt::train(&data, &crate::gbdt::GbdtParams::quick());
        let pool = Arc::new(ShardPool::with_config(crate::runtime::ShardPoolConfig {
            n_shards: 4,
            min_task_rows: 8,
            ..Default::default()
        }));
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(crate::rpc::server::NativeBackend::with_pool(second, pool)),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig::default(),
            metrics.clone(),
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();
        let coord = Coordinator::new(ServingTables::from_model(&first), Some(client), 0, metrics);
        (data, coord, server)
    }

    /// A deterministic "datacenter hop": every injected delay is exactly
    /// `ms` milliseconds (sigma 0 ⇒ the lognormal collapses to its base).
    fn fixed_hop_ms(ms: u64) -> NetSimConfig {
        NetSimConfig {
            base_us: ms as f64 * 1000.0,
            sigma: 0.0,
            max_us: ms as f64 * 2000.0,
        }
    }

    #[test]
    fn multistage_conservation_every_row_answered() {
        let (data, coord, _server) = setup();
        let mut s1 = 0;
        let mut rpc = 0;
        let mut row = Vec::new();
        for r in 0..500 {
            data.row_into(r, &mut row);
            let (p, served) = coord.predict(&row).unwrap();
            assert!((0.0..=1.0).contains(&p), "p={p}");
            match served {
                Served::Stage1 => s1 += 1,
                Served::Rpc => rpc += 1,
                Served::Degraded => panic!("healthy backend must not degrade"),
            }
        }
        assert_eq!(s1 + rpc, 500);
        assert!(s1 > 0, "some rows must be stage-1");
        assert!(rpc > 0, "some rows must fall back");
        assert!((coord.metrics.coverage() - s1 as f64 / 500.0).abs() < 1e-9);
    }

    #[test]
    fn batch_matches_single_row_path() {
        let (data, coord, _server) = setup();
        let rows: Vec<Vec<f32>> = (0..64).map(|r| data.row(r)).collect();
        let batch = coord.predict_batch(&rows).unwrap();
        for (i, row) in rows.iter().enumerate() {
            let (p, served) = coord.predict(row).unwrap();
            assert_eq!(batch[i].1, served, "row {i}");
            assert!((batch[i].0 - p).abs() < 1e-6, "row {i}");
        }
    }

    #[test]
    fn block_matches_batch_and_reuses_scratch() {
        let (data, coord, _server) = setup();
        let rows: Vec<Vec<f32>> = (0..96).map(|r| data.row(r)).collect();
        let batch = coord.predict_batch(&rows).unwrap();
        let mut block = crate::tabular::RowBlock::new();
        // Run the block path twice over varying sizes to exercise scratch
        // reuse (shrinking and growing between requests).
        for take in [96usize, 17, 96] {
            block.fill_from_rows(&rows[..take]);
            let via_block = coord.predict_block(&block).unwrap();
            assert_eq!(via_block.len(), take);
            for i in 0..take {
                assert_eq!(via_block[i].1, batch[i].1, "take {take} row {i}");
                // Stage-1 probabilities are bit-identical; RPC responses go
                // through f32 wire serialization and are exact as well.
                assert_eq!(
                    via_block[i].0.to_bits(),
                    batch[i].0.to_bits(),
                    "take {take} row {i}"
                );
            }
        }
    }

    #[test]
    fn async_delivers_hits_while_rpc_in_flight() {
        // One simulated hop = 50ms, so the fallback RPC cannot complete in
        // under ~100ms — yet stage-1 hits must be readable immediately.
        let (data, coord, _server) = setup_with_netsim(fixed_hop_ms(50));
        let rows: Vec<Vec<f32>> = (0..64).map(|r| data.row(r)).collect();
        let block = crate::tabular::RowBlock::from_rows(&rows);

        let t0 = Instant::now();
        let pending = coord.predict_block_async(&block).unwrap();
        let issued = t0.elapsed();
        assert!(pending.n_hits() > 0, "block must contain stage-1 hits");
        assert!(pending.n_misses() > 0, "block must contain misses");
        assert!(pending.rpc_in_flight());
        let hits: Vec<(usize, f32)> = pending.stage1_hits().collect();
        assert_eq!(hits.len(), pending.n_hits());
        assert!(
            issued < Duration::from_millis(45),
            "stage-1 results must not wait on the RPC (issued in {issued:?})"
        );

        let full = pending.wait().unwrap();
        assert!(
            t0.elapsed() >= Duration::from_millis(90),
            "the miss RPC really was delayed by the simulated network"
        );
        for (i, p) in hits {
            assert_eq!(full[i].1, Served::Stage1);
            assert_eq!(full[i].0.to_bits(), p.to_bits(), "row {i}");
        }
        // The async path stays bit-identical to the synchronous wrapper.
        let sync = coord.predict_block(&block).unwrap();
        for i in 0..rows.len() {
            assert_eq!(full[i].1, sync[i].1, "row {i}");
            assert_eq!(full[i].0.to_bits(), sync[i].0.to_bits(), "row {i}");
        }
        // Completion timestamps were recorded per stage: the stage-1 pass
        // finished microseconds in; the RPC ~100ms later.
        assert!(coord.metrics.block_stage1_complete.count() >= 2);
        assert!(coord.metrics.block_rpc_complete.mean_ns() > 80e6);
        assert!(
            coord.metrics.block_stage1_complete.mean_ns()
                < coord.metrics.block_rpc_complete.mean_ns() / 10.0
        );
    }

    #[test]
    fn consecutive_blocks_overlap_their_rpcs() {
        let (data, coord, _server) = setup_with_netsim(fixed_hop_ms(50));
        let rows: Vec<Vec<f32>> = (0..128).map(|r| data.row(r)).collect();
        let block_a = crate::tabular::RowBlock::from_rows(&rows[..64]);
        let block_b = crate::tabular::RowBlock::from_rows(&rows[64..]);

        let t0 = Instant::now();
        let pa = coord.predict_block_async(&block_a).unwrap();
        // Issuing B must not block on A's outstanding RPC (~100ms).
        let pb = coord.predict_block_async(&block_b).unwrap();
        let both_issued = t0.elapsed();
        assert!(
            both_issued < Duration::from_millis(45),
            "second block's stage-1 pass must overlap the first block's RPC \
             (issued both in {both_issued:?})"
        );
        let ra = pa.wait().unwrap();
        let rb = pb.wait().unwrap();
        let total = t0.elapsed();
        // Serialized, the two ~100ms RPCs would take ≥200ms; pipelined they
        // overlap. Leave a wide margin for scheduler noise.
        assert!(
            total < Duration::from_millis(180),
            "overlapped blocks must beat back-to-back RPCs (took {total:?})"
        );
        assert_eq!(ra.len() + rb.len(), 128);
        for (p, _) in ra.iter().chain(&rb) {
            assert!((0.0..=1.0).contains(p), "p={p}");
        }
    }

    #[test]
    fn fetch_sim_applies_on_block_path_matching_scalar_accounting() {
        let (data, mut coord, _server) = setup();
        let fetch = FetchSim { per_feature_us: 3.0 };
        coord.fetch = Some(fetch);
        let n = 96usize;
        let rows: Vec<Vec<f32>> = (0..n).map(|r| data.row(r)).collect();

        coord.metrics.reset_all();
        for r in &rows {
            coord.predict(r).unwrap();
        }
        let load = |c: &std::sync::atomic::AtomicU64| c.load(std::sync::atomic::Ordering::Relaxed);
        let scalar_hits = load(&coord.metrics.stage1_hits);
        let scalar_rpc = load(&coord.metrics.rpc_calls);
        let scalar_feats = load(&coord.metrics.features_fetched);
        let scalar_s1_cpu = load(&coord.metrics.stage1_cpu_ns);
        let scalar_rpc_cpu = load(&coord.metrics.rpc_cpu_ns);

        coord.metrics.reset_all();
        let block = crate::tabular::RowBlock::from_rows(&rows);
        let res = coord.predict_block(&block).unwrap();
        assert_eq!(res.len(), n);

        // Identical routing ⇒ identical per-row fetch accounting.
        assert_eq!(load(&coord.metrics.stage1_hits), scalar_hits);
        assert_eq!(load(&coord.metrics.rpc_calls), scalar_rpc);
        assert_eq!(load(&coord.metrics.features_fetched), scalar_feats);

        // The busy-wait fetch burns real CPU on BOTH paths. Each stage's
        // CPU must at least cover the simulated cost it owes (generous 50%
        // slack for descheduling under CI load):
        //   stage-1: every row fetches the top-n subset, booked to hits;
        //   misses:  the full feature set, booked to the RPC stage.
        let s1_floor = fetch.duration(scalar_hits as usize * coord.tables.n_infer());
        let rpc_floor = fetch.duration(scalar_rpc as usize * coord.tables.n_features);
        for (label, cpu_ns, floor) in [
            ("scalar stage1", scalar_s1_cpu, s1_floor),
            ("scalar rpc", scalar_rpc_cpu, rpc_floor),
            ("block stage1", load(&coord.metrics.stage1_cpu_ns), s1_floor),
            ("block rpc", load(&coord.metrics.rpc_cpu_ns), rpc_floor),
        ] {
            assert!(
                cpu_ns >= floor.as_nanos() as u64 / 2,
                "{label}: cpu {cpu_ns}ns < fetch floor {floor:?}"
            );
        }
        // And the wall clocks see the cost too: the block's stage-1
        // completion cannot beat the whole-block top-n fetch.
        let block_floor = fetch.duration(n * coord.tables.n_infer()).as_nanos() as f64;
        assert!(coord.metrics.block_stage1_complete.mean_ns() >= block_floor);
    }

    #[test]
    fn coalesced_rpc_bytes_counted_once_per_frame() {
        let (data, coord, _server) = setup();
        let rows: Vec<Vec<f32>> = (0..64).map(|r| data.row(r)).collect();
        let block = crate::tabular::RowBlock::from_rows(&rows);
        coord.metrics.reset_all();
        let res = coord.predict_block(&block).unwrap();
        let k = res.iter().filter(|(_, s)| *s == Served::Rpc).count();
        assert!(k > 1, "need several misses to observe coalescing");
        // rpc_row_len == n_features for the native backend (setup passes 0).
        let row_len = coord.tables.n_features;
        let expected = RpcClient::wire_bytes(k, row_len);
        assert_eq!(
            coord
                .metrics
                .rpc_bytes
                .load(std::sync::atomic::Ordering::Relaxed),
            expected,
            "block bytes must be ONE coalesced frame of {k} rows"
        );
        // Strictly less than k single-row frames (k-1 saved frame headers).
        assert!(expected < k as u64 * RpcClient::wire_bytes(1, row_len));
    }

    #[test]
    fn fetch_sim_keeps_fractional_nanoseconds() {
        // 0.0005µs = 0.5ns per feature: the per-feature cost truncates to 0,
        // but the total over 1000 features is a real 500ns.
        let f = FetchSim { per_feature_us: 0.0005 };
        assert_eq!(f.duration(1000), Duration::from_nanos(500));
        assert_eq!(f.duration(0), Duration::ZERO);
        // Whole-ns per-feature costs are unchanged by the f64 total.
        let g = FetchSim { per_feature_us: 2.0 };
        assert_eq!(g.duration(3), Duration::from_nanos(6000));
    }

    /// Tentpole acceptance, coordinator level: the streamed fallback's rows
    /// are consumable span by span through `poll_fallback`, and the joined
    /// block stays bit-identical to the synchronous path.
    #[test]
    fn streamed_fallback_polls_spans_and_stays_bit_identical() {
        let (data, mut coord, _server) = setup_streaming();
        // Every row misses: the coalesced RPC carries the whole block, big
        // enough for the server's 8-row-task pool to chunk it.
        coord.mode = Mode::AlwaysRpc;
        let rows: Vec<Vec<f32>> = (0..256).map(|r| data.row(r)).collect();
        let block = crate::tabular::RowBlock::from_rows(&rows);
        let sync: Vec<(u32, Served)> = coord
            .predict_block(&block)
            .unwrap()
            .into_iter()
            .map(|(p, s)| (p.to_bits(), s))
            .collect();

        coord.metrics.reset_all();
        let mut pending = coord.predict_block_async(&block).unwrap();
        assert_eq!(pending.n_misses(), 256);
        let t0 = Instant::now();
        let mut polled: Vec<(usize, f32)> = Vec::new();
        while polled.len() < 256 {
            let before = polled.len();
            polled.extend(pending.poll_fallback());
            assert!(t0.elapsed() < Duration::from_secs(10), "stream stalled");
            if polled.len() == before {
                std::thread::sleep(Duration::from_micros(100));
            }
        }
        // Every row arrived exactly once through the polls...
        let mut seen = vec![false; 256];
        for &(i, p) in &polled {
            assert!(!seen[i], "row {i} delivered twice");
            seen[i] = true;
            assert_eq!(p.to_bits(), sync[i].0, "row {i}: polled != sync");
        }
        // ...and the join returns the identical complete block.
        let full = pending.wait().unwrap();
        for i in 0..256 {
            assert_eq!(full[i].0.to_bits(), sync[i].0, "row {i}");
            assert_eq!(full[i].1, sync[i].1, "row {i}");
        }
        // Telemetry saw the chunks: several spans, recorded per arrival.
        let chunks = coord
            .metrics
            .stream_chunks
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(chunks >= 2, "expected a chunked stream, saw {chunks}");
        assert_eq!(coord.metrics.block_span_complete.count(), chunks);
    }

    #[test]
    fn block_pipeline_adapts_depth_and_stays_bit_identical() {
        let (data, coord, _server) = setup_with_netsim(fixed_hop_ms(20));
        let blocks: Vec<crate::tabular::RowBlock> = (0..8)
            .map(|b| {
                let rows: Vec<Vec<f32>> =
                    (b * 32..b * 32 + 64).map(|r| data.row(r)).collect();
                crate::tabular::RowBlock::from_rows(&rows)
            })
            .collect();
        // Sync references (also the reason fresh metrics aren't empty when
        // the pipeline starts — depth adapts from live history).
        coord.metrics.reset_all();
        let mut pipe = BlockPipeline::new(&coord);
        assert_eq!(pipe.depth(), 1, "no completion history yet: depth 1");
        let sync: Vec<Vec<(u32, Served)>> = blocks
            .iter()
            .map(|b| {
                coord
                    .predict_block(b)
                    .unwrap()
                    .into_iter()
                    .map(|(p, s)| (p.to_bits(), s))
                    .collect()
            })
            .collect();
        // A 20ms hop each way dwarfs the stage-1 pass: the live gap must
        // open the window wide.
        assert_eq!(pipe.depth(), 4, "40ms RPCs over µs stage-1 saturate the cap");

        let mut results = Vec::new();
        let mut max_in_flight = 0;
        for b in &blocks {
            results.extend(pipe.submit(b).unwrap());
            max_in_flight = max_in_flight.max(pipe.in_flight());
        }
        results.extend(pipe.finish().unwrap());
        assert!(max_in_flight >= 2, "adaptive window never opened: {max_in_flight}");
        assert_eq!(results.len(), blocks.len());
        for (bi, (got, want)) in results.iter().zip(&sync).enumerate() {
            assert_eq!(got.len(), want.len());
            for i in 0..got.len() {
                assert_eq!(got[i].0.to_bits(), want[i].0, "block {bi} row {i}");
                assert_eq!(got[i].1, want[i].1, "block {bi} row {i}");
            }
        }
    }

    /// The stage-1 kernel tier must be invisible end to end: identical
    /// routing, identical probabilities, whatever tier the coordinator is
    /// forced onto.
    #[test]
    fn forced_dispatch_tiers_serve_bit_identically() {
        let (data, mut coord, _server) = setup();
        let rows: Vec<Vec<f32>> = (0..96).map(|r| data.row(r)).collect();
        let block = crate::tabular::RowBlock::from_rows(&rows);
        assert_eq!(
            coord.set_stage1_dispatch(Stage1Dispatch::Scalar),
            Stage1Dispatch::Scalar
        );
        let reference: Vec<(u32, Served)> = coord
            .predict_block(&block)
            .unwrap()
            .into_iter()
            .map(|(p, s)| (p.to_bits(), s))
            .collect();
        for tier in Stage1Dispatch::available_tiers() {
            assert_eq!(coord.set_stage1_dispatch(tier), tier);
            let got = coord.predict_block(&block).unwrap();
            for (i, (p, s)) in got.iter().enumerate() {
                assert_eq!(p.to_bits(), reference[i].0, "{tier:?} row {i}");
                assert_eq!(*s, reference[i].1, "{tier:?} row {i}");
            }
        }
        // Unavailable requests clamp to a tier that can actually run.
        assert!(coord.set_stage1_dispatch(Stage1Dispatch::Avx2).available());
    }

    #[test]
    fn always_rpc_mode_never_uses_stage1() {
        let (data, mut coord, _server) = setup();
        coord.mode = Mode::AlwaysRpc;
        let mut row = Vec::new();
        for r in 0..50 {
            data.row_into(r, &mut row);
            let (_, served) = coord.predict(&row).unwrap();
            assert_eq!(served, Served::Rpc);
        }
    }

    #[test]
    fn always_stage1_mode_never_calls_rpc() {
        let (data, mut coord, _server) = setup();
        coord.mode = Mode::AlwaysStage1;
        let mut row = Vec::new();
        for r in 0..50 {
            data.row_into(r, &mut row);
            let (_, served) = coord.predict(&row).unwrap();
            assert_eq!(served, Served::Stage1);
        }
        assert_eq!(
            coord
                .metrics
                .rpc_calls
                .load(std::sync::atomic::Ordering::Relaxed),
            0
        );
    }

    #[test]
    fn embedded_multi_tenant_coordinators_share_one_pool() {
        // Two tenants — distinct datasets, stage-1 tables, and second-stage
        // models — fall back to ONE shared shard pool, in-process (no RPC
        // server anywhere in this test).
        let pool = Arc::new(ShardPool::new(2));
        let mut tenants = Vec::new();
        for seed in [5u64, 11] {
            let spec = datagen::preset("aci").unwrap().with_rows(4000);
            let data = datagen::generate(&spec, seed);
            let ranking = rank_features(&data, RankMethod::GbdtGain, 1);
            let mut first = LrwBinsModel::train(
                &data,
                &ranking.order,
                &LrwBinsParams {
                    b: 2,
                    n_bin_features: 3,
                    n_infer_features: 6,
                    ..Default::default()
                },
            );
            let route: std::collections::HashSet<u32> =
                first.weights.keys().copied().filter(|b| b % 2 == 0).collect();
            first.set_route(route);
            let second = crate::gbdt::train(&data, &crate::gbdt::GbdtParams::quick());
            let id = pool.register(second.flatten());
            let coord = Coordinator::new_embedded(
                ServingTables::from_model(&first),
                pool.clone(),
                id,
                Arc::new(ServeMetrics::new()),
            );
            tenants.push((data, coord, second));
        }
        // Both tenants serve concurrently; every miss must score on the
        // tenant's OWN model, bit-identical to its scalar prediction.
        std::thread::scope(|s| {
            for (data, coord, second) in &tenants {
                s.spawn(move || {
                    let mut row = Vec::new();
                    let mut misses = 0;
                    for r in 0..300 {
                        data.row_into(r, &mut row);
                        let (p, served) = coord.predict(&row).unwrap();
                        if served == Served::Rpc {
                            misses += 1;
                            assert_eq!(
                                p.to_bits(),
                                second.predict_one(&row).to_bits(),
                                "row {r}: embedded miss must score on the tenant's model"
                            );
                        }
                    }
                    assert!(misses > 0, "tenant must exercise the shared pool");
                    // Block path rides the same embedded fallback,
                    // bit-identical to the scalar path.
                    let rows: Vec<Vec<f32>> = (0..96).map(|r| data.row(r)).collect();
                    let block = crate::tabular::RowBlock::from_rows(&rows);
                    let via_block = coord.predict_block(&block).unwrap();
                    for (i, row) in rows.iter().enumerate() {
                        let (p, served) = coord.predict(row).unwrap();
                        assert_eq!(via_block[i].1, served, "row {i}");
                        assert_eq!(via_block[i].0.to_bits(), p.to_bits(), "row {i}");
                    }
                });
            }
        });
        // The in-process second stage moves no bytes over any wire.
        for (_, coord, _) in &tenants {
            let load =
                |c: &std::sync::atomic::AtomicU64| c.load(std::sync::atomic::Ordering::Relaxed);
            assert!(load(&coord.metrics.rpc_calls) > 0);
            assert_eq!(load(&coord.metrics.rpc_bytes), 0, "embedded mode: zero network bytes");
        }
        // And both tenants' traffic really went through the one pool.
        assert!(pool.stats().spans_completed() + pool.stats().inline_runs.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }

    /// A self-contained trained stack over `n` numeric features, with half
    /// the bins routed so the coordinator really exercises the second stage.
    /// Distinct per seed, so a reload visibly changes both stages.
    fn snap_stack(
        n: usize,
        seed: u64,
    ) -> (crate::tabular::Dataset, ServingTables, crate::gbdt::GbdtModel) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut d = crate::tabular::Dataset::new(crate::tabular::Schema::numeric(n));
        for _ in 0..1500 {
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let y = (x[0] * x[1] + x[n - 1] > 0.2) as u8 as f32;
            d.push_row(&x, y);
        }
        let order: Vec<usize> = (0..n).collect();
        let mut first = LrwBinsModel::train(
            &d,
            &order,
            &LrwBinsParams {
                b: 2,
                n_bin_features: 3,
                n_infer_features: n,
                min_bin_rows: 20,
                ..Default::default()
            },
        );
        let route: std::collections::HashSet<u32> =
            first.weights.keys().copied().filter(|b| b % 2 == 0).collect();
        first.set_route(route);
        let second = crate::gbdt::train(&d, &crate::gbdt::GbdtParams::quick());
        (d, ServingTables::from_model(&first), second)
    }

    #[test]
    fn reload_swaps_both_stages_under_embedded_fallback() {
        let (data, tables_a, second_a) = snap_stack(5, 5);
        let (_, tables_b, second_b) = snap_stack(5, 11);
        let pool = Arc::new(ShardPool::new(2));
        let id = pool.register(second_a.flatten());
        let mut coord =
            Coordinator::new_embedded(tables_a, pool.clone(), id, Arc::new(ServeMetrics::new()));

        let serve = |coord: &Coordinator, model: &crate::gbdt::GbdtModel| {
            let mut row = Vec::new();
            let mut misses = 0;
            for r in 0..200 {
                data.row_into(r, &mut row);
                let (p, served) = coord.predict(&row).unwrap();
                if served == Served::Rpc {
                    misses += 1;
                    assert_eq!(
                        p.to_bits(),
                        model.predict_one(&row).to_bits(),
                        "row {r}: miss must score on the live model version"
                    );
                }
            }
            assert!(misses > 0, "stack must route some rows to the second stage");
        };
        serve(&coord, &second_a);

        // Reload from snapshot bytes — the full production path: write →
        // parse → validate → swap tables + pooled forest.
        let bytes = crate::snapshot::Snapshot::write(&tables_b, &second_b.flatten());
        let snap = Snapshot::parse(&bytes).unwrap();
        assert_eq!(coord.reload(&snap).unwrap(), 2, "register was v1, reload is v2");
        assert_eq!(coord.tables, tables_b, "stage-1 tables swapped");
        serve(&coord, &second_b);

        // The drained old version stays resolvable in the shadow window.
        let (shadow_v, _) = pool.shadow(id).expect("previous version windowed");
        assert_eq!(shadow_v, 1);

        // A feature-width change is a redeploy, not a reload: rejected, and
        // the coordinator is untouched.
        let (_, tables_w, second_w) = snap_stack(3, 7);
        let wide = crate::snapshot::Snapshot::write(&tables_w, &second_w.flatten());
        let err = coord.reload(&Snapshot::parse(&wide).unwrap()).unwrap_err();
        assert!(err.contains("features"), "err: {err}");
        assert_eq!(coord.tables, tables_b, "failed reload must not touch tables");
        assert_eq!(pool.version(id), 2, "failed reload must not bump the pool");
        serve(&coord, &second_b);

        let load = |c: &std::sync::atomic::AtomicU64| c.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(load(&coord.metrics.model_reloads), 1, "only the successful reload counts");
    }

    #[test]
    fn no_rpc_configured_errors_on_miss() {
        let (data, coord, server) = setup();
        let tables = coord.tables.clone();
        let metrics = Arc::new(ServeMetrics::new());
        drop(coord);
        drop(server);
        let lone = Coordinator::new(tables, None, 0, metrics);
        let mut row = Vec::new();
        let mut saw_error = false;
        for r in 0..200 {
            data.row_into(r, &mut row);
            match lone.predict(&row) {
                Ok((_, Served::Stage1)) => {}
                Ok((_, Served::Rpc | Served::Degraded)) => {
                    panic!("cannot serve rpc or degrade without client")
                }
                Err(_) => {
                    saw_error = true;
                    break;
                }
            }
        }
        assert!(saw_error, "expected an error on the first miss");
    }

    /// Breaker drill (the graceful-degradation contract): with the breaker
    /// forced open under `DegradeMode::Stage1Prior`, routed rows serve
    /// normally, missed rows answer with their stage-1 prior explicitly
    /// marked `Served::Degraded` (bit-identical to the embedded pass, no
    /// rpc_calls booked), the degraded counters reconcile exactly — and
    /// `force_close` restores full `Served::Rpc` service.
    #[test]
    fn forced_open_breaker_degrades_to_stage1_prior() {
        use std::sync::atomic::Ordering;
        let (data, mut coord, _server) = setup();
        coord.degrade = DegradeMode::Stage1Prior;
        coord.rpc_client().unwrap().breaker().force_open();

        // Scalar path.
        let mut row = Vec::new();
        let mut degraded = 0u64;
        for r in 0..200 {
            data.row_into(r, &mut row);
            let (p1_ref, routed) = coord.tables.evaluate(&row);
            let (p, served) = coord.predict(&row).unwrap();
            if routed {
                assert_eq!(served, Served::Stage1);
            } else {
                assert_eq!(served, Served::Degraded);
                assert_eq!(
                    p.to_bits(),
                    p1_ref.to_bits(),
                    "degraded row {r} must answer the stage-1 prior"
                );
                degraded += 1;
            }
        }
        assert!(degraded > 0, "drill needs missed rows");
        assert_eq!(coord.metrics.degraded_rows.load(Ordering::Relaxed), degraded);
        assert_eq!(
            coord.metrics.degraded_requests.load(Ordering::Relaxed),
            degraded
        );
        assert_eq!(
            coord.metrics.rpc_calls.load(Ordering::Relaxed),
            0,
            "degraded rows must never count as rpc_calls"
        );

        // Block path: hits stay Stage1, misses degrade, counters reconcile.
        let rows: Vec<Vec<f32>> = (200..328)
            .map(|r| {
                data.row_into(r, &mut row);
                row.clone()
            })
            .collect();
        let block = crate::tabular::RowBlock::from_rows(&rows);
        let out = coord.predict_block(&block).unwrap();
        assert_eq!(out.len(), rows.len());
        let block_degraded = out
            .iter()
            .filter(|(_, s)| *s == Served::Degraded)
            .count() as u64;
        assert!(block_degraded > 0, "block drill needs missed rows");
        for (i, (p, served)) in out.iter().enumerate() {
            let (p1_ref, _) = coord.tables.evaluate(&rows[i]);
            match served {
                Served::Stage1 | Served::Degraded => {
                    assert_eq!(p.to_bits(), p1_ref.to_bits())
                }
                Served::Rpc => panic!("breaker is open — no rpc service"),
            }
        }
        assert_eq!(
            coord.metrics.degraded_rows.load(Ordering::Relaxed),
            degraded + block_degraded
        );
        assert_eq!(
            coord.metrics.degraded_requests.load(Ordering::Relaxed),
            degraded + 1
        );

        // Close the drill: normal second-stage service resumes.
        coord.rpc_client().unwrap().breaker().force_close();
        let mut served_rpc = false;
        for r in 0..200 {
            data.row_into(r, &mut row);
            let (_, served) = coord.predict(&row).unwrap();
            assert_ne!(served, Served::Degraded, "breaker closed — no degradation");
            served_rpc |= served == Served::Rpc;
        }
        assert!(served_rpc, "rpc service must resume after force_close");
    }

    /// The brownout ladder: rung 1 browns out low-priority misses only,
    /// rung 2 browns out every miss (block path included), rung 0 restores
    /// full service — with exact degraded accounting, stage-1-prior bits,
    /// and no second-stage spend for browned-out work.
    #[test]
    fn brownout_ladder_degrades_low_priority_then_everyone() {
        let (data, mut coord, _server) = setup();
        coord.degrade = DegradeMode::Stage1Prior;

        // Find a route-missed row to drill with.
        let mut row = Vec::new();
        let mut miss_row = None;
        for r in 0..200 {
            data.row_into(r, &mut row);
            if !coord.tables.evaluate(&row).1 {
                miss_row = Some(row.clone());
                break;
            }
        }
        let miss_row = miss_row.expect("drill needs a route-missed row");
        let p1_bits = coord.tables.evaluate(&miss_row).0.to_bits();
        let low = PredictOptions::default().low_priority();
        let full = PredictOptions::default();

        // Rung 0: everyone gets the second stage.
        assert_eq!(coord.predict_with(&miss_row, &low).unwrap().1, Served::Rpc);
        assert_eq!(coord.predict_with(&miss_row, &full).unwrap().1, Served::Rpc);

        // Rung 1: low-priority browns out (stage-1 prior bits), full
        // priority is still served for real.
        coord.set_brownout(BROWNOUT_LOW_PRIORITY);
        let (p, served) = coord.predict_with(&miss_row, &low).unwrap();
        assert_eq!(served, Served::Degraded);
        assert_eq!(p.to_bits(), p1_bits, "brownout must answer the stage-1 prior");
        assert_eq!(coord.predict_with(&miss_row, &full).unwrap().1, Served::Rpc);

        // Rung 2 (levels past it clamp): every miss browns out, the block
        // path's coalesced RPC included.
        coord.set_brownout(99);
        assert_eq!(coord.brownout(), BROWNOUT_ALL);
        assert_eq!(
            coord.predict_with(&miss_row, &full).unwrap().1,
            Served::Degraded
        );
        let rpc_before = coord.metrics.rpc_calls.load(Ordering::Relaxed);
        let rows = vec![miss_row.clone(); 8];
        let out = coord.predict_batch(&rows).unwrap();
        assert_eq!(out.len(), 8);
        for (p, served) in &out {
            assert_eq!(*served, Served::Degraded);
            assert_eq!(p.to_bits(), p1_bits);
        }
        assert_eq!(
            coord.metrics.rpc_calls.load(Ordering::Relaxed),
            rpc_before,
            "browned-out blocks must not spend second-stage calls"
        );

        // Ladder down: full service resumes.
        coord.set_brownout(0);
        assert_eq!(coord.predict_with(&miss_row, &low).unwrap().1, Served::Rpc);

        // Degraded accounting reconciles exactly: 1 (rung-1 low) +
        // 1 (rung-2 scalar) + 8 (rung-2 block) rows over 3 requests.
        assert_eq!(coord.metrics.degraded_rows.load(Ordering::Relaxed), 10);
        assert_eq!(coord.metrics.degraded_requests.load(Ordering::Relaxed), 3);
    }

    /// Brownout is scoped to `DegradeMode::Stage1Prior`: a coordinator
    /// that promised errors (`Fail`) must not silently degrade, whatever
    /// rung a confused controller sets.
    #[test]
    fn brownout_without_stage1prior_never_degrades() {
        let (data, coord, _server) = setup();
        assert_eq!(coord.degrade, DegradeMode::Fail);
        coord.set_brownout(BROWNOUT_ALL);
        let mut row = Vec::new();
        let mut served_rpc = false;
        for r in 0..50 {
            data.row_into(r, &mut row);
            let (_, served) = coord.predict(&row).unwrap();
            assert_ne!(served, Served::Degraded, "Fail mode must not brown out");
            served_rpc |= served == Served::Rpc;
        }
        assert!(served_rpc, "misses must still reach the second stage");
    }

    // ---- guarded rollout ------------------------------------------------

    /// Embedded stack whose pool handle and flattened incumbent are kept
    /// out for rollout assertions.
    fn setup_embedded() -> (
        crate::tabular::Dataset,
        Coordinator,
        Arc<ShardPool>,
        crate::gbdt::FlatForest,
    ) {
        let spec = datagen::preset("aci").unwrap().with_rows(4000);
        let data = datagen::generate(&spec, 5);
        let ranking = rank_features(&data, RankMethod::GbdtGain, 1);
        let mut first = LrwBinsModel::train(
            &data,
            &ranking.order,
            &LrwBinsParams {
                b: 2,
                n_bin_features: 3,
                n_infer_features: 6,
                ..Default::default()
            },
        );
        let route: std::collections::HashSet<u32> =
            first.weights.keys().copied().filter(|b| b % 2 == 0).collect();
        first.set_route(route);
        let incumbent = crate::gbdt::train(&data, &crate::gbdt::GbdtParams::quick()).flatten();
        let pool = Arc::new(ShardPool::new(2));
        let model = pool.register(incumbent.clone());
        let metrics = Arc::new(ServeMetrics::new());
        let coord = Coordinator::new_embedded(
            ServingTables::from_model(&first),
            pool.clone(),
            model,
            metrics,
        );
        (data, coord, pool, incumbent)
    }

    /// Candidate snapshot: the coordinator's own tables + the incumbent
    /// forest with every leaf margin shifted by `leaf_shift` (0.0 ⇒ a
    /// bit-identical candidate).
    fn candidate_snapshot(
        coord: &Coordinator,
        incumbent: &crate::gbdt::FlatForest,
        leaf_shift: f32,
    ) -> Snapshot {
        let mut forest = incumbent.clone();
        if leaf_shift != 0.0 {
            for i in 0..forest.value.len() {
                if forest.feat[i] == crate::gbdt::LEAF {
                    forest.value[i] += leaf_shift;
                }
            }
        }
        Snapshot::parse(&Snapshot::write(&coord.tables, &forest)).unwrap()
    }

    /// A rollout config tuned so tests promote in a handful of ticks.
    fn fast_rollout_cfg() -> RolloutConfig {
        RolloutConfig {
            shadow_sample_permille: 1000,
            min_rows_compared: 50,
            min_shadow_ticks: 1,
            canary_steps_permille: vec![500],
            step_ticks: 1,
            error_budget_rows: 1_000_000,
            ..Default::default()
        }
    }

    /// A bit-identical candidate walks Shadow → Canary → Promoted; the
    /// served bits during Shadow are exactly the incumbent's, and finalize
    /// installs the candidate as the pool's live version.
    #[test]
    fn rollout_good_candidate_promotes_with_identical_shadow_bits() {
        let (data, mut coord, _pool, incumbent) = setup_embedded();
        let baseline: Vec<(f32, Served)> = (0..300)
            .map(|r| coord.predict(&data.row(r)).unwrap())
            .collect();
        let snap = candidate_snapshot(&coord, &incumbent, 0.0);
        let ro = coord.begin_rollout(&snap, fast_rollout_cfg()).unwrap();
        assert_eq!(ro.phase(), RolloutPhase::Shadow);

        // Shadow: every request sampled; served bits must not move.
        for (r, base) in baseline.iter().enumerate() {
            let (p, served) = coord.predict(&data.row(r)).unwrap();
            assert_eq!(p.to_bits(), base.0.to_bits(), "row {r} bits moved in shadow");
            assert_eq!(served, base.1, "row {r} served path moved in shadow");
        }
        assert!(ro.stats.rows_compared.load(Ordering::Relaxed) >= 300);
        assert_eq!(ro.stats.disagreements.load(Ordering::Relaxed), 0);

        coord.rollout_tick(false);
        assert_eq!(ro.phase(), RolloutPhase::Canary);
        assert_eq!(ro.canary_permille(), 500);
        for r in 0..200 {
            let (p, _) = coord.predict(&data.row(r)).unwrap();
            // Candidate == incumbent, so even canary-served rows are
            // bit-identical.
            assert_eq!(p.to_bits(), baseline[r].0.to_bits(), "row {r} in canary");
        }
        assert!(
            ro.stats.canary_rows.load(Ordering::Relaxed) > 0,
            "a 50% canary over 200 requests must have routed some"
        );
        coord.rollout_tick(false);
        assert_eq!(ro.phase(), RolloutPhase::Promoted);
        assert_eq!(ro.canary_permille(), 1000);
        assert_eq!(coord.metrics.rollout_rolled_back.load(Ordering::Relaxed), 0);

        let version = coord.finalize_rollout().unwrap();
        assert!(version > 0, "embedded promotion must bump the pool version");
        assert!(coord.rollout().is_none(), "finalize retires the slot");
        for (r, base) in baseline.iter().enumerate().take(100) {
            let (p, _) = coord.predict(&data.row(r)).unwrap();
            assert_eq!(p.to_bits(), base.0.to_bits(), "row {r} after promotion");
        }
    }

    /// A candidate whose leaves are shifted past the score-delta guard
    /// rolls back automatically during Shadow — no traffic ever reaches it
    /// and the incumbent keeps serving.
    #[test]
    fn rollout_divergent_candidate_rolls_back_in_shadow() {
        let (data, coord, _pool, incumbent) = setup_embedded();
        let snap = candidate_snapshot(&coord, &incumbent, 4.0);
        let cfg = RolloutConfig {
            max_score_delta: 0.2,
            ..fast_rollout_cfg()
        };
        let ro = coord.begin_rollout(&snap, cfg).unwrap();

        // Shadow scoring drains through the pool's idle slots, so the trip
        // is asynchronous — keep serving until it lands (bounded).
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut r = 0usize;
        while ro.phase() == RolloutPhase::Shadow && Instant::now() < deadline {
            coord.predict(&data.row(r % data.n_rows())).unwrap();
            r += 1;
            std::thread::yield_now();
        }
        assert_eq!(
            ro.phase(),
            RolloutPhase::RolledBack,
            "divergent candidate must auto-roll back (served {r} rows)"
        );
        assert_eq!(ro.rollback_reason(), Some(RollbackReason::ScoreDelta));
        assert_eq!(coord.metrics.rollout_rolled_back.load(Ordering::Relaxed), 1);
        assert_eq!(
            ro.stats.canary_rows.load(Ordering::Relaxed),
            0,
            "a shadow-phase rollback must never have served canary traffic"
        );
        // Incumbent serving is unaffected.
        for r in 0..50 {
            let (p, served) = coord.predict(&data.row(r)).unwrap();
            assert!((0.0..=1.0).contains(&p));
            assert_ne!(served, Served::Degraded);
        }
    }

    /// With a zero error budget the canary never claims a batch: rows are
    /// counted `budget_held_rows` and served by the incumbent — held, not
    /// shed.
    #[test]
    fn rollout_exhausted_budget_keeps_traffic_on_incumbent() {
        let (data, coord, _pool, incumbent) = setup_embedded();
        let snap = candidate_snapshot(&coord, &incumbent, 0.0);
        let cfg = RolloutConfig {
            error_budget_rows: 0,
            ..fast_rollout_cfg()
        };
        let ro = coord.begin_rollout(&snap, cfg).unwrap();
        for r in 0..100 {
            coord.predict(&data.row(r)).unwrap();
        }
        coord.rollout_tick(false);
        assert_eq!(ro.phase(), RolloutPhase::Canary);
        for r in 0..100 {
            let (p, _) = coord.predict(&data.row(r)).unwrap();
            assert!((0.0..=1.0).contains(&p));
        }
        assert_eq!(
            ro.stats.canary_rows.load(Ordering::Relaxed),
            0,
            "zero budget must keep every row on the incumbent"
        );
        assert!(
            ro.stats.budget_held_rows.load(Ordering::Relaxed) > 0,
            "held rows must be counted"
        );
    }

    /// One candidate at a time: begin while Shadow/Canary is active is
    /// refused; after end_rollout the slot is free again.
    #[test]
    fn rollout_slot_exclusive_until_ended() {
        let (_data, coord, _pool, incumbent) = setup_embedded();
        let snap = candidate_snapshot(&coord, &incumbent, 0.0);
        let ro = coord.begin_rollout(&snap, fast_rollout_cfg()).unwrap();
        assert!(coord.begin_rollout(&snap, fast_rollout_cfg()).is_err());
        let ended = coord.end_rollout().expect("active rollout");
        assert!(Arc::ptr_eq(&ro, &ended));
        assert!(coord.rollout().is_none());
        coord.begin_rollout(&snap, fast_rollout_cfg()).unwrap();
    }

    /// RPC-mode coordinators run the candidate's second stage locally
    /// (no pool): the same lifecycle promotes, and finalize reports
    /// version 0 as `reload` does.
    #[test]
    fn rollout_rpc_mode_local_candidate_promotes() {
        let (data, mut coord, _server) = setup();
        let second = crate::gbdt::train(&data, &crate::gbdt::GbdtParams::quick());
        let snap =
            Snapshot::parse(&Snapshot::write(&coord.tables, &second.flatten())).unwrap();
        let ro = coord.begin_rollout(&snap, fast_rollout_cfg()).unwrap();
        for r in 0..200 {
            coord.predict(&data.row(r)).unwrap();
        }
        assert!(ro.stats.rows_compared.load(Ordering::Relaxed) >= 200);
        coord.rollout_tick(false);
        assert_eq!(ro.phase(), RolloutPhase::Canary);
        for r in 0..200 {
            let (p, _) = coord.predict(&data.row(r)).unwrap();
            assert!((0.0..=1.0).contains(&p));
        }
        assert!(ro.stats.canary_rows.load(Ordering::Relaxed) > 0);
        coord.rollout_tick(false);
        assert_eq!(ro.phase(), RolloutPhase::Promoted);
        assert_eq!(coord.finalize_rollout().unwrap(), 0);
        assert_eq!(coord.metrics.rollout_rolled_back.load(Ordering::Relaxed), 0);
    }

    /// Escalated ticks freeze the ramp: the phase and permille hold, and
    /// every freeze is counted.
    #[test]
    fn rollout_ramp_freezes_while_escalated() {
        let (data, coord, _pool, incumbent) = setup_embedded();
        let snap = candidate_snapshot(&coord, &incumbent, 0.0);
        let ro = coord.begin_rollout(&snap, fast_rollout_cfg()).unwrap();
        for r in 0..100 {
            coord.predict(&data.row(r)).unwrap();
        }
        // Dwell + compared thresholds are met, but escalated ticks must
        // not advance Shadow → Canary.
        for _ in 0..5 {
            coord.rollout_tick(true);
        }
        assert_eq!(ro.phase(), RolloutPhase::Shadow);
        assert_eq!(ro.stats.ramp_freezes.load(Ordering::Relaxed), 5);
        coord.rollout_tick(false);
        assert_eq!(ro.phase(), RolloutPhase::Canary);
    }
}
