//! The multistage coordinator — the paper's system contribution, embedded
//! in "product code".
//!
//! Per request: evaluate the embedded first-stage LRwBins tables (pure Rust,
//! config-table driven, no ML library — the paper's PHP-embedded model);
//! on a route miss, pad the row and call the second-stage RPC service.
//! Batched product requests send ONE coalesced RPC for all missed rows.
//! Every request is timed (wall + CPU) and accounted per stage so Table 3 /
//! §5.2 quantities (mean latency, CPU, coverage, feature-fetch and network
//! bytes) fall out of `ServeMetrics`.

use crate::lrwbins::{BlockScratch, ServingTables};
use crate::rpc::RpcClient;
use crate::tabular::RowBlock;
use crate::telemetry::{CpuTimer, ServeMetrics};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Routing override, used by the Table 3 bench to measure each mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Paper's multistage: embedded stage 1, RPC fallback.
    Multistage,
    /// Always call the RPC service (the conventional architecture).
    AlwaysRpc,
    /// Always answer with stage 1 (even unrouted bins — shadow mode).
    AlwaysStage1,
}

/// Which stage produced a prediction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Served {
    Stage1,
    Rpc,
}

/// Feature-fetch cost model (paper §5.2: feature fetching is a CPU
/// bottleneck; LRwBins fetches only the top-n subset, giving the 1.2×
/// speedup / 70% resource claim). Busy-waits `per_feature_us` per fetched
/// feature so both wall latency AND CPU accounting see the cost, like a
/// real feature-store deserialization would.
#[derive(Clone, Copy, Debug)]
pub struct FetchSim {
    pub per_feature_us: f64,
}

impl FetchSim {
    /// Total simulated fetch cost for `n_features`. Computed in f64 *before*
    /// truncating to integer nanoseconds — casting the per-feature cost
    /// first would silently drop fractional-ns costs (e.g. 0.5ns/feature
    /// over 1000 features is 500ns, not 0).
    pub fn duration(&self, n_features: usize) -> Duration {
        if self.per_feature_us <= 0.0 || n_features == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.per_feature_us * 1000.0 * n_features as f64) as u64)
    }

    pub fn fetch(&self, n_features: usize) {
        let cost = self.duration(n_features);
        if cost.is_zero() {
            return;
        }
        let deadline = Instant::now() + cost;
        while Instant::now() < deadline {
            std::hint::spin_loop();
        }
    }
}

/// Reusable per-coordinator scratch for the batched path: the transposed
/// request block, stage-1 outputs, and the coalesced RPC gather buffer all
/// persist across requests, so a steady-state batch costs zero allocations
/// beyond the caller-visible result vector.
#[derive(Default)]
struct CoordScratch {
    block: RowBlock,
    tab: BlockScratch,
    probs: Vec<f32>,
    routed: Vec<bool>,
    miss_idx: Vec<usize>,
    miss_rows: Vec<f32>,
    row: Vec<f32>,
}

/// The product-code front-end.
pub struct Coordinator {
    pub tables: ServingTables,
    rpc: Option<RpcClient>,
    /// Padded row width expected by the RPC backend (PJRT f_max, or the raw
    /// feature count for the native backend).
    rpc_row_len: usize,
    pub metrics: Arc<ServeMetrics>,
    pub mode: Mode,
    /// Optional feature-fetch cost model (None = features already in hand).
    pub fetch: Option<FetchSim>,
    scratch: Mutex<CoordScratch>,
}

impl Coordinator {
    pub fn new(
        tables: ServingTables,
        rpc: Option<RpcClient>,
        rpc_row_len: usize,
        metrics: Arc<ServeMetrics>,
    ) -> Coordinator {
        let rpc_row_len = if rpc_row_len == 0 {
            tables.n_features
        } else {
            rpc_row_len
        };
        assert!(rpc_row_len >= tables.n_features);
        Coordinator {
            tables,
            rpc,
            rpc_row_len,
            metrics,
            mode: Mode::Multistage,
            fetch: None,
            scratch: Mutex::new(CoordScratch::default()),
        }
    }

    fn pad_for_rpc(&self, row: &[f32], buf: &mut Vec<f32>) {
        buf.reserve(self.rpc_row_len);
        buf.extend_from_slice(row);
        buf.resize(buf.len() + (self.rpc_row_len - row.len()), 0.0);
    }

    fn rpc_predict(&self, rows: &[f32], n: usize) -> std::io::Result<Vec<f32>> {
        let client = self.rpc.as_ref().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::NotConnected, "no RPC backend configured")
        })?;
        let probs = client.predict(rows, self.rpc_row_len)?;
        debug_assert_eq!(probs.len(), n);
        Ok(probs)
    }

    /// Serve one inference. Returns `(probability, stage)`.
    pub fn predict(&self, row: &[f32]) -> std::io::Result<(f32, Served)> {
        debug_assert_eq!(row.len(), self.tables.n_features);
        let t0 = Instant::now();
        let cpu = CpuTimer::start();

        // Feature fetch for the stage-1 attempt: only the top-n subset
        // (paper: the first-stage fetches the most important features).
        // AlwaysRpc skips the attempt entirely and fetches everything.
        if let Some(f) = &self.fetch {
            match self.mode {
                Mode::AlwaysRpc => f.fetch(self.tables.n_features),
                _ => f.fetch(self.tables.n_infer()),
            }
        }

        // Embedded stage-1 evaluation (also the router decision).
        let (p1, routed) = self.tables.evaluate(row);
        let stage1_wall = t0.elapsed().as_nanos() as u64;
        let use_stage1 = match self.mode {
            Mode::Multistage => routed,
            Mode::AlwaysRpc => false,
            Mode::AlwaysStage1 => true,
        };
        if use_stage1 {
            self.metrics
                .hit_stage1(stage1_wall, cpu.elapsed_ns(), self.tables.n_infer() as u64);
            self.metrics.e2e.record(t0.elapsed().as_nanos() as u64);
            return Ok((p1, Served::Stage1));
        }

        // Fallback: fetch the remaining features, pad + RPC.
        if let Some(f) = &self.fetch {
            if self.mode != Mode::AlwaysRpc {
                f.fetch(self.tables.n_features.saturating_sub(self.tables.n_infer()));
            }
        }
        let mut padded = Vec::with_capacity(self.rpc_row_len);
        self.pad_for_rpc(row, &mut padded);
        let probs = self.rpc_predict(&padded, 1)?;
        let wall = t0.elapsed().as_nanos() as u64;
        self.metrics.hit_rpc(
            wall,
            cpu.elapsed_ns(),
            self.tables.n_features as u64,
            RpcClient::wire_bytes(1, self.rpc_row_len),
        );
        self.metrics.e2e.record(wall);
        Ok((probs[0], Served::Rpc))
    }

    /// Serve a batched product request: stage-1 for routed rows, one
    /// coalesced RPC for the rest. Returns per-row `(prob, stage)`.
    ///
    /// Transposes `rows` into the reusable columnar scratch block and runs
    /// the block path ([`Coordinator::predict_block`]); results are
    /// bit-identical to the scalar per-row path.
    pub fn predict_batch(&self, rows: &[Vec<f32>]) -> std::io::Result<Vec<(f32, Served)>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let mut guard = self.lock_scratch();
        let mut block = std::mem::take(&mut guard.block);
        block.fill_from_rows(rows);
        let res = self.serve_block(&block, Some(rows), guard);
        self.lock_scratch().block = block;
        res
    }

    /// Serve a columnar request block: one batched stage-1 evaluation over
    /// the whole block, then one coalesced RPC carrying every route-missed
    /// row (gathered into a single padded buffer that is reused across
    /// requests). Per-row results are bit-identical to
    /// [`Coordinator::predict`]; metrics are accounted per stage exactly as
    /// on the scalar path (amortized per row).
    pub fn predict_block(&self, block: &RowBlock) -> std::io::Result<Vec<(f32, Served)>> {
        let guard = self.lock_scratch();
        self.serve_block(block, None, guard)
    }

    /// Scratch contents are cleared before every use, so a poisoned lock
    /// (a panicking request) must not take serving down — recover it.
    fn lock_scratch(&self) -> MutexGuard<'_, CoordScratch> {
        self.scratch.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Stage-1 + gather under the scratch lock, then RELEASE it before the
    /// blocking fallback RPC so concurrent batched requests only serialize
    /// on the (cheap) embedded pass, never on the network. `src_rows`, when
    /// available (the row-major `predict_batch` input), avoids re-gathering
    /// missed rows out of the columnar block with strided reads.
    fn serve_block(
        &self,
        block: &RowBlock,
        src_rows: Option<&[Vec<f32>]>,
        mut guard: MutexGuard<'_, CoordScratch>,
    ) -> std::io::Result<Vec<(f32, Served)>> {
        debug_assert!(block.is_empty() || block.n_features() == self.tables.n_features);
        let n = block.n_rows();
        let t0 = Instant::now();
        let cpu = CpuTimer::start();

        // One batched stage-1 pass over the whole block (also routing).
        let (mut out, miss_idx, miss_rows) = {
            let s = &mut *guard;
            self.tables
                .evaluate_block(block, &mut s.tab, &mut s.probs, &mut s.routed);
            let mut out: Vec<(f32, Served)> = Vec::with_capacity(n);
            s.miss_idx.clear();
            s.miss_rows.clear();
            for (i, (&p1, &routed)) in s.probs.iter().zip(&s.routed).enumerate() {
                let use_stage1 = match self.mode {
                    Mode::Multistage => routed,
                    Mode::AlwaysRpc => false,
                    Mode::AlwaysStage1 => true,
                };
                if use_stage1 {
                    out.push((p1, Served::Stage1));
                } else {
                    s.miss_idx.push(i);
                    out.push((0.0, Served::Rpc)); // placeholder
                }
            }
            // Gather all missed rows into ONE padded, coalesced RPC buffer.
            if !s.miss_idx.is_empty() {
                s.miss_rows.reserve(s.miss_idx.len() * self.rpc_row_len);
                match src_rows {
                    Some(rows) => {
                        for &i in &s.miss_idx {
                            self.pad_for_rpc(&rows[i], &mut s.miss_rows);
                        }
                    }
                    None => {
                        for &i in &s.miss_idx {
                            block.row_into(i, &mut s.row);
                            self.pad_for_rpc(&s.row, &mut s.miss_rows);
                        }
                    }
                }
            }
            (
                out,
                std::mem::take(&mut s.miss_idx),
                std::mem::take(&mut s.miss_rows),
            )
        };
        drop(guard);

        let stage1_cpu = cpu.elapsed_ns();
        let n_hits = n - miss_idx.len();
        if n_hits > 0 {
            let per = t0.elapsed().as_nanos() as u64 / n.max(1) as u64;
            for _ in 0..n_hits {
                self.metrics.hit_stage1(
                    per,
                    stage1_cpu / n.max(1) as u64,
                    self.tables.n_infer() as u64,
                );
            }
        }
        let rpc_result = if miss_idx.is_empty() {
            Ok(())
        } else {
            let t_rpc = Instant::now();
            let cpu_rpc = CpuTimer::start();
            match self.rpc_predict(&miss_rows, miss_idx.len()) {
                Ok(probs) => {
                    let rpc_wall = t_rpc.elapsed().as_nanos() as u64;
                    let rpc_cpu = cpu_rpc.elapsed_ns();
                    for (k, &i) in miss_idx.iter().enumerate() {
                        out[i].0 = probs[k];
                        self.metrics.hit_rpc(
                            rpc_wall / miss_idx.len() as u64,
                            rpc_cpu / miss_idx.len() as u64,
                            self.tables.n_features as u64,
                            RpcClient::wire_bytes(1, self.rpc_row_len),
                        );
                    }
                    Ok(())
                }
                Err(e) => Err(e),
            }
        };
        // Hand the gather buffers back for the next request (best effort —
        // under contention another request may already have fresh ones).
        {
            let mut g = self.lock_scratch();
            g.miss_idx = miss_idx;
            g.miss_rows = miss_rows;
        }
        rpc_result?;
        let wall = t0.elapsed().as_nanos() as u64;
        for _ in 0..n {
            self.metrics.e2e.record(wall / n.max(1) as u64);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::features::{rank_features, RankMethod};
    use crate::lrwbins::{LrwBinsModel, LrwBinsParams};
    use crate::rpc::netsim::{NetSim, NetSimConfig};
    use crate::rpc::server::{BatcherConfig, NativeBackend, RpcServer};

    fn setup() -> (crate::tabular::Dataset, Coordinator, RpcServer) {
        let spec = datagen::preset("aci").unwrap().with_rows(4000);
        let data = datagen::generate(&spec, 5);
        let ranking = rank_features(&data, RankMethod::GbdtGain, 1);
        let mut first = LrwBinsModel::train(
            &data,
            &ranking.order,
            &LrwBinsParams {
                b: 2,
                n_bin_features: 3,
                n_infer_features: 6,
                ..Default::default()
            },
        );
        // Route half the bins.
        let route: std::collections::HashSet<u32> =
            first.weights.keys().copied().filter(|b| b % 2 == 0).collect();
        first.set_route(route);
        let second = crate::gbdt::train(&data, &crate::gbdt::GbdtParams::quick());

        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(NativeBackend::new(second)),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig::default(),
            metrics.clone(),
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();
        let tables = ServingTables::from_model(&first);
        let coord = Coordinator::new(tables, Some(client), 0, metrics);
        (data, coord, server)
    }

    #[test]
    fn multistage_conservation_every_row_answered() {
        let (data, coord, _server) = setup();
        let mut s1 = 0;
        let mut rpc = 0;
        let mut row = Vec::new();
        for r in 0..500 {
            data.row_into(r, &mut row);
            let (p, served) = coord.predict(&row).unwrap();
            assert!((0.0..=1.0).contains(&p), "p={p}");
            match served {
                Served::Stage1 => s1 += 1,
                Served::Rpc => rpc += 1,
            }
        }
        assert_eq!(s1 + rpc, 500);
        assert!(s1 > 0, "some rows must be stage-1");
        assert!(rpc > 0, "some rows must fall back");
        assert!((coord.metrics.coverage() - s1 as f64 / 500.0).abs() < 1e-9);
    }

    #[test]
    fn batch_matches_single_row_path() {
        let (data, coord, _server) = setup();
        let rows: Vec<Vec<f32>> = (0..64).map(|r| data.row(r)).collect();
        let batch = coord.predict_batch(&rows).unwrap();
        for (i, row) in rows.iter().enumerate() {
            let (p, served) = coord.predict(row).unwrap();
            assert_eq!(batch[i].1, served, "row {i}");
            assert!((batch[i].0 - p).abs() < 1e-6, "row {i}");
        }
    }

    #[test]
    fn block_matches_batch_and_reuses_scratch() {
        let (data, coord, _server) = setup();
        let rows: Vec<Vec<f32>> = (0..96).map(|r| data.row(r)).collect();
        let batch = coord.predict_batch(&rows).unwrap();
        let mut block = crate::tabular::RowBlock::new();
        // Run the block path twice over varying sizes to exercise scratch
        // reuse (shrinking and growing between requests).
        for take in [96usize, 17, 96] {
            block.fill_from_rows(&rows[..take]);
            let via_block = coord.predict_block(&block).unwrap();
            assert_eq!(via_block.len(), take);
            for i in 0..take {
                assert_eq!(via_block[i].1, batch[i].1, "take {take} row {i}");
                // Stage-1 probabilities are bit-identical; RPC responses go
                // through f32 wire serialization and are exact as well.
                assert_eq!(
                    via_block[i].0.to_bits(),
                    batch[i].0.to_bits(),
                    "take {take} row {i}"
                );
            }
        }
    }

    #[test]
    fn fetch_sim_keeps_fractional_nanoseconds() {
        // 0.0005µs = 0.5ns per feature: the per-feature cost truncates to 0,
        // but the total over 1000 features is a real 500ns.
        let f = FetchSim { per_feature_us: 0.0005 };
        assert_eq!(f.duration(1000), Duration::from_nanos(500));
        assert_eq!(f.duration(0), Duration::ZERO);
        // Whole-ns per-feature costs are unchanged by the f64 total.
        let g = FetchSim { per_feature_us: 2.0 };
        assert_eq!(g.duration(3), Duration::from_nanos(6000));
    }

    #[test]
    fn always_rpc_mode_never_uses_stage1() {
        let (data, mut coord, _server) = setup();
        coord.mode = Mode::AlwaysRpc;
        let mut row = Vec::new();
        for r in 0..50 {
            data.row_into(r, &mut row);
            let (_, served) = coord.predict(&row).unwrap();
            assert_eq!(served, Served::Rpc);
        }
    }

    #[test]
    fn always_stage1_mode_never_calls_rpc() {
        let (data, mut coord, _server) = setup();
        coord.mode = Mode::AlwaysStage1;
        let mut row = Vec::new();
        for r in 0..50 {
            data.row_into(r, &mut row);
            let (_, served) = coord.predict(&row).unwrap();
            assert_eq!(served, Served::Stage1);
        }
        assert_eq!(
            coord
                .metrics
                .rpc_calls
                .load(std::sync::atomic::Ordering::Relaxed),
            0
        );
    }

    #[test]
    fn no_rpc_configured_errors_on_miss() {
        let (data, coord, server) = setup();
        let tables = coord.tables.clone();
        let metrics = Arc::new(ServeMetrics::new());
        drop(coord);
        drop(server);
        let lone = Coordinator::new(tables, None, 0, metrics);
        let mut row = Vec::new();
        let mut saw_error = false;
        for r in 0..200 {
            data.row_into(r, &mut row);
            match lone.predict(&row) {
                Ok((_, Served::Stage1)) => {}
                Ok((_, Served::Rpc)) => panic!("cannot serve rpc without client"),
                Err(_) => {
                    saw_error = true;
                    break;
                }
            }
        }
        assert!(saw_error, "expected an error on the first miss");
    }
}
