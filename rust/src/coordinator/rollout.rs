//! Guarded model rollout: shadow scoring, canary ramp, and
//! divergence-triggered automatic rollback.
//!
//! The state machine a candidate snapshot walks before it may replace the
//! incumbent (see the crate docs' "Model rollout" section for the full
//! contract):
//!
//! ```text
//! Idle ──begin_rollout──▶ Shadow ──▶ Canary(p%) ──▶ Promoted
//!                            │            │
//!                            └── guard ───┴──▶ RolledBack{reason}
//! ```
//!
//! *Idle* is the coordinator's normal state — no [`Rollout`] object exists.
//! In **Shadow**, a sampled fraction of served batches is re-evaluated on
//! the candidate (stage-1 tables inline, second-stage forest on the shard
//! pool's strictly-lower-priority shadow queue) while served bits stay
//! bit-identical to pre-rollout; the divergence monitor accumulates routing
//! disagreement, score-delta histograms, and shadow-vs-live latency in
//! [`RolloutStats`]. In **Canary**, a deterministic hash of the request's
//! rollout key routes p‰ of real traffic to the candidate — whole batches
//! only, never mixing versions within a batch — with the ramp advanced by
//! SLO-controller ticks and frozen whenever the controller is escalated.
//! Any guard trip ([`RollbackReason`]) flips the phase to **RolledBack**:
//! routing reverts on the very next request, and the error budget bounds
//! how many rows the candidate may ever have answered.

use crate::gbdt::{FlatForest, ForestScratch};
use crate::lrwbins::ServingTables;
use crate::runtime::{ModelId, ShadowJob, ShadowOutcome, ShardPool, VersionLease};
use crate::telemetry::{RolloutStats, ServeMetrics};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// Rollout phase. `Idle` is represented by the ABSENCE of a rollout; a
/// constructed [`Rollout`] starts in `Shadow`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum RolloutPhase {
    Shadow = 1,
    Canary = 2,
    Promoted = 3,
    RolledBack = 4,
}

/// Why a rollout was automatically rolled back — stored on the rollout and
/// counted in [`ServeMetrics::rollout_rolled_back`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum RollbackReason {
    /// Stage-1 routing disagreement rate exceeded the bound (after the
    /// minimum compared-row count armed the guard).
    Disagreement = 1,
    /// A single |candidate − live| score delta exceeded the bound.
    ScoreDelta = 2,
    /// Shadow-scoring p99 exceeded the configured multiple of the live p99.
    ShadowLatency = 3,
    /// Canary batch p99 exceeded the absolute bound.
    CanaryLatency = 4,
    /// The candidate panicked or failed while scoring (shadow or canary) —
    /// maximal divergence, tripped immediately.
    CandidateFailure = 5,
}

impl RollbackReason {
    fn from_u8(v: u8) -> Option<RollbackReason> {
        match v {
            1 => Some(RollbackReason::Disagreement),
            2 => Some(RollbackReason::ScoreDelta),
            3 => Some(RollbackReason::ShadowLatency),
            4 => Some(RollbackReason::CanaryLatency),
            5 => Some(RollbackReason::CandidateFailure),
            _ => None,
        }
    }
}

/// Rollout policy knobs (`ServeConfig::rollout_config`).
#[derive(Clone, Debug)]
pub struct RolloutConfig {
    /// Fraction of served (non-canary) batches sampled into the shadow
    /// comparison, in permille. 0 disables shadow sampling (the rollout
    /// then never arms its divergence guards — only useful for drills).
    pub shadow_sample_permille: u32,
    /// Compared rows required before the disagreement-rate guard arms AND
    /// before Shadow may hand over to Canary.
    pub min_rows_compared: u64,
    /// Stage-1 routing disagreement-rate bound (fraction of compared rows).
    pub max_disagreement: f64,
    /// Bound on any single |candidate − live| score delta (probability
    /// scale, stage-1 prior and second-stage scores alike).
    pub max_score_delta: f64,
    /// Controller ticks that must elapse in Shadow before Canary.
    pub min_shadow_ticks: u32,
    /// Canary ramp schedule in permille of traffic, e.g. `[50, 200, 500]`;
    /// after the last step the rollout promotes (1000‰).
    pub canary_steps_permille: Vec<u32>,
    /// Unescalated controller ticks per ramp step.
    pub step_ticks: u32,
    /// Hard pre-promotion cap on rows the candidate may answer: a canary
    /// batch that would exceed it is NOT routed (served by the incumbent,
    /// counted in [`RolloutStats::budget_held_rows`]).
    pub error_budget_rows: u64,
    /// Absolute canary-batch p99 bound, µs (0 disables the guard).
    pub canary_p99_bound_us: u64,
    /// Shadow-vs-live p99 ratio bound (0.0 disables the guard).
    pub max_shadow_latency_ratio: f64,
    /// Shed horizon for queued shadow jobs.
    pub shadow_timeout: Duration,
}

impl Default for RolloutConfig {
    fn default() -> RolloutConfig {
        RolloutConfig {
            shadow_sample_permille: 250,
            min_rows_compared: 200,
            max_disagreement: 0.02,
            max_score_delta: 0.25,
            min_shadow_ticks: 2,
            canary_steps_permille: vec![50, 200, 500],
            step_ticks: 2,
            error_budget_rows: 10_000,
            canary_p99_bound_us: 0,
            max_shadow_latency_ratio: 0.0,
            shadow_timeout: Duration::from_millis(250),
        }
    }
}

/// Minimum latency samples before a p99-based guard may trip — a p99 over
/// a handful of samples is noise, not evidence.
const LATENCY_GUARD_MIN_SAMPLES: u64 = 32;

/// splitmix64 — the deterministic canary router. The same rollout key maps
/// to the same side of the p‰ threshold on every replay.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Where the candidate's second-stage scores come from.
pub(crate) enum CandidateStage2 {
    /// Embedded mode: the candidate forest is STAGED in the shared shard
    /// pool (versioned next to the incumbent) and pinned by a lease —
    /// canary batches serve it via `predict_spans_version`, shadow rows
    /// ride the pool's lowest-priority shadow queue.
    Pool {
        pool: Arc<ShardPool>,
        model: ModelId,
        version: u32,
        /// Keeps the staged version resolvable across racing swaps and
        /// past an `unstage` for in-flight work; released when the
        /// rollout drops.
        _lease: VersionLease,
    },
    /// RPC (or stage-1-only) mode: the remote service knows nothing of the
    /// candidate, so its forest is scored IN-PROCESS from the snapshot —
    /// zero wire bytes, serialized on a private scratch.
    Local {
        forest: Arc<FlatForest>,
        scratch: Mutex<ForestScratch>,
    },
}

/// One guarded rollout of one candidate snapshot. Created by
/// `Coordinator::begin_rollout`; all state is interior-mutable so the
/// coordinator drives it through `&self` under live traffic.
pub struct Rollout {
    pub(crate) cfg: RolloutConfig,
    /// Candidate stage-1 tables (same feature width as the incumbent,
    /// enforced at `begin_rollout`).
    pub(crate) tables: ServingTables,
    pub(crate) stage2: CandidateStage2,
    phase: AtomicU8,
    reason: AtomicU8,
    /// Live canary routing threshold, permille.
    permille: AtomicU32,
    /// Index into `cfg.canary_steps_permille`.
    step: AtomicU32,
    ticks_in_step: AtomicU32,
    shadow_ticks: AtomicU32,
    /// Rows the candidate has answered pre-promotion (the error budget).
    budget_used: AtomicU64,
    /// Batch arrival counter feeding the shadow sampling hash.
    sample_seq: AtomicU64,
    /// Fallback canary key for requests that carry none.
    key_seq: AtomicU64,
    /// The divergence monitor's accumulators.
    pub stats: RolloutStats,
}

impl Rollout {
    pub(crate) fn new(cfg: RolloutConfig, tables: ServingTables, stage2: CandidateStage2) -> Rollout {
        Rollout {
            cfg,
            tables,
            stage2,
            phase: AtomicU8::new(RolloutPhase::Shadow as u8),
            reason: AtomicU8::new(0),
            permille: AtomicU32::new(0),
            step: AtomicU32::new(0),
            ticks_in_step: AtomicU32::new(0),
            shadow_ticks: AtomicU32::new(0),
            budget_used: AtomicU64::new(0),
            sample_seq: AtomicU64::new(0),
            key_seq: AtomicU64::new(0),
            stats: RolloutStats::new(),
        }
    }

    pub fn phase(&self) -> RolloutPhase {
        match self.phase.load(Ordering::Acquire) {
            1 => RolloutPhase::Shadow,
            2 => RolloutPhase::Canary,
            3 => RolloutPhase::Promoted,
            _ => RolloutPhase::RolledBack,
        }
    }

    /// The typed rollback reason, once rolled back.
    pub fn rollback_reason(&self) -> Option<RollbackReason> {
        RollbackReason::from_u8(self.reason.load(Ordering::Acquire))
    }

    /// Current canary routing fraction, permille of traffic.
    pub fn canary_permille(&self) -> u32 {
        self.permille.load(Ordering::Relaxed)
    }

    /// The staged candidate's pool-side version (0 for the local path).
    pub fn candidate_version(&self) -> u32 {
        match &self.stage2 {
            CandidateStage2::Pool { version, .. } => *version,
            CandidateStage2::Local { .. } => 0,
        }
    }

    /// Rows the candidate has answered so far against the error budget.
    pub fn budget_used(&self) -> u64 {
        self.budget_used.load(Ordering::Relaxed)
    }

    /// One SLO-controller tick. `escalated` (brownout active or admission
    /// throttled) freezes the ramp: an overloaded system must not widen a
    /// model experiment. Unescalated ticks advance Shadow → Canary (once
    /// the minimum dwell AND compared-row count are met) and the canary
    /// ramp step-by-step to promotion.
    pub fn tick(&self, escalated: bool) {
        self.stats.ticks.fetch_add(1, Ordering::Relaxed);
        let phase = self.phase();
        if !matches!(phase, RolloutPhase::Shadow | RolloutPhase::Canary) {
            return;
        }
        if escalated {
            self.stats.ramp_freezes.fetch_add(1, Ordering::Relaxed);
            return;
        }
        match phase {
            RolloutPhase::Shadow => {
                let dwelled = self.shadow_ticks.fetch_add(1, Ordering::Relaxed) + 1;
                let compared = self.stats.rows_compared.load(Ordering::Relaxed);
                if dwelled >= self.cfg.min_shadow_ticks && compared >= self.cfg.min_rows_compared {
                    let p = self.cfg.canary_steps_permille.first().copied().unwrap_or(1000);
                    // CAS so a racing guard trip wins over the transition.
                    if self
                        .phase
                        .compare_exchange(
                            RolloutPhase::Shadow as u8,
                            RolloutPhase::Canary as u8,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        self.permille.store(p, Ordering::Relaxed);
                    }
                }
            }
            RolloutPhase::Canary => {
                let t = self.ticks_in_step.fetch_add(1, Ordering::Relaxed) + 1;
                if t < self.cfg.step_ticks {
                    return;
                }
                self.ticks_in_step.store(0, Ordering::Relaxed);
                let next = self.step.load(Ordering::Relaxed) + 1;
                if (next as usize) < self.cfg.canary_steps_permille.len() {
                    self.step.store(next, Ordering::Relaxed);
                    self.permille.store(
                        self.cfg.canary_steps_permille[next as usize],
                        Ordering::Relaxed,
                    );
                } else if self
                    .phase
                    .compare_exchange(
                        RolloutPhase::Canary as u8,
                        RolloutPhase::Promoted as u8,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    self.permille.store(1000, Ordering::Relaxed);
                }
            }
            _ => {}
        }
    }

    /// Deterministic canary routing: does `key` fall in the candidate's
    /// current p‰ slice? Replayable — the same key always lands on the
    /// same side for a given ramp step.
    pub fn routes(&self, key: u64) -> bool {
        if !matches!(self.phase(), RolloutPhase::Canary | RolloutPhase::Promoted) {
            return false;
        }
        let p = self.permille.load(Ordering::Relaxed) as u64;
        p > 0 && splitmix64(key) % 1000 < p
    }

    /// The canary key for a request that carries none: an internal
    /// sequence, still deterministic per arrival order.
    pub(crate) fn next_key(&self) -> u64 {
        // Offset so internal keys don't collide with common explicit ids.
        0x5EED_0000_0000_0000 ^ self.key_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Reserve `n` rows of error budget before routing a canary batch.
    /// Post-promotion there is no budget (the candidate IS the model).
    /// Refusal counts `budget_held_rows` — the batch then serves the
    /// incumbent, it is not shed.
    pub(crate) fn try_reserve_budget(&self, n: u64) -> bool {
        if self.phase() == RolloutPhase::Promoted {
            return true;
        }
        let mut cur = self.budget_used.load(Ordering::Relaxed);
        loop {
            if cur + n > self.cfg.error_budget_rows {
                self.stats.budget_held_rows.fetch_add(n, Ordering::Relaxed);
                return false;
            }
            match self.budget_used.compare_exchange_weak(
                cur,
                cur + n,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Return a reservation the candidate did not end up serving.
    pub(crate) fn release_budget(&self, n: u64) {
        self.budget_used.fetch_sub(n, Ordering::AcqRel);
    }

    /// Should this (non-canary) batch be sampled into the shadow
    /// comparison? Deterministic in arrival order; only Shadow and Canary
    /// phases monitor.
    pub(crate) fn samples_shadow(&self) -> bool {
        if !matches!(self.phase(), RolloutPhase::Shadow | RolloutPhase::Canary) {
            return false;
        }
        let p = self.cfg.shadow_sample_permille as u64;
        if p == 0 {
            return false;
        }
        let seq = self.sample_seq.fetch_add(1, Ordering::Relaxed);
        splitmix64(seq ^ 0x5A5A_5A5A_5A5A_5A5A) % 1000 < p
    }

    /// Trip a guard: instant rollback. Only Shadow and Canary can trip —
    /// the CAS loop makes the first tripping guard the recorded reason and
    /// promotion/rollback races resolve to whoever got there first.
    /// Routing reverts on the next request (every canary check reads the
    /// phase); the staged candidate is unstaged from the pool (the lease
    /// keeps it resolvable for batches already in flight).
    pub(crate) fn trip(&self, reason: RollbackReason, metrics: &ServeMetrics) {
        let mut cur = self.phase.load(Ordering::Acquire);
        loop {
            if cur != RolloutPhase::Shadow as u8 && cur != RolloutPhase::Canary as u8 {
                return; // already promoted or rolled back
            }
            match self.phase.compare_exchange_weak(
                cur,
                RolloutPhase::RolledBack as u8,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.reason.store(reason as u8, Ordering::Release);
        self.permille.store(0, Ordering::Relaxed);
        metrics.rollout_rolled_back.fetch_add(1, Ordering::Relaxed);
        if let CandidateStage2::Pool { pool, model, .. } = &self.stage2 {
            pool.unstage(*model);
        }
    }

    /// Compare one row's stage-1 decision between incumbent and candidate
    /// tables; accumulate and check the routing guards.
    pub(crate) fn compare_stage1_row(
        &self,
        live: &ServingTables,
        row: &[f32],
        metrics: &ServeMetrics,
    ) {
        let (p_live, routed_live) = live.evaluate(row);
        let (p_cand, routed_cand) = self.tables.evaluate(row);
        let compared = self.stats.rows_compared.fetch_add(1, Ordering::Relaxed) + 1;
        if routed_live != routed_cand {
            self.stats.disagreements.fetch_add(1, Ordering::Relaxed);
        }
        self.note_delta(p_cand - p_live, metrics);
        if compared >= self.cfg.min_rows_compared
            && self.stats.disagreement_rate() > self.cfg.max_disagreement
        {
            self.trip(RollbackReason::Disagreement, metrics);
        }
    }

    /// Record one |candidate − live| score delta and check the delta guard.
    /// A non-finite delta (a candidate emitting NaN/∞) is an automatic
    /// violation — `NaN > bound` is false, so it must not ride the
    /// comparison.
    pub(crate) fn note_delta(&self, delta: f32, metrics: &ServeMetrics) {
        self.stats.note_score_delta(delta);
        let d = f64::from(delta.abs());
        if !d.is_finite() || d > self.cfg.max_score_delta {
            self.trip(RollbackReason::ScoreDelta, metrics);
        }
    }

    /// Check the shadow-vs-live latency-ratio guard (needs enough samples
    /// of BOTH distributions to mean anything).
    pub(crate) fn check_shadow_latency(&self, metrics: &ServeMetrics) {
        let ratio = self.cfg.max_shadow_latency_ratio;
        if ratio <= 0.0 {
            return;
        }
        if self.stats.shadow_exec.count() < LATENCY_GUARD_MIN_SAMPLES
            || self.stats.live_exec.count() < LATENCY_GUARD_MIN_SAMPLES
        {
            return;
        }
        let shadow_p99 = self.stats.shadow_exec.quantile_ns(0.99) as f64;
        let live_p99 = (self.stats.live_exec.quantile_ns(0.99) as f64).max(1.0);
        if shadow_p99 / live_p99 > ratio {
            self.trip(RollbackReason::ShadowLatency, metrics);
        }
    }

    /// Check the absolute canary p99 guard.
    pub(crate) fn check_canary_latency(&self, metrics: &ServeMetrics) {
        let bound_us = self.cfg.canary_p99_bound_us;
        if bound_us == 0 || self.stats.canary_exec.count() < LATENCY_GUARD_MIN_SAMPLES {
            return;
        }
        if self.stats.canary_exec.quantile_ns(0.99) > bound_us.saturating_mul(1000) {
            self.trip(RollbackReason::CanaryLatency, metrics);
        }
    }

    /// Score `n` rows on the candidate's second stage, blocking — the
    /// canary serve path. `rows` is padded to `row_len`. Errors mean the
    /// candidate failed (panic or unresolvable version), never the
    /// incumbent.
    pub(crate) fn score_candidate(
        &self,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
        deadline: Option<Instant>,
    ) -> Result<(), String> {
        match &self.stage2 {
            CandidateStage2::Pool { pool, model, version, .. } => {
                let failed = pool.predict_spans_version(*model, *version, rows, row_len, out, deadline);
                if failed.is_empty() {
                    Ok(())
                } else {
                    Err(format!("candidate failed row spans {failed:?}"))
                }
            }
            CandidateStage2::Local { forest, scratch } => {
                let mut guard = scratch.lock().unwrap_or_else(PoisonError::into_inner);
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    forest.predict_flat_rows(rows, row_len, &mut guard, out);
                }));
                if r.is_err() {
                    // The panic may have left the scratch mid-traversal.
                    *guard = ForestScratch::default();
                    return Err("candidate panicked while scoring".into());
                }
                Ok(())
            }
        }
    }

    /// Shadow-score a sampled batch's route-missed rows on the candidate's
    /// second stage and compare against the live scores. Embedded mode
    /// enqueues a [`ShadowJob`] on the pool's lowest-priority queue (shed
    /// first under pressure); the local path scores inline. Either way the
    /// rows are billed to the shadow buckets, never to real traffic, and
    /// `shadow_rows + shadow_shed_rows` accounts every row passed in.
    pub(crate) fn shadow_score_misses(
        this: &Arc<Rollout>,
        rows: &[f32],
        row_len: usize,
        live_probs: Vec<f32>,
        live_wall_ns: u64,
        metrics: &Arc<ServeMetrics>,
    ) {
        let n = live_probs.len() as u64;
        if n == 0 {
            return;
        }
        match &this.stage2 {
            CandidateStage2::Pool { pool, model, version, .. } => {
                let ro = this.clone();
                let m = metrics.clone();
                let submitted = Instant::now();
                let deadline = Some(submitted + this.cfg.shadow_timeout);
                let job = ShadowJob::new(
                    *model,
                    *version,
                    rows.to_vec(),
                    row_len,
                    deadline,
                    move |outcome| {
                        ro.absorb_shadow_outcome(outcome, &live_probs, live_wall_ns, submitted, &m);
                    },
                );
                // A refused submit already delivered `Shed` through the
                // job's Drop — the callback accounted it.
                let _ = pool.submit_shadow(job);
            }
            CandidateStage2::Local { .. } => {
                let t0 = Instant::now();
                let mut out = vec![0f32; live_probs.len()];
                let outcome = match this.score_candidate(rows, row_len, &mut out, None) {
                    Ok(()) => ShadowOutcome::Scored(out),
                    Err(_) => ShadowOutcome::Failed,
                };
                this.absorb_shadow_outcome(outcome, &live_probs, live_wall_ns, t0, metrics);
            }
        }
    }

    /// Fold one shadow outcome into the monitor: scored rows compare and
    /// feed the guards; shed AND failed rows bill as shed (they produced
    /// no comparison), with failure additionally tripping the
    /// candidate-failure guard.
    fn absorb_shadow_outcome(
        &self,
        outcome: ShadowOutcome,
        live_probs: &[f32],
        live_wall_ns: u64,
        submitted: Instant,
        metrics: &ServeMetrics,
    ) {
        let n = live_probs.len() as u64;
        match outcome {
            ShadowOutcome::Scored(scores) => {
                self.stats.shadow_rows.fetch_add(n, Ordering::Relaxed);
                metrics.shadow_rows.fetch_add(n, Ordering::Relaxed);
                self.stats.shadow_exec.record_duration(submitted.elapsed());
                self.stats.live_exec.record(live_wall_ns);
                for (cand, live) in scores.iter().zip(live_probs) {
                    self.note_delta(cand - live, metrics);
                }
                self.check_shadow_latency(metrics);
            }
            ShadowOutcome::Shed => {
                self.stats.shadow_shed_rows.fetch_add(n, Ordering::Relaxed);
                metrics.shadow_shed_rows.fetch_add(n, Ordering::Relaxed);
            }
            ShadowOutcome::Failed => {
                self.stats.shadow_shed_rows.fetch_add(n, Ordering::Relaxed);
                metrics.shadow_shed_rows.fetch_add(n, Ordering::Relaxed);
                self.stats.candidate_failures.fetch_add(1, Ordering::Relaxed);
                self.trip(RollbackReason::CandidateFailure, metrics);
            }
        }
    }

    /// Book a successfully served canary batch.
    pub(crate) fn note_canary_batch(&self, rows: u64, wall_ns: u64, metrics: &ServeMetrics) {
        self.stats.canary_batches.fetch_add(1, Ordering::Relaxed);
        self.stats.canary_rows.fetch_add(rows, Ordering::Relaxed);
        metrics.canary_rows.fetch_add(rows, Ordering::Relaxed);
        self.stats.canary_exec.record(wall_ns);
        self.check_canary_latency(metrics);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_routing_is_deterministic_and_roughly_uniform() {
        // Same key ⇒ same slice membership, every time.
        for key in [0u64, 1, 42, u64::MAX, 0xDEAD_BEEF] {
            assert_eq!(splitmix64(key), splitmix64(key));
        }
        // ~p‰ of sequential keys land under the threshold.
        for permille in [10u64, 100, 500] {
            let hits = (0..100_000u64)
                .filter(|&k| splitmix64(k) % 1000 < permille)
                .count() as f64;
            let expect = 100.0 * permille as f64;
            assert!(
                (hits - expect).abs() < expect * 0.15 + 100.0,
                "permille={permille}: {hits} hits, expected ≈{expect}"
            );
        }
    }

    #[test]
    fn rollback_reason_roundtrips() {
        for r in [
            RollbackReason::Disagreement,
            RollbackReason::ScoreDelta,
            RollbackReason::ShadowLatency,
            RollbackReason::CanaryLatency,
            RollbackReason::CandidateFailure,
        ] {
            assert_eq!(RollbackReason::from_u8(r as u8), Some(r));
        }
        assert_eq!(RollbackReason::from_u8(0), None);
        assert_eq!(RollbackReason::from_u8(9), None);
    }
}
