//! Versioned zero-copy model snapshot: the deployment format of a trained
//! stack (stage-1 [`ServingTables`] + second-stage SoA [`FlatForest`]).
//!
//! # Why a binary format
//!
//! Serving a fleet means shipping retrained models under traffic. The JSON
//! config path re-parses and re-allocates every array; this format instead
//! lays the **already-flat** arena arrays out section-per-array in one
//! contiguous, 8-byte-aligned buffer, so a loaded snapshot serves the
//! forest **directly from the buffer** ([`Snapshot::forest_view`] →
//! [`ForestView`]) with no per-node rebuild — materializing an owned copy
//! ([`Snapshot::forest`]) is five `memcpy`s, and the whole file is
//! `mmap`-able by construction (every section offset is 8-aligned in a
//! buffer whose base is 8-aligned).
//!
//! # Layout (version 1, little-endian)
//!
//! | region        | bytes                 | contents                                   |
//! |---------------|-----------------------|--------------------------------------------|
//! | header        | 24                    | magic `LRWBSNAP`, version u32, n_sections u32, total_len u64 |
//! | section table | 32 × n_sections       | per section: tag u32, pad u32, offset u64, len u64, FNV-1a-64 checksum u64 |
//! | payloads      | —                     | raw array bytes, each offset 8-aligned     |
//!
//! One section per array (`META`, the nine table arrays, the five forest
//! arrays). `META` holds the scalars (`n_features`, `q_max`, `total_bins`,
//! `base_score`, the forest's `n_features`) as five u64 slots. Derived
//! state (`tiled_quantiles`, the dispatch tier) is never serialized — every
//! load rebuilds it through [`ServingTables::try_from_parts`].
//!
//! # The panic-free load contract
//!
//! [`Snapshot::parse`] is **fallible end to end** and validates in two
//! stages, both before any model-sized allocation:
//!
//! 1. **structural** — magic, version, section count, `total_len` against
//!    the real buffer length (truncation), every section's tag, 8-aligned
//!    offset, in-bounds `offset + len` (checked in u64 — an oversized
//!    length errors instead of allocating), element-size divisibility, and
//!    per-section checksum;
//! 2. **semantic** — the cross-array shape/index invariants, via
//!    [`TablePartsRef::validate`] and [`ForestView::validate`] over
//!    borrowed slices (zero-copy): feature ids in range, mixed-radix
//!    reachable-id bound, every child edge in-arena and forward (so walks
//!    terminate even on adversarial bytes).
//!
//! A `Snapshot` value therefore only exists for bytes that are safe to
//! serve. Corrupt input — truncated, bit-flipped, resized, hostile — gets
//! an `Err`, never a panic, never an out-of-bounds read, never an
//! attacker-sized allocation (`tests/snapshot_roundtrip.rs` fuzzes this).
//!
//! # Lifecycle wiring
//!
//! `lrwbins train` writes `<name>.snap` next to the JSON artifacts;
//! `lrwbins predict --snapshot` serves from it;
//! [`Coordinator::reload`](crate::coordinator::Coordinator::reload) swaps a
//! live coordinator (and its embedded [`ShardPool`] model, version-stamped,
//! two-version drain window) to a parsed snapshot between batches.

use crate::gbdt::flat::{FlatForest, ForestView};
use crate::lrwbins::tables::{ServingTables, TableParts, TablePartsRef};

/// File magic — first 8 bytes of every snapshot.
pub const MAGIC: [u8; 8] = *b"LRWBSNAP";
/// Format version this build writes and the only one it parses.
pub const VERSION: u32 = 1;

/// Header bytes: magic (8) + version (4) + n_sections (4) + total_len (8).
const HEADER_LEN: usize = 24;
/// Section-table entry bytes: tag (4) + pad (4) + offset (8) + len (8) +
/// checksum (8).
const ENTRY_LEN: usize = 32;

/// Section tags, in file order. The parser requires exactly this set, each
/// tag once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
enum Tag {
    Meta = 1,
    BinFeatures = 2,
    Quantiles = 3,
    Strides = 4,
    Means = 5,
    InvStds = 6,
    InferFeatures = 7,
    Weights = 8,
    GlobalWeights = 9,
    Route = 10,
    ForestFeat = 11,
    ForestThresh = 12,
    ForestLo = 13,
    ForestValue = 14,
    ForestRoots = 15,
}

/// Every section of a v1 snapshot, in file order.
const TAGS: [Tag; 15] = [
    Tag::Meta,
    Tag::BinFeatures,
    Tag::Quantiles,
    Tag::Strides,
    Tag::Means,
    Tag::InvStds,
    Tag::InferFeatures,
    Tag::Weights,
    Tag::GlobalWeights,
    Tag::Route,
    Tag::ForestFeat,
    Tag::ForestThresh,
    Tag::ForestLo,
    Tag::ForestValue,
    Tag::ForestRoots,
];

impl Tag {
    /// Element width of the section's payload (checked by the parser).
    fn elem_size(self) -> usize {
        match self {
            Tag::Meta | Tag::Means | Tag::InvStds => 8,
            Tag::Route => 1,
            _ => 4,
        }
    }

    fn from_u32(v: u32) -> Option<Tag> {
        TAGS.into_iter().find(|&t| t as u32 == v)
    }
}

/// u64 slots of the `META` section, in order.
const META_SLOTS: usize = 5;

/// FNV-1a 64 over a byte slice — the per-section checksum. Hand-rolled (no
/// external hashing deps); not cryptographic, exactly strong enough to
/// catch the corruption classes a deployment pipeline produces (truncated
/// copies, bit rot, concatenation mistakes).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Backing storage with a guaranteed 8-byte-aligned base: a `Vec<u64>`
/// viewed as bytes. Every section offset is 8-aligned, so reinterpreting a
/// section's bytes as `&[u32]`/`&[f32]`/`&[f64]` is always
/// alignment-correct.
struct AlignedBuf {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    fn from_bytes(bytes: &[u8]) -> AlignedBuf {
        let words = vec![0u64; bytes.len().div_ceil(8)];
        let mut buf = AlignedBuf { words, len: bytes.len() };
        // SAFETY: u64 → u8 reinterpretation is always valid (alignment 1,
        // no padding); the region is exactly the Vec's initialized storage.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(buf.words.as_mut_ptr() as *mut u8, buf.words.len() * 8)
        };
        dst[..bytes.len()].copy_from_slice(bytes);
        buf
    }

    fn bytes(&self) -> &[u8] {
        // SAFETY: as in `from_bytes`; `len <= words.len() * 8`.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }

    /// Reinterpret `len` bytes at `off` as a `T` slice. Caller guarantees
    /// (the parser checked) that the range is in bounds, `off` is 8-aligned
    /// and `len` divides by `size_of::<T>()`.
    fn typed<T: Copy>(&self, off: usize, len: usize) -> &[T] {
        let size = std::mem::size_of::<T>();
        debug_assert!(off % 8 == 0 && len % size == 0 && off + len <= self.len);
        // SAFETY: the base is 8-aligned (Vec<u64>) and off % 8 == 0, so the
        // pointer is aligned for any T with align <= 8; the range is in
        // bounds per the parser's checks; u32/f32/f64/u8 accept any bit
        // pattern.
        unsafe { std::slice::from_raw_parts(self.bytes().as_ptr().add(off) as *const T, len / size) }
    }
}

/// A parsed, fully validated snapshot: one contiguous aligned buffer plus
/// the resolved section ranges. Exists only for bytes that passed every
/// structural and semantic check — see the module docs.
pub struct Snapshot {
    buf: AlignedBuf,
    /// `(offset, len)` per tag, indexed by position in [`TAGS`].
    sect: [(usize, usize); TAGS.len()],
    /// Stage-1 row width.
    n_features: usize,
    q_max: usize,
    total_bins: u32,
    base_score: f64,
    forest_n_features: usize,
}

impl Snapshot {
    /// Serialize a trained stack. The inverse of [`Snapshot::parse`]:
    /// `parse(&write(t, f))` yields bit-identical arrays.
    pub fn write(tables: &ServingTables, forest: &FlatForest) -> Vec<u8> {
        let meta: [u64; META_SLOTS] = [
            tables.n_features as u64,
            tables.q_max as u64,
            tables.total_bins as u64,
            forest.base_score.to_bits(),
            forest.n_features as u64,
        ];
        let payloads: [Vec<u8>; TAGS.len()] = [
            meta.iter().flat_map(|v| v.to_le_bytes()).collect(),
            le_u32(&tables.bin_features),
            le_f32(&tables.quantiles),
            le_u32(&tables.strides),
            le_f64(&tables.means),
            le_f64(&tables.inv_stds),
            le_u32(&tables.infer_features),
            le_f32(&tables.weights),
            le_f32(&tables.global_weights),
            tables.route.clone(),
            le_u32(&forest.feat),
            le_f32(&forest.thresh),
            le_u32(&forest.lo),
            le_f32(&forest.value),
            le_u32(&forest.roots),
        ];
        // Layout pass: 8-aligned payload offsets after header + table.
        let table_end = HEADER_LEN + ENTRY_LEN * TAGS.len();
        let mut offsets = [0usize; TAGS.len()];
        let mut at = table_end;
        for (i, p) in payloads.iter().enumerate() {
            at = at.next_multiple_of(8);
            offsets[i] = at;
            at += p.len();
        }
        let total_len = at;

        let mut out = Vec::with_capacity(total_len);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(TAGS.len() as u32).to_le_bytes());
        out.extend_from_slice(&(total_len as u64).to_le_bytes());
        for (i, p) in payloads.iter().enumerate() {
            out.extend_from_slice(&(TAGS[i] as u32).to_le_bytes());
            out.extend_from_slice(&0u32.to_le_bytes());
            out.extend_from_slice(&(offsets[i] as u64).to_le_bytes());
            out.extend_from_slice(&(p.len() as u64).to_le_bytes());
            out.extend_from_slice(&fnv1a64(p).to_le_bytes());
        }
        for (i, p) in payloads.iter().enumerate() {
            out.resize(offsets[i], 0); // alignment padding
            out.extend_from_slice(p);
        }
        debug_assert_eq!(out.len(), total_len);
        out
    }

    /// Write a snapshot to a file.
    pub fn write_file(
        path: &std::path::Path,
        tables: &ServingTables,
        forest: &FlatForest,
    ) -> std::io::Result<()> {
        std::fs::write(path, Snapshot::write(tables, forest))
    }

    /// Read and [`Snapshot::parse`] a snapshot file.
    pub fn read_file(path: &std::path::Path) -> Result<Snapshot, String> {
        let bytes =
            std::fs::read(path).map_err(|e| format!("snapshot {}: {e}", path.display()))?;
        Snapshot::parse(&bytes).map_err(|e| format!("snapshot {}: {e}", path.display()))
    }

    /// Parse and fully validate snapshot bytes (one copy into an 8-aligned
    /// buffer; everything after is borrowed). See the module docs for the
    /// two validation stages and the panic-free contract.
    pub fn parse(bytes: &[u8]) -> Result<Snapshot, String> {
        // --- structural: header ---
        if bytes.len() < HEADER_LEN {
            return Err(format!("too short: {} bytes, header is {HEADER_LEN}", bytes.len()));
        }
        if bytes[..8] != MAGIC {
            return Err("bad magic (not a snapshot)".to_string());
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(format!("unsupported version {version} (this build reads {VERSION})"));
        }
        let n_sections = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        if n_sections != TAGS.len() {
            return Err(format!("expected {} sections, header says {n_sections}", TAGS.len()));
        }
        let total_len = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
        // Exact-length check catches truncation AND trailing garbage.
        if total_len != bytes.len() as u64 {
            return Err(format!(
                "length mismatch: header says {total_len} bytes, buffer is {}",
                bytes.len()
            ));
        }
        let table_end = HEADER_LEN + ENTRY_LEN * TAGS.len();
        if bytes.len() < table_end {
            return Err(format!("truncated inside the section table ({} bytes)", bytes.len()));
        }

        // --- structural: section table + checksums ---
        let buf = AlignedBuf::from_bytes(bytes);
        let b = buf.bytes();
        let mut sect = [(0usize, 0usize); TAGS.len()];
        let mut seen = [false; TAGS.len()];
        for e in 0..TAGS.len() {
            let at = HEADER_LEN + e * ENTRY_LEN;
            let raw_tag = u32::from_le_bytes(b[at..at + 4].try_into().unwrap());
            let tag = Tag::from_u32(raw_tag)
                .ok_or_else(|| format!("entry {e}: unknown section tag {raw_tag}"))?;
            let idx = TAGS.iter().position(|&t| t == tag).unwrap();
            if seen[idx] {
                return Err(format!("duplicate section {tag:?}"));
            }
            seen[idx] = true;
            let offset = u64::from_le_bytes(b[at + 8..at + 16].try_into().unwrap());
            let len = u64::from_le_bytes(b[at + 16..at + 24].try_into().unwrap());
            let checksum = u64::from_le_bytes(b[at + 24..at + 32].try_into().unwrap());
            if offset % 8 != 0 {
                return Err(format!("section {tag:?}: offset {offset} not 8-aligned"));
            }
            // u64 overflow-safe bound: an oversized len errors here, before
            // anything could allocate or index by it.
            let end = offset
                .checked_add(len)
                .ok_or_else(|| format!("section {tag:?}: offset + len overflows"))?;
            if offset < table_end as u64 || end > total_len {
                return Err(format!(
                    "section {tag:?}: bytes {offset}..{end} outside payload region \
                     {table_end}..{total_len}"
                ));
            }
            if len as usize % tag.elem_size() != 0 {
                return Err(format!(
                    "section {tag:?}: {len} bytes not a multiple of element size {}",
                    tag.elem_size()
                ));
            }
            let payload = &b[offset as usize..end as usize];
            let actual = fnv1a64(payload);
            if actual != checksum {
                return Err(format!(
                    "section {tag:?}: checksum mismatch (stored {checksum:#018x}, \
                     computed {actual:#018x})"
                ));
            }
            sect[idx] = (offset as usize, len as usize);
        }

        // --- semantic: META scalars ---
        let (moff, mlen) = sect[0];
        if mlen != META_SLOTS * 8 {
            return Err(format!("META must be {} bytes, got {mlen}", META_SLOTS * 8));
        }
        let meta: &[u64] = buf.typed(moff, mlen);
        let as_usize = |v: u64, what: &str| -> Result<usize, String> {
            usize::try_from(v).map_err(|_| format!("{what} {v} does not fit usize"))
        };
        let n_features = as_usize(meta[0], "n_features")?;
        let q_max = as_usize(meta[1], "q_max")?;
        let total_bins = u32::try_from(meta[2])
            .map_err(|_| format!("total_bins {} does not fit u32", meta[2]))?;
        let base_score = f64::from_bits(meta[3]);
        let forest_n_features = as_usize(meta[4], "forest n_features")?;

        let snap = Snapshot {
            buf,
            sect,
            n_features,
            q_max,
            total_bins,
            base_score,
            forest_n_features,
        };

        // --- semantic: table + forest invariants, over borrowed slices ---
        snap.table_parts_ref()
            .validate()
            .map_err(|e| format!("tables: {e}"))?;
        snap.forest_view().validate().map_err(|e| format!("forest: {e}"))?;
        Ok(snap)
    }

    /// Total buffer size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.buf.len
    }

    fn section<T: Copy>(&self, tag: Tag) -> &[T] {
        let idx = TAGS.iter().position(|&t| t == tag).unwrap();
        let (off, len) = self.sect[idx];
        self.buf.typed(off, len)
    }

    /// Borrowed view of the stage-1 table arrays (zero-copy).
    fn table_parts_ref(&self) -> TablePartsRef<'_> {
        TablePartsRef {
            n_features: self.n_features,
            bin_features: self.section(Tag::BinFeatures),
            quantiles: self.section(Tag::Quantiles),
            q_max: self.q_max,
            strides: self.section(Tag::Strides),
            total_bins: self.total_bins,
            means: self.section(Tag::Means),
            inv_stds: self.section(Tag::InvStds),
            infer_features: self.section(Tag::InferFeatures),
            weights: self.section(Tag::Weights),
            global_weights: self.section(Tag::GlobalWeights),
            route: self.section(Tag::Route),
        }
    }

    /// The forest served **directly from the snapshot buffer** — no owned
    /// arrays, no node rebuild. Valid by construction: [`Snapshot::parse`]
    /// ran [`ForestView::validate`] before this value could exist.
    pub fn forest_view(&self) -> ForestView<'_> {
        ForestView {
            feat: self.section(Tag::ForestFeat),
            thresh: self.section(Tag::ForestThresh),
            lo: self.section(Tag::ForestLo),
            value: self.section(Tag::ForestValue),
            roots: self.section(Tag::ForestRoots),
            base_score: self.base_score,
            n_features: self.forest_n_features,
        }
    }

    /// Materialize an owned forest (five `memcpy`s) — for consumers that
    /// outlive the snapshot, like [`ShardPool::swap`]
    /// (`crate::runtime::ShardPool::swap`).
    pub fn forest(&self) -> FlatForest {
        self.forest_view().materialize()
    }

    /// Materialize the stage-1 tables, finishing through
    /// [`ServingTables::try_from_parts`] (rebuilds the derived tiled
    /// quantiles and re-detects the kernel tier for THIS machine).
    pub fn tables(&self) -> Result<ServingTables, String> {
        let r = self.table_parts_ref();
        ServingTables::try_from_parts(TableParts {
            n_features: r.n_features,
            bin_features: r.bin_features.to_vec(),
            quantiles: r.quantiles.to_vec(),
            q_max: r.q_max,
            strides: r.strides.to_vec(),
            total_bins: r.total_bins,
            means: r.means.to_vec(),
            inv_stds: r.inv_stds.to_vec(),
            infer_features: r.infer_features.to_vec(),
            weights: r.weights.to_vec(),
            global_weights: r.global_weights.to_vec(),
            route: r.route.to_vec(),
        })
    }
}

fn le_u32(v: &[u32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn le_f32(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn le_f64(v: &[f64]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::{train, GbdtParams};
    use crate::lrwbins::{LrwBinsModel, LrwBinsParams};
    use crate::tabular::{Dataset, Schema};
    use crate::util::rng::Rng;

    fn stack(seed: u64) -> (ServingTables, FlatForest) {
        let mut rng = Rng::new(seed);
        let mut d = Dataset::new(Schema::numeric(5));
        for _ in 0..1500 {
            let x: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
            let y = (x[0] * x[1] + x[2] > 0.2) as u8 as f32;
            d.push_row(&x, y);
        }
        let m = LrwBinsModel::train(
            &d,
            &[0, 1, 2, 3, 4],
            &LrwBinsParams {
                b: 3,
                n_bin_features: 3,
                n_infer_features: 5,
                min_bin_rows: 20,
                ..Default::default()
            },
        );
        let g = train(&d, &GbdtParams { n_trees: 12, max_depth: 4, ..Default::default() });
        (ServingTables::from_model(&m), FlatForest::from_model(&g))
    }

    #[test]
    fn roundtrip_preserves_every_array_bitwise() {
        let (t, f) = stack(3);
        let bytes = Snapshot::write(&t, &f);
        let s = Snapshot::parse(&bytes).expect("own writer output parses");
        assert_eq!(s.size_bytes(), bytes.len());

        let t2 = s.tables().expect("tables materialize");
        assert_eq!(t, t2, "tables round-trip exactly");

        let f2 = s.forest();
        assert_eq!(f.feat, f2.feat);
        assert_eq!(f.lo, f2.lo);
        assert_eq!(f.roots, f2.roots);
        assert_eq!(f.base_score.to_bits(), f2.base_score.to_bits());
        assert_eq!(f.n_features, f2.n_features);
        for (a, b) in f.thresh.iter().zip(&f2.thresh) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in f.value.iter().zip(&f2.value) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // And the borrowed view is the same bits without materializing.
        let v = s.forest_view();
        assert_eq!(v.feat, &f.feat[..]);
        assert_eq!(v.n_nodes(), f.n_nodes());
    }

    #[test]
    fn parse_rejects_header_corruption() {
        let (t, f) = stack(4);
        let good = Snapshot::write(&t, &f);

        assert!(Snapshot::parse(&[]).unwrap_err().contains("too short"));
        assert!(Snapshot::parse(&good[..HEADER_LEN - 1]).unwrap_err().contains("too short"));

        let mut b = good.clone();
        b[0] ^= 0xff;
        assert!(Snapshot::parse(&b).unwrap_err().contains("magic"));

        let mut b = good.clone();
        b[8..12].copy_from_slice(&2u32.to_le_bytes());
        assert!(Snapshot::parse(&b).unwrap_err().contains("version"));

        let mut b = good.clone();
        b[12..16].copy_from_slice(&3u32.to_le_bytes());
        assert!(Snapshot::parse(&b).unwrap_err().contains("sections"));

        // Truncation and extension both fail the exact-length check.
        assert!(Snapshot::parse(&good[..good.len() - 1]).is_err());
        let mut b = good.clone();
        b.push(0);
        assert!(Snapshot::parse(&b).is_err());
    }

    #[test]
    fn parse_rejects_payload_corruption() {
        let (t, f) = stack(5);
        let good = Snapshot::write(&t, &f);
        let table_end = HEADER_LEN + ENTRY_LEN * TAGS.len();

        // A flipped payload byte must fail its section's checksum.
        let mut b = good.clone();
        let last = b.len() - 1;
        b[last] ^= 0x01;
        assert!(Snapshot::parse(&b).unwrap_err().contains("checksum"));

        // An oversized section length: clean Err, no huge allocation.
        let mut b = good.clone();
        b[HEADER_LEN + 16..HEADER_LEN + 24].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Snapshot::parse(&b).is_err());

        // An offset pointing before the payload region.
        let mut b = good.clone();
        b[HEADER_LEN + 8..HEADER_LEN + 16].copy_from_slice(&0u64.to_le_bytes());
        assert!(Snapshot::parse(&b).is_err());

        // A misaligned offset.
        let mut b = good;
        b[HEADER_LEN + 8..HEADER_LEN + 16]
            .copy_from_slice(&(table_end as u64 + 4).to_le_bytes());
        assert!(Snapshot::parse(&b).is_err());
    }

    #[test]
    fn parse_rejects_semantic_corruption_with_fixed_checksums() {
        // Corrupt an array VALUE (not its bytes-level framing), re-sign the
        // checksum so the structural pass accepts it, and require the
        // semantic validators to catch it.
        let (t, f) = stack(6);
        let good = Snapshot::write(&t, &f);

        // Find the ForestLo section entry and poison its first element with
        // a backward edge (index 0 → never a valid child of node 0).
        let mut b = good;
        let mut fixed = false;
        for e in 0..TAGS.len() {
            let at = HEADER_LEN + e * ENTRY_LEN;
            let tag = u32::from_le_bytes(b[at..at + 4].try_into().unwrap());
            if tag != Tag::ForestLo as u32 {
                continue;
            }
            let off = u64::from_le_bytes(b[at + 8..at + 16].try_into().unwrap()) as usize;
            let len = u64::from_le_bytes(b[at + 16..at + 24].try_into().unwrap()) as usize;
            b[off..off + 4].copy_from_slice(&0u32.to_le_bytes());
            let sum = fnv1a64(&b[off..off + len]);
            b[at + 24..at + 32].copy_from_slice(&sum.to_le_bytes());
            fixed = true;
        }
        assert!(fixed, "ForestLo section present");
        let err = Snapshot::parse(&b).unwrap_err();
        assert!(err.contains("forest"), "semantic validation must reject: {err}");
    }
}
