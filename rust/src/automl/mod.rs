//! AutoML — "crucial to the success of multistage inference" (paper §4).
//!
//! Three tasks, exactly as the paper enumerates:
//! 1. **Shape search**: choose `(b, n)` — quantiles per feature and number
//!    of binning features — by validation ROC AUC (Figure 4's grid).
//! 2. **Per-bin model tuning**: per-combined-bin L2 strength, falling back
//!    to the bin prior when LR does not validate better.
//! 3. **Stage balancing**: pick the Algorithm-2 tolerance/coverage point.
//!
//! Search is a seeded grid with successive-halving on rows for the expensive
//! configs (full data only for finalists).

use crate::allocation::{self, Allocation, Metric};
use crate::features::Ranking;
use crate::gbdt::GbdtModel;
use crate::lr::LrParams;
use crate::lrwbins::{LrwBinsModel, LrwBinsParams};
use crate::metrics::roc_auc;
use crate::tabular::Dataset;

/// One evaluated cell of the (b, n) grid — Figure 4 data point.
#[derive(Clone, Debug)]
pub struct ShapeCell {
    pub b: usize,
    pub n_bin_features: usize,
    pub val_auc: f64,
    pub total_bins: u32,
}

/// Result of the shape search.
#[derive(Clone, Debug)]
pub struct ShapeSearch {
    pub cells: Vec<ShapeCell>,
    pub best: LrwBinsParams,
}

/// Search space bounds.
#[derive(Clone, Debug)]
pub struct ShapeSpace {
    pub bs: Vec<usize>,
    pub ns: Vec<usize>,
    pub n_infer_features: usize,
    /// Skip configs whose combined-bin space exceeds this.
    pub max_total_bins: u32,
    /// Rows used for the cheap screening pass (full data for finalists).
    pub screen_rows: usize,
}

impl Default for ShapeSpace {
    fn default() -> Self {
        ShapeSpace {
            bs: vec![2, 3, 4, 5],
            ns: vec![3, 4, 5, 6, 7, 8],
            n_infer_features: 20,
            max_total_bins: 1 << 14,
            screen_rows: 30_000,
        }
    }
}

/// AutoML task (i): grid over (b, n) with successive halving.
pub fn shape_search(
    train: &Dataset,
    val: &Dataset,
    ranking: &Ranking,
    space: &ShapeSpace,
) -> ShapeSearch {
    let screen_train = train.head(space.screen_rows);
    let mut cells = Vec::new();

    for &b in &space.bs {
        for &n in &space.ns {
            let n = n.min(ranking.order.len());
            // Pre-check bin-space size cheaply: upper bound b^n adjusted for
            // boolean/categorical types.
            let mut upper: u64 = 1;
            for &f in &ranking.order[..n] {
                let per = match train.schema.types[f] {
                    crate::tabular::ColType::Boolean => 2,
                    crate::tabular::ColType::Categorical { cardinality } => cardinality as u64,
                    crate::tabular::ColType::Numeric => b as u64,
                };
                upper = upper.saturating_mul(per);
            }
            if upper > space.max_total_bins as u64 {
                continue;
            }
            let params = LrwBinsParams {
                b,
                n_bin_features: n,
                n_infer_features: space.n_infer_features.min(ranking.order.len()),
                ..Default::default()
            };
            let model = LrwBinsModel::train(&screen_train, &ranking.order, &params);
            let auc = roc_auc(&model.predict_proba(val), &val.labels);
            cells.push(ShapeCell {
                b,
                n_bin_features: n,
                val_auc: auc,
                total_bins: model.binner.total_bins,
            });
        }
    }
    assert!(!cells.is_empty(), "shape search space exhausted (all too big)");

    // Finalists: top 3 on screening data, re-evaluated on full train.
    let mut order: Vec<usize> = (0..cells.len()).collect();
    order.sort_by(|&a, &z| cells[z].val_auc.partial_cmp(&cells[a].val_auc).unwrap());
    let finalists = &order[..order.len().min(3)];

    let mut best_idx = finalists[0];
    if screen_train.n_rows() < train.n_rows() {
        let mut best_auc = f64::NEG_INFINITY;
        for &i in finalists {
            let params = LrwBinsParams {
                b: cells[i].b,
                n_bin_features: cells[i].n_bin_features,
                n_infer_features: space.n_infer_features.min(ranking.order.len()),
                ..Default::default()
            };
            let model = LrwBinsModel::train(train, &ranking.order, &params);
            let auc = roc_auc(&model.predict_proba(val), &val.labels);
            if auc > best_auc {
                best_auc = auc;
                best_idx = i;
            }
        }
    }

    let best = LrwBinsParams {
        b: cells[best_idx].b,
        n_bin_features: cells[best_idx].n_bin_features,
        n_infer_features: space.n_infer_features.min(ranking.order.len()),
        ..Default::default()
    };
    ShapeSearch { cells, best }
}

/// AutoML task (ii): per-bin L2 tuning. Retrains each bin's LR at several
/// regularization strengths and keeps the best by validation log-loss on
/// that bin; falls back to the prior when nothing beats it.
pub fn tune_per_bin(
    model: &mut LrwBinsModel,
    train: &Dataset,
    val: &Dataset,
    l2_grid: &[f64],
) {
    let norm_train = model.normalizer.apply(train);
    let norm_val = model.normalizer.apply(val);
    let train_ids = model.binner.bin_dataset(&norm_train);
    let val_ids = model.binner.bin_dataset(&norm_val);

    // Group validation rows per bin.
    let mut val_groups: std::collections::HashMap<u32, Vec<usize>> = Default::default();
    for (r, &b) in val_ids.iter().enumerate() {
        val_groups.entry(b).or_default().push(r);
    }
    let mut train_groups: std::collections::HashMap<u32, Vec<usize>> = Default::default();
    for (r, &b) in train_ids.iter().enumerate() {
        train_groups.entry(b).or_default().push(r);
    }

    let infer = model.infer_features.clone();
    let bins: Vec<u32> = model.weights.keys().copied().collect();
    for bin in bins {
        let Some(vrows) = val_groups.get(&bin) else { continue };
        if vrows.len() < 10 {
            continue;
        }
        let Some(trows) = train_groups.get(&bin) else { continue };
        if trows.len() < 20 {
            continue;
        }
        let sub_train = norm_train.take_rows(trows);
        let sub_val = norm_val.take_rows(vrows);
        let mut best = model.weights[&bin].clone();
        let mut best_ll = {
            let preds = crate::lr::predict_dataset(&best, &sub_val, &infer);
            crate::metrics::log_loss(&preds, &sub_val.labels)
        };
        for &l2 in l2_grid {
            let cand = crate::lr::fit_dataset(
                &sub_train,
                &infer,
                &LrParams { l2, ..Default::default() },
            );
            let preds = crate::lr::predict_dataset(&cand, &sub_val, &infer);
            let ll = crate::metrics::log_loss(&preds, &sub_val.labels);
            if ll < best_ll {
                best_ll = ll;
                best = cand;
            }
        }
        // Prior fallback.
        let prior = crate::lr::LrModel::prior(sub_train.positive_rate(), infer.len());
        let prior_ll = {
            let preds = crate::lr::predict_dataset(&prior, &sub_val, &infer);
            crate::metrics::log_loss(&preds, &sub_val.labels)
        };
        if prior_ll < best_ll {
            best = prior;
        }
        model.weights.insert(bin, best);
    }
}

/// AutoML task (iii): stage balancing — run Algorithm 2 at the requested
/// tolerance (optionally trying to reach a coverage target by relaxing the
/// tolerance up to `max_tolerance`).
pub fn balance_stages(
    model: &mut LrwBinsModel,
    second: &GbdtModel,
    val: &Dataset,
    metric: Metric,
    tolerance: f64,
    coverage_target: Option<f64>,
    max_tolerance: f64,
) -> Allocation {
    let mut tol = tolerance;
    let mut alloc = allocation::allocate_and_route(model, second, val, metric, tol);
    if let Some(target) = coverage_target {
        while alloc.coverage < target && tol < max_tolerance {
            tol = (tol * 2.0).min(max_tolerance);
            alloc = allocation::allocate_and_route(model, second, val, metric, tol);
            if tol >= max_tolerance {
                break;
            }
        }
    }
    alloc
}

/// Full AutoML-configured multistage pipeline: rank → shape search → train →
/// per-bin tune → second-stage train → balance. This is the one-call API
/// the launcher and the examples use.
pub struct Pipeline {
    pub ranking: Ranking,
    pub shape: ShapeSearch,
    pub first: LrwBinsModel,
    pub second: GbdtModel,
    pub allocation: Allocation,
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub shape_space: ShapeSpace,
    pub gbdt: crate::gbdt::GbdtParams,
    pub metric: Metric,
    pub tolerance: f64,
    pub coverage_target: Option<f64>,
    pub max_tolerance: f64,
    pub per_bin_l2_grid: Vec<f64>,
    pub rank_method: crate::features::RankMethod,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            shape_space: ShapeSpace::default(),
            gbdt: crate::gbdt::GbdtParams::default(),
            metric: Metric::Accuracy,
            tolerance: 0.002,
            coverage_target: Some(0.5),
            max_tolerance: 0.02,
            per_bin_l2_grid: vec![0.1, 1.0, 10.0],
            rank_method: crate::features::RankMethod::GbdtGain,
            seed: 7,
        }
    }
}

impl PipelineConfig {
    /// Small/fast settings for tests and quick benches.
    pub fn quick() -> Self {
        PipelineConfig {
            shape_space: ShapeSpace {
                bs: vec![2, 3],
                ns: vec![2, 3, 4],
                n_infer_features: 8,
                screen_rows: 5_000,
                ..Default::default()
            },
            gbdt: crate::gbdt::GbdtParams::quick(),
            per_bin_l2_grid: vec![1.0],
            ..Default::default()
        }
    }
}

/// Run the full pipeline on a train/val pair.
pub fn run_pipeline(train: &Dataset, val: &Dataset, cfg: &PipelineConfig) -> Pipeline {
    let ranking = crate::features::rank_features(train, cfg.rank_method, cfg.seed);
    let shape = shape_search(train, val, &ranking, &cfg.shape_space);
    let mut first = LrwBinsModel::train(train, &ranking.order, &shape.best);
    if !cfg.per_bin_l2_grid.is_empty() {
        tune_per_bin(&mut first, train, val, &cfg.per_bin_l2_grid);
    }
    let second = crate::gbdt::train(train, &cfg.gbdt);
    let allocation = balance_stages(
        &mut first,
        &second,
        val,
        cfg.metric,
        cfg.tolerance,
        cfg.coverage_target,
        cfg.max_tolerance,
    );
    Pipeline {
        ranking,
        shape,
        first,
        second,
        allocation,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tabular::{split, Schema};
    use crate::util::rng::Rng;

    fn world(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut d = Dataset::new(Schema::numeric(6));
        for _ in 0..n {
            let x: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
            let q = (x[0] > 0.0) as usize;
            let z = if q == 1 {
                2.0 * x[1] as f64 - x[2] as f64
            } else {
                -1.5 * x[1] as f64 + 2.0 * x[3] as f64
            };
            d.push_row(&x, rng.bool(crate::util::sigmoid(z)) as u8 as f32);
        }
        d
    }

    #[test]
    fn pipeline_end_to_end() {
        let d = world(8000, 1);
        let mut rng = Rng::new(2);
        let s = split::three_way_split(&d, (0.6, 0.2, 0.2), &mut rng);
        let p = run_pipeline(&s.train, &s.val, &PipelineConfig::quick());

        // Shape search produced a grid and a best config.
        assert!(!p.shape.cells.is_empty());
        assert!(p.shape.best.b >= 2);

        // Allocation routes something and stays within tolerance bounds.
        assert!(p.allocation.coverage > 0.0, "coverage={}", p.allocation.coverage);
        // Hybrid on test beats chance.
        let test_auc = {
            let mut preds = Vec::new();
            let mut row = Vec::new();
            for r in 0..s.test.n_rows() {
                s.test.row_into(r, &mut row);
                let pr = match p.first.stage1(&row) {
                    crate::lrwbins::Stage1::Hit(pr) => pr,
                    crate::lrwbins::Stage1::Miss { .. } => p.second.predict_one(&row),
                };
                preds.push(pr);
            }
            crate::metrics::roc_auc(&preds, &s.test.labels)
        };
        assert!(test_auc > 0.65, "test_auc={test_auc}");
    }

    #[test]
    fn shape_search_respects_bin_cap() {
        let d = world(3000, 3);
        let ranking = crate::features::rank_features(&d, crate::features::RankMethod::GbdtGain, 1);
        let space = ShapeSpace {
            bs: vec![5],
            ns: vec![2, 8],
            max_total_bins: 30, // 5^2=25 ok, 5^8 skipped
            screen_rows: 2000,
            n_infer_features: 6,
        };
        let s = shape_search(&d, &d, &ranking, &space);
        assert!(s.cells.iter().all(|c| c.total_bins <= 30));
        assert_eq!(s.best.b, 5);
        assert_eq!(s.best.n_bin_features, 2);
    }

    #[test]
    fn balance_relaxes_toward_target() {
        let d = world(6000, 4);
        let mut rng = Rng::new(5);
        let s = split::three_way_split(&d, (0.6, 0.2, 0.2), &mut rng);
        let ranking = crate::features::rank_features(&s.train, crate::features::RankMethod::GbdtGain, 1);
        let params = LrwBinsParams {
            b: 2,
            n_bin_features: 3,
            n_infer_features: 6,
            ..Default::default()
        };
        let mut first = LrwBinsModel::train(&s.train, &ranking.order, &params);
        let second = crate::gbdt::train(&s.train, &crate::gbdt::GbdtParams::quick());
        let tight = balance_stages(&mut first, &second, &s.val, Metric::Accuracy, 1e-6, None, 1e-6);
        let relaxed = balance_stages(
            &mut first,
            &second,
            &s.val,
            Metric::Accuracy,
            1e-6,
            Some(0.8),
            0.05,
        );
        assert!(relaxed.coverage >= tight.coverage);
    }

    #[test]
    fn per_bin_tuning_never_hurts_val_logloss() {
        let d = world(5000, 6);
        let mut rng = Rng::new(7);
        let s = split::train_test_split(&d, 0.3, &mut rng);
        let ranking = crate::features::rank_features(&s.train, crate::features::RankMethod::GbdtGain, 1);
        let params = LrwBinsParams {
            b: 2,
            n_bin_features: 3,
            n_infer_features: 6,
            ..Default::default()
        };
        let mut m = LrwBinsModel::train(&s.train, &ranking.order, &params);
        let before = crate::metrics::log_loss(&m.predict_proba(&s.test), &s.test.labels);
        tune_per_bin(&mut m, &s.train, &s.test, &[0.1, 1.0, 10.0]);
        let after = crate::metrics::log_loss(&m.predict_proba(&s.test), &s.test.labels);
        assert!(after <= before + 1e-9, "before={before} after={after}");
    }
}
