//! Logistic regression trained by IRLS (Newton–Raphson), the first-stage
//! building block.
//!
//! The paper's key constraint is that *inference* must be trivially
//! embeddable (`h(x) = 1/(1+e^{-θᵀx})`) while *training* may use full ML
//! machinery (§2, tradeoff 1). IRLS with L2 regularization converges to the
//! unique optimum of the convex objective in a handful of iterations; per-bin
//! problems are tiny so Newton is both the fastest and the most accurate
//! option.

use crate::linalg::{solve_spd, Mat};
use crate::util::sigmoid;

/// Trained LR model: `p = sigmoid(w·x + b)`. Weights are f32 so the
/// embedded table matches the PJRT artifact exactly (paper §4 stores the LR
/// weight map as 32-bit floats).
#[derive(Clone, Debug, PartialEq)]
pub struct LrModel {
    pub weights: Vec<f32>,
    pub bias: f32,
}

impl LrModel {
    /// Prior-only model (used for bins whose data is single-class or too
    /// small to fit).
    pub fn prior(pos_rate: f64, n_features: usize) -> LrModel {
        let p = pos_rate.clamp(1e-4, 1.0 - 1e-4);
        LrModel {
            weights: vec![0.0; n_features],
            bias: (p / (1.0 - p)).ln() as f32,
        }
    }

    /// Predicted probability for one row.
    #[inline]
    pub fn predict_one(&self, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.weights.len());
        let mut z = self.bias as f64;
        for (w, v) in self.weights.iter().zip(x) {
            z += *w as f64 * *v as f64;
        }
        sigmoid(z) as f32
    }

    /// Predict probabilities for row-major data.
    pub fn predict(&self, xs: &[f32], n_features: usize) -> Vec<f32> {
        xs.chunks_exact(n_features)
            .map(|row| self.predict_one(row))
            .collect()
    }
}

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct LrParams {
    /// L2 regularization strength (not applied to the bias).
    pub l2: f64,
    pub max_iter: usize,
    /// Stop when max |Δw| < tol.
    pub tol: f64,
}

impl Default for LrParams {
    fn default() -> Self {
        LrParams {
            l2: 1.0,
            max_iter: 25,
            tol: 1e-8,
        }
    }
}

/// Fit LR on row-major features `xs` (n_rows × n_features) and labels.
/// Always returns a usable model: degenerate inputs fall back to the prior.
pub fn fit(xs: &[f32], n_features: usize, labels: &[f32], params: &LrParams) -> LrModel {
    let n = labels.len();
    debug_assert_eq!(xs.len(), n * n_features);
    let pos_rate = labels.iter().map(|&y| y as f64).sum::<f64>() / n.max(1) as f64;
    if n == 0 || pos_rate == 0.0 || pos_rate == 1.0 {
        return LrModel::prior(pos_rate, n_features);
    }
    let d = n_features + 1; // weights + bias
    let mut theta = vec![0.0f64; d];
    theta[n_features] = (pos_rate / (1.0 - pos_rate)).ln(); // warm-start bias

    let mut p = vec![0.0f64; n];
    for _ in 0..params.max_iter {
        // Predictions.
        for (r, pr) in p.iter_mut().enumerate() {
            let row = &xs[r * n_features..(r + 1) * n_features];
            let mut z = theta[n_features];
            for (j, &v) in row.iter().enumerate() {
                z += theta[j] * v as f64;
            }
            *pr = sigmoid(z);
        }
        // Gradient g = Xᵀ(p - y) + λw ; Hessian H = XᵀWX + λI.
        let mut g = vec![0.0f64; d];
        let mut h = Mat::zeros(d);
        for r in 0..n {
            let row = &xs[r * n_features..(r + 1) * n_features];
            let e = p[r] - labels[r] as f64;
            let w = (p[r] * (1.0 - p[r])).max(1e-10);
            for j in 0..n_features {
                let xj = row[j] as f64;
                g[j] += e * xj;
                for k in j..n_features {
                    *h.at_mut(j, k) += w * xj * row[k] as f64;
                }
                *h.at_mut(j, n_features) += w * xj;
            }
            g[n_features] += e;
            *h.at_mut(n_features, n_features) += w;
        }
        // L2 on weights only.
        for j in 0..n_features {
            g[j] += params.l2 * theta[j];
            *h.at_mut(j, j) += params.l2;
        }
        // Mirror to lower triangle.
        for j in 0..d {
            for k in (j + 1)..d {
                let v = h.at(j, k);
                *h.at_mut(k, j) = v;
            }
        }
        let Some(step) = solve_spd(h, &g) else {
            break; // keep current theta
        };
        let mut max_delta = 0.0f64;
        for (t, s) in theta.iter_mut().zip(&step) {
            *t -= s;
            max_delta = max_delta.max(s.abs());
        }
        // Clamp runaway weights (quasi-separable bins).
        for t in theta.iter_mut() {
            *t = t.clamp(-30.0, 30.0);
        }
        if max_delta < params.tol {
            break;
        }
    }
    LrModel {
        weights: theta[..n_features].iter().map(|&w| w as f32).collect(),
        bias: theta[n_features] as f32,
    }
}

/// Fit on a Dataset restricted to `feature_idx` columns.
pub fn fit_dataset(
    data: &crate::tabular::Dataset,
    feature_idx: &[usize],
    params: &LrParams,
) -> LrModel {
    let n = data.n_rows();
    let nf = feature_idx.len();
    let mut xs = vec![0f32; n * nf];
    for (j, &f) in feature_idx.iter().enumerate() {
        let col = &data.cols[f];
        for r in 0..n {
            xs[r * nf + j] = col[r];
        }
    }
    fit(&xs, nf, &data.labels, params)
}

/// Predict for a Dataset restricted to `feature_idx`.
pub fn predict_dataset(
    model: &LrModel,
    data: &crate::tabular::Dataset,
    feature_idx: &[usize],
) -> Vec<f32> {
    let n = data.n_rows();
    let mut out = Vec::with_capacity(n);
    let mut row = vec![0f32; feature_idx.len()];
    for r in 0..n {
        for (j, &f) in feature_idx.iter().enumerate() {
            row[j] = data.cols[f][r];
        }
        out.push(model.predict_one(&row));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::roc_auc;
    use crate::util::rng::Rng;

    /// Generate linearly-separable-ish data: y ~ Bernoulli(sigmoid(w·x)).
    fn synth(n: usize, w: &[f64], bias: f64, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let d = w.len();
        let mut xs = Vec::with_capacity(n * d);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let mut z = bias;
            for &wj in w {
                let x = rng.normal();
                xs.push(x as f32);
                z += wj * x;
            }
            ys.push(rng.bool(sigmoid(z)) as u8 as f32);
        }
        (xs, ys)
    }

    #[test]
    fn recovers_generating_weights() {
        let w_true = [2.0, -1.5, 0.5];
        let (xs, ys) = synth(20_000, &w_true, 0.3, 1);
        let m = fit(&xs, 3, &ys, &LrParams { l2: 0.01, ..Default::default() });
        for (j, &wt) in w_true.iter().enumerate() {
            assert!(
                (m.weights[j] as f64 - wt).abs() < 0.15,
                "w[{j}]={} true={wt}",
                m.weights[j]
            );
        }
        assert!((m.bias as f64 - 0.3).abs() < 0.15, "bias={}", m.bias);
    }

    #[test]
    fn auc_beats_chance_strongly() {
        let (xs, ys) = synth(5_000, &[1.0, 1.0], 0.0, 2);
        let m = fit(&xs, 2, &ys, &LrParams::default());
        let preds = m.predict(&xs, 2);
        assert!(roc_auc(&preds, &ys) > 0.75);
    }

    #[test]
    fn single_class_gives_prior() {
        let xs = vec![1.0f32, 2.0, 3.0, 4.0];
        let ys = vec![1.0f32, 1.0];
        let m = fit(&xs, 2, &ys, &LrParams::default());
        assert!(m.weights.iter().all(|&w| w == 0.0));
        assert!(m.predict_one(&[0.0, 0.0]) > 0.99);
    }

    #[test]
    fn empty_input_safe() {
        let m = fit(&[], 3, &[], &LrParams::default());
        assert_eq!(m.weights.len(), 3);
        assert!(m.bias.is_finite());
    }

    #[test]
    fn separable_data_clamped_not_nan() {
        // Perfectly separable: weights would diverge without clamping/L2.
        let xs = vec![-1.0f32, -2.0, -3.0, 1.0, 2.0, 3.0];
        let ys = vec![0.0f32, 0.0, 0.0, 1.0, 1.0, 1.0];
        let m = fit(&xs, 1, &ys, &LrParams { l2: 1e-6, ..Default::default() });
        assert!(m.weights[0].is_finite());
        assert!(m.predict_one(&[3.0]) > 0.9);
        assert!(m.predict_one(&[-3.0]) < 0.1);
    }

    #[test]
    fn l2_shrinks_weights() {
        let (xs, ys) = synth(2_000, &[2.0], 0.0, 3);
        let loose = fit(&xs, 1, &ys, &LrParams { l2: 0.01, ..Default::default() });
        let tight = fit(&xs, 1, &ys, &LrParams { l2: 100.0, ..Default::default() });
        assert!(tight.weights[0].abs() < loose.weights[0].abs());
    }

    #[test]
    fn dataset_roundtrip_matches_flat() {
        use crate::tabular::{Dataset, Schema};
        let (xs, ys) = synth(500, &[1.0, -1.0], 0.1, 4);
        let mut d = Dataset::new(Schema::numeric(2));
        for (row, &y) in xs.chunks_exact(2).zip(&ys) {
            d.push_row(row, y);
        }
        let m1 = fit(&xs, 2, &ys, &LrParams::default());
        let m2 = fit_dataset(&d, &[0, 1], &LrParams::default());
        assert_eq!(m1, m2);
        let p1 = m1.predict(&xs, 2);
        let p2 = predict_dataset(&m2, &d, &[0, 1]);
        assert_eq!(p1, p2);
    }

    #[test]
    fn prior_model_matches_rate() {
        let m = LrModel::prior(0.25, 2);
        assert!((m.predict_one(&[5.0, -3.0]) - 0.25).abs() < 1e-5);
    }
}
