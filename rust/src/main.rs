//! `lrwbins` — launcher for the multistage-inference framework.
//!
//! Subcommands:
//!   datagen   generate a synthetic dataset clone to CSV
//!   train     run the AutoML pipeline, write serving tables + GBDT model
//!             (JSON pair + one binary `.snap` zero-copy snapshot)
//!   serve     start the full serving stack and run a live workload
//!   eval      Table-1-style evaluation of LR / LRwBins / GBDT on a preset
//!   predict   score a CSV with saved model files (JSON pair, or a binary
//!             snapshot via --snapshot)
//!   rollout   guarded model-rollout drill: shadow → canary ramp → promote,
//!             or divergence-triggered automatic rollback
//!   fig5      Picasso feature map (SVG + terminal rendering)
//!   info      print artifact manifest + compiled batch variants

use lrwbins::automl::PipelineConfig;
use lrwbins::coordinator::Mode;
use lrwbins::datagen;
use lrwbins::features::{rank_features, RankMethod};
use lrwbins::harness::{self, StackConfig};
use lrwbins::lrwbins::ServingTables;
use lrwbins::metrics::{accuracy, roc_auc};
use lrwbins::tabular::split;
use lrwbins::util::cli::Cli;
use lrwbins::util::rng::Rng;

fn main() {
    let sub = std::env::args().nth(1).unwrap_or_default();
    let code = match sub.as_str() {
        "datagen" => cmd_datagen(),
        "train" => cmd_train(),
        "serve" => cmd_serve(),
        "eval" => cmd_eval(),
        "predict" => cmd_predict(),
        "rollout" => cmd_rollout(),
        "fig5" => cmd_fig5(),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: lrwbins <datagen|train|serve|eval|rollout|fig5|info> [options]\n\
                 Run `lrwbins <subcommand> --help` for options."
            );
            2
        }
    };
    std::process::exit(code);
}

fn cmd_datagen() -> i32 {
    let args = Cli::new("lrwbins datagen", "generate a synthetic dataset clone to CSV")
        .opt("name", "preset name (case1..case4, aci, blastchar, shrutime, patient, banknote, jasmine, higgs)", Some("aci"))
        .opt("rows", "row count override (0 = preset size)", Some("0"))
        .opt("seed", "sampling seed", Some("1"))
        .opt("out", "output CSV path", Some("data/dataset.csv"))
        .parse_subcommand();
    let name = args.get_or("name", "aci");
    let Some(mut spec) = datagen::preset(&name) else {
        eprintln!("unknown preset '{name}'; options: {}", datagen::PRESET_NAMES.join(", "));
        return 2;
    };
    let rows = args.get_usize("rows", 0);
    if rows > 0 {
        spec = spec.with_rows(rows);
    }
    let data = datagen::generate(&spec, args.get_u64("seed", 1));
    let out = std::path::PathBuf::from(args.get_or("out", "data/dataset.csv"));
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match lrwbins::tabular::csv::write_csv(&data, &out) {
        Ok(()) => {
            println!(
                "wrote {} rows × {} features (pos rate {:.3}) to {}",
                data.n_rows(),
                data.n_features(),
                data.positive_rate(),
                out.display()
            );
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

fn cmd_train() -> i32 {
    let args = Cli::new("lrwbins train", "run the AutoML multistage pipeline and save model files")
        .opt("name", "dataset preset", Some("aci"))
        .opt("data", "train from a CSV file instead of a preset (label column required)", None)
        .opt("rows", "row cap (0 = preset size)", Some("0"))
        .opt("seed", "seed", Some("1"))
        .opt("tolerance", "metric-loss tolerance for Algorithm 2", Some("0.002"))
        .opt("coverage", "coverage target (0 disables)", Some("0.5"))
        .opt("out-dir", "output directory", Some("data"))
        .flag("quick", "small/fast AutoML settings")
        .parse_subcommand();
    let seed = args.get_u64("seed", 1);
    let (name, data) = if let Some(path) = args.get("data") {
        let data = match lrwbins::tabular::csv::read_csv(std::path::Path::new(path)) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("reading {path}: {e}");
                return 1;
            }
        };
        let stem = std::path::Path::new(path)
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_else(|| "model".into());
        (stem, data)
    } else {
        let name = args.get_or("name", "aci");
        let Some(mut spec) = datagen::preset(&name) else {
            eprintln!("unknown preset '{name}'");
            return 2;
        };
        let rows = args.get_usize("rows", 0);
        if rows > 0 {
            spec = spec.with_rows(rows);
        }
        (name.clone(), datagen::generate(&spec, seed))
    };
    let mut rng = Rng::new(seed ^ 0xABCD);
    let s = split::three_way_split(&data, (0.6, 0.2, 0.2), &mut rng);

    let mut cfg = if args.flag("quick") {
        PipelineConfig::quick()
    } else {
        PipelineConfig::default()
    };
    cfg.tolerance = args.get_f64("tolerance", 0.002);
    let cov = args.get_f64("coverage", 0.5);
    cfg.coverage_target = if cov > 0.0 { Some(cov) } else { None };

    println!("training multistage pipeline on {name} ({} rows)...", s.train.n_rows());
    let t0 = std::time::Instant::now();
    let p = lrwbins::automl::run_pipeline(&s.train, &s.val, &cfg);
    println!(
        "  shape search: b={} n={} ({} cells); coverage={:.1}%  ΔAUC={:.4}  ΔACC={:.4}  [{:.1}s]",
        p.shape.best.b,
        p.shape.best.n_bin_features,
        p.shape.cells.len(),
        p.allocation.coverage * 100.0,
        p.allocation.stage2_auc - p.allocation.auc,
        p.allocation.stage2_accuracy - p.allocation.accuracy,
        t0.elapsed().as_secs_f64()
    );

    // Test-set report.
    let lrw = p.first.predict_proba(&s.test);
    let gbd = p.second.predict_proba(&s.test);
    println!(
        "  test: LRwBins auc={:.3} acc={:.3} | GBDT auc={:.3} acc={:.3}",
        roc_auc(&lrw, &s.test.labels),
        accuracy(&lrw, &s.test.labels),
        roc_auc(&gbd, &s.test.labels),
        accuracy(&gbd, &s.test.labels)
    );

    let out_dir = std::path::PathBuf::from(args.get_or("out-dir", "data"));
    std::fs::create_dir_all(&out_dir).ok();
    let tables = ServingTables::from_model(&p.first);
    let (qb, wb) = p.first.config_size_bytes();
    std::fs::write(out_dir.join(format!("{name}.tables.json")), tables.to_json().pretty()).unwrap();
    std::fs::write(out_dir.join(format!("{name}.gbdt.json")), p.second.to_json().to_string()).unwrap();
    println!(
        "  wrote {0}/{name}.tables.json ({qb} B quantiles + {wb} B weights sparse) and {0}/{name}.gbdt.json",
        out_dir.display()
    );
    // Binary snapshot: the production load path — both stages in one
    // checksummed buffer, served zero-copy by `lrwbins predict --snapshot`
    // and `snapshot_path` in a serve config.
    let snap = lrwbins::snapshot::Snapshot::write(&tables, &p.second.flatten());
    let snap_path = out_dir.join(format!("{name}.snap"));
    if let Err(e) = std::fs::write(&snap_path, &snap) {
        eprintln!("snapshot write failed: {e}");
        return 1;
    }
    println!("  wrote {} ({} B zero-copy snapshot)", snap_path.display(), snap.len());
    0
}

fn cmd_serve() -> i32 {
    let args = Cli::new("lrwbins serve", "start the multistage serving stack and run a workload")
        .opt("name", "dataset preset", Some("aci"))
        .opt("rows", "row cap", Some("20000"))
        .opt("backend", "pjrt|native", Some("pjrt"))
        .opt("requests", "number of requests to serve", Some("5000"))
        .opt("netsim-us", "simulated one-way network latency (µs)", Some("250"))
        .opt("mode", "multistage|rpc|stage1", Some("multistage"))
        .flag("full", "full (slow) AutoML training instead of quick")
        .parse_subcommand();
    let mut cfg = StackConfig::quick(&args.get_or("name", "aci"), args.get_usize("rows", 20_000));
    if args.flag("full") {
        cfg.pipeline = PipelineConfig::default();
    }
    cfg.backend = args.get_or("backend", "pjrt");
    cfg.netsim.base_us = args.get_f64("netsim-us", 250.0);
    println!("building stack (dataset={}, backend={})...", cfg.dataset, cfg.backend);
    let mut stack = match harness::build(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("stack build failed: {e:#}");
            return 1;
        }
    };
    stack.coordinator.mode = match args.get_or("mode", "multistage").as_str() {
        "rpc" => Mode::AlwaysRpc,
        "stage1" => Mode::AlwaysStage1,
        _ => Mode::Multistage,
    };
    let n = args.get_usize("requests", 5000).min(stack.test.n_rows());
    println!(
        "serving {n} requests (val coverage {:.1}%)...",
        stack.pipeline.allocation.coverage * 100.0
    );
    let mut row = Vec::new();
    let t0 = std::time::Instant::now();
    for r in 0..n {
        stack.test.row_into(r, &mut row);
        if let Err(e) = stack.coordinator.predict(&row) {
            eprintln!("request {r} failed: {e}");
            return 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "done in {:.2}s ({:.0} req/s)\n{}",
        wall.as_secs_f64(),
        n as f64 / wall.as_secs_f64(),
        stack.metrics.report()
    );
    0
}

fn cmd_eval() -> i32 {
    let args = Cli::new("lrwbins eval", "Table-1-style evaluation on one preset")
        .opt("name", "dataset preset", Some("aci"))
        .opt("rows", "row cap", Some("20000"))
        .opt("seeds", "number of random repetitions", Some("3"))
        .flag("quick", "small/fast settings")
        .parse_subcommand();
    let name = args.get_or("name", "aci");
    let Some(mut spec) = datagen::preset(&name) else {
        eprintln!("unknown preset '{name}'");
        return 2;
    };
    let rows = args.get_usize("rows", 20_000);
    if rows > 0 && rows < spec.rows {
        spec = spec.with_rows(rows);
    }
    let seeds = args.get_usize("seeds", 3);
    let mut aucs = (vec![], vec![], vec![]);
    for seed in 0..seeds as u64 {
        let data = datagen::generate(&spec, seed + 1);
        let mut rng = Rng::new(seed ^ 0x5555);
        let s = split::train_test_split(&data, 0.25, &mut rng);
        let ranking = rank_features(&s.train, RankMethod::GbdtGain, seed);
        let cfg = if args.flag("quick") {
            PipelineConfig::quick()
        } else {
            PipelineConfig::default()
        };
        // LR baseline.
        let norm = lrwbins::tabular::stats::Normalizer::fit(&s.train);
        let topn: Vec<usize> = ranking.top(cfg.shape_space.n_infer_features);
        let lr = lrwbins::lr::fit_dataset(&norm.apply(&s.train), &topn, &Default::default());
        let lr_p = lrwbins::lr::predict_dataset(&lr, &norm.apply(&s.test), &topn);
        // LRwBins (shape-searched on a val split of train).
        let mut rng2 = Rng::new(seed ^ 0x9999);
        let inner = split::train_test_split(&s.train, 0.25, &mut rng2);
        let shape = lrwbins::automl::shape_search(&inner.train, &inner.test, &ranking, &cfg.shape_space);
        let lrw = lrwbins::lrwbins::LrwBinsModel::train(&s.train, &ranking.order, &shape.best);
        let lrw_p = lrw.predict_proba(&s.test);
        // GBDT.
        let gb = lrwbins::gbdt::train(&s.train, &cfg.gbdt);
        let gb_p = gb.predict_proba(&s.test);
        aucs.0.push(roc_auc(&lr_p, &s.test.labels));
        aucs.1.push(roc_auc(&lrw_p, &s.test.labels));
        aucs.2.push(roc_auc(&gb_p, &s.test.labels));
    }
    let f = lrwbins::metrics::mean_std;
    let (m0, s0) = f(&aucs.0);
    let (m1, s1) = f(&aucs.1);
    let (m2, s2) = f(&aucs.2);
    println!("{name} ({} seeds, {} rows): ROC AUC", seeds, spec.rows);
    println!("  LR      {}", lrwbins::metrics::fmt_pm(m0, s0));
    println!("  LRwBins {}", lrwbins::metrics::fmt_pm(m1, s1));
    println!("  GBDT    {}", lrwbins::metrics::fmt_pm(m2, s2));
    0
}

fn cmd_predict() -> i32 {
    let args = Cli::new(
        "lrwbins predict",
        "score a CSV with saved model files (multistage: embedded tables + GBDT fallback)",
    )
    .opt("data", "input CSV (label column optional for scoring metrics)", Some("data/dataset.csv"))
    .opt("snapshot", "binary snapshot (`<name>.snap` from `lrwbins train`): loads BOTH stages from one checksummed buffer, overriding --tables/--gbdt", None)
    .opt("tables", "serving tables JSON (from `lrwbins train`)", Some("data/aci.tables.json"))
    .opt("gbdt", "GBDT model JSON (from `lrwbins train`)", Some("data/aci.gbdt.json"))
    .opt("out", "output CSV of probabilities + stage", Some("data/predictions.csv"))
    .parse_subcommand();

    let read = |p: &str| std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"));
    let loaded: Result<(ServingTables, lrwbins::gbdt::FlatForest), String> =
        if let Some(path) = args.get("snapshot") {
            // Corrupt bytes come back as a clean Err here — never a panic
            // mid-scoring (see `snapshot`).
            lrwbins::snapshot::Snapshot::read_file(std::path::Path::new(path))
                .and_then(|s| Ok((s.tables()?, s.forest())))
        } else {
            read(&args.get_or("tables", ""))
                .and_then(|t| lrwbins::util::json::Json::parse(&t).map_err(|e| e.to_string()))
                .and_then(|j| ServingTables::from_json(&j))
                .map_err(|e| format!("tables: {e}"))
                .and_then(|t| {
                    read(&args.get_or("gbdt", ""))
                        .and_then(|g| lrwbins::util::json::Json::parse(&g).map_err(|e| e.to_string()))
                        .and_then(|j| lrwbins::gbdt::GbdtModel::from_json(&j))
                        .map_err(|e| format!("gbdt: {e}"))
                        .map(|g| (t, g.flatten()))
                })
        };
    let (tables, gbdt) = match loaded {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let data = match lrwbins::tabular::csv::read_csv(std::path::Path::new(&args.get_or("data", ""))) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("data: {e}");
            return 1;
        }
    };
    if data.n_features() != tables.n_features {
        eprintln!(
            "feature mismatch: CSV has {}, model expects {}",
            data.n_features(),
            tables.n_features
        );
        return 1;
    }

    let mut out = String::from("prob,stage\n");
    let mut probs = Vec::with_capacity(data.n_rows());
    let mut hits = 0usize;
    let mut row = Vec::new();
    for r in 0..data.n_rows() {
        data.row_into(r, &mut row);
        let (p1, routed) = tables.evaluate(&row);
        let (p, stage) = if routed {
            hits += 1;
            (p1, "stage1")
        } else {
            (gbdt.predict_one(&row), "gbdt")
        };
        probs.push(p);
        out.push_str(&format!("{p},{stage}\n"));
    }
    let out_path = args.get_or("out", "data/predictions.csv");
    if let Some(parent) = std::path::Path::new(&out_path).parent() {
        std::fs::create_dir_all(parent).ok();
    }
    std::fs::write(&out_path, out).unwrap();
    println!(
        "scored {} rows → {out_path}  (stage-1 coverage {:.1}%)",
        data.n_rows(),
        100.0 * hits as f64 / data.n_rows().max(1) as f64
    );
    // If labels are present and binary-ish, report metrics.
    if data.labels.iter().any(|&y| y > 0.5) && data.labels.iter().any(|&y| y < 0.5) {
        println!(
            "AUC {:.3}  accuracy {:.3}",
            roc_auc(&probs, &data.labels),
            accuracy(&probs, &data.labels)
        );
    }
    0
}

fn cmd_rollout() -> i32 {
    let args = Cli::new(
        "lrwbins rollout",
        "guarded model-rollout drill: shadow-score a candidate, ramp a canary, promote — or auto-rollback on divergence",
    )
    .opt("name", "dataset preset", Some("aci"))
    .opt("rows", "row count override (0 = preset size)", Some("4000"))
    .opt("seed", "data + routing seed", Some("1"))
    .opt("requests", "request budget to drive through the stack", Some("8000"))
    .opt(
        "leaf-shift",
        "shift every candidate leaf margin by this much (0 = bit-identical candidate; large values trip the score-delta guard)",
        Some("0"),
    )
    .opt("sample-permille", "shadow sampling rate, permille of admitted batches", Some("500"))
    .opt("min-compared", "rows compared before the canary ramp may start", Some("200"))
    .opt("max-delta", "score-delta guard: max |candidate - live| probability", Some("0.25"))
    .opt("error-budget", "max rows the candidate may answer before promotion", Some("10000"))
    .parse_subcommand();
    let name = args.get_or("name", "aci");
    let mut cfg = StackConfig::quick(&name, args.get_usize("rows", 4000));
    cfg.seed = args.get_u64("seed", 1);
    let rcfg = lrwbins::coordinator::RolloutConfig {
        shadow_sample_permille: args.get_usize("sample-permille", 500).min(1000) as u32,
        min_rows_compared: args.get_u64("min-compared", 200),
        max_score_delta: args.get_f64("max-delta", 0.25),
        error_budget_rows: args.get_u64("error-budget", 10_000),
        ..Default::default()
    };
    let shift = args.get_f64("leaf-shift", 0.0) as f32;
    println!("building embedded stack on '{name}', candidate leaf shift {shift:+}...");
    match harness::run_rollout(&cfg, rcfg, shift, args.get_usize("requests", 8000)) {
        Ok(run) => {
            println!("{}", run.rollout.stats.report());
            if run.promoted {
                println!("PROMOTED: candidate installed as pool version {}", run.version);
            } else {
                println!(
                    "ROLLED BACK: {}",
                    run.reason.map_or_else(
                        || "no guard tripped (request budget exhausted mid-rollout)".into(),
                        |r| format!("{r:?} guard tripped")
                    )
                );
            }
            0
        }
        Err(e) => {
            eprintln!("rollout failed: {e:#}");
            1
        }
    }
}

fn cmd_fig5() -> i32 {
    let args = Cli::new("lrwbins fig5", "Picasso feature visualization (paper Fig. 5)")
        .opt("name", "dataset preset", Some("case2"))
        .opt("rows", "row cap for importance estimation", Some("20000"))
        .opt("out", "SVG output path", Some("data/fig5.svg"))
        .parse_subcommand();
    let name = args.get_or("name", "case2");
    let Some(spec) = datagen::preset(&name) else {
        eprintln!("unknown preset '{name}'");
        return 2;
    };
    let data = datagen::generate(&spec.with_rows(args.get_usize("rows", 20_000)), 1);
    let ranking = rank_features(&data, RankMethod::GbdtGain, 1);
    let placed = lrwbins::picasso::layout(&data.schema, &ranking);
    let out = std::path::PathBuf::from(args.get_or("out", "data/fig5.svg"));
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    std::fs::write(&out, lrwbins::picasso::to_svg(&placed, 800)).unwrap();
    println!("{}", lrwbins::picasso::to_text(&placed, 41));
    println!("wrote {} ({} features; digits = importance rank)", out.display(), placed.len());
    0
}

fn cmd_info() -> i32 {
    let dir = harness::default_artifacts_dir();
    match std::fs::read_to_string(dir.join("manifest.json")) {
        Ok(text) => {
            println!("artifacts at {}:\n{text}", dir.display());
            0
        }
        Err(e) => {
            eprintln!("no artifacts ({e}); run `make artifacts`");
            1
        }
    }
}
