//! Deployment configuration: JSON files + CLI overrides.
//!
//! A deployable framework needs a real config system; this one covers the
//! three lifecycle stages — data generation, training, serving — with
//! validated JSON round-trips (`util::json`, no serde offline).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Directory with AOT artifacts (`manifest.json` + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Stage-1 serving tables (JSON from `lrwbins::tables`).
    pub tables_path: PathBuf,
    /// Second-stage GBDT model (JSON from `gbdt`).
    pub gbdt_path: PathBuf,
    /// Binary model snapshot (`.snap`, see `snapshot`): when non-empty the
    /// server loads BOTH stages from this one checksummed buffer instead of
    /// the `tables_path`/`gbdt_path` JSON pair — the production load path
    /// (`lrwbins train` writes it next to the JSON artifacts).
    pub snapshot_path: PathBuf,
    /// Bind address for the backend service.
    pub bind: String,
    /// Backend kind: "pjrt" (AOT artifact) or "native" (Rust GBDT).
    pub backend: String,
    /// Stage-1 block-kernel tier: "auto" (runtime detection) or a forced
    /// "scalar" | "tiled" | "avx2" for A/B runs — every tier is
    /// bit-identical (see `lrwbins::tables`), so this is a perf switch,
    /// never a correctness one.
    pub stage1_simd: String,
    /// Dynamic batcher.
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub workers: usize,
    /// Server I/O path: `true` (default) runs the epoll reactor on Linux —
    /// a fixed set of event loops for all connections; `false` forces the
    /// legacy thread-per-connection path (the A/B baseline). Non-Linux
    /// targets always use the threaded path regardless.
    pub reactor: bool,
    /// Reactor event-loop count; 0 = auto (min(4, available cores)).
    pub reactor_loops: usize,
    /// Per-connection write-queue bound, frames; producers block briefly
    /// when a slow client fills it (backpressure).
    pub write_queue_frames: usize,
    /// Simulated datacenter RTT (one way), microseconds; 0 disables.
    pub netsim_base_us: f64,
    pub netsim_sigma: f64,
    pub seed: u64,
    /// Failure model — client retry policy: extra attempts after the first
    /// (0 disables retrying) and the starting backoff (doubles per retry,
    /// jittered; see `rpc::fault::RetryPolicy`).
    pub retry_max: u32,
    pub retry_base_backoff_ms: u64,
    /// Circuit breaker: consecutive transport failures that trip it open,
    /// and how long it fails fast before the half-open probe.
    pub breaker_failures: u32,
    pub breaker_cooldown_ms: u64,
    /// What a route-missed row gets when the second stage cannot serve it:
    /// "fail" (propagate the error), "stage1-prior" (answer with the
    /// stage-1 prior, marked degraded), or "block" (wait out the breaker).
    pub degrade: String,
    /// Default per-request deadline budget, milliseconds; 0 = none. The
    /// budget rides the wire so the server batcher and shard pool shed
    /// expired work instead of computing answers nobody can use.
    pub deadline_ms: u64,
    /// Overload model — global cap on admitted-but-unfinished rows
    /// (0 = uncapped). Any admission knob > 0 turns admission control on
    /// at the server door (see `rpc::admission`).
    pub admit_global_rows: usize,
    /// Sustained per-tenant admission rate, rows per second (0 = the
    /// admission default when another knob enables admission).
    pub admit_tenant_rate: f64,
    /// Per-tenant burst allowance, rows (token-bucket capacity; 0 = the
    /// admission default).
    pub admit_tenant_burst: f64,
    /// CoDel sojourn target for the batcher queue, microseconds; jobs whose
    /// measured queue delay stands above this for a full interval are shed
    /// with `REJECTED` frames. 0 disables sojourn shedding.
    pub sojourn_slo_us: u64,
    /// Admitted-request p99 target for the SLO controller, milliseconds
    /// (0 = the controller default). Only read by the SLO harness/bench;
    /// the serving path itself never looks at it.
    pub slo_p99_ms: u64,
    /// Guarded model rollout (`lrwbins rollout`, `Coordinator::
    /// begin_rollout`) — fraction of served batches sampled into the
    /// shadow comparison, permille.
    pub rollout_shadow_sample_permille: u64,
    /// Compared rows required before the disagreement guard arms and
    /// Shadow may hand over to Canary.
    pub rollout_min_rows_compared: u64,
    /// Stage-1 routing disagreement-rate bound (fraction, 0..1).
    pub rollout_max_disagreement: f64,
    /// Bound on any single |candidate − live| score delta.
    pub rollout_max_score_delta: f64,
    /// Controller ticks the rollout must dwell in Shadow.
    pub rollout_min_shadow_ticks: u64,
    /// Canary ramp schedule, comma-separated permille steps
    /// (e.g. "50,200,500"); after the last step the rollout promotes.
    pub rollout_canary_steps: String,
    /// Unescalated controller ticks per ramp step.
    pub rollout_step_ticks: u64,
    /// Hard pre-promotion cap on rows the candidate may answer.
    pub rollout_error_budget_rows: u64,
    /// Absolute canary-batch p99 bound, µs (0 disables the guard).
    pub rollout_canary_p99_bound_us: u64,
    /// Shadow-vs-live p99 ratio bound (0 disables the guard).
    pub rollout_max_shadow_latency_ratio: f64,
    /// Shed horizon for queued shadow-scoring jobs, milliseconds.
    pub rollout_shadow_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            tables_path: PathBuf::from("data/model.tables.json"),
            gbdt_path: PathBuf::from("data/model.gbdt.json"),
            snapshot_path: PathBuf::new(),
            bind: "127.0.0.1:7171".into(),
            backend: "pjrt".into(),
            stage1_simd: "auto".into(),
            max_batch: 128,
            max_wait_us: 200,
            workers: 2,
            reactor: true,
            reactor_loops: 0,
            write_queue_frames: 1024,
            netsim_base_us: 250.0,
            netsim_sigma: 0.25,
            seed: 7,
            retry_max: 2,
            retry_base_backoff_ms: 5,
            breaker_failures: 5,
            breaker_cooldown_ms: 250,
            degrade: "fail".into(),
            deadline_ms: 0,
            admit_global_rows: 0,
            admit_tenant_rate: 0.0,
            admit_tenant_burst: 0.0,
            sojourn_slo_us: 0,
            slo_p99_ms: 0,
            rollout_shadow_sample_permille: 250,
            rollout_min_rows_compared: 200,
            rollout_max_disagreement: 0.02,
            rollout_max_score_delta: 0.25,
            rollout_min_shadow_ticks: 2,
            rollout_canary_steps: "50,200,500".into(),
            rollout_step_ticks: 2,
            rollout_error_budget_rows: 10_000,
            rollout_canary_p99_bound_us: 0,
            rollout_max_shadow_latency_ratio: 0.0,
            rollout_shadow_timeout_ms: 250,
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("artifacts_dir", Json::Str(self.artifacts_dir.display().to_string()));
        j.set("tables_path", Json::Str(self.tables_path.display().to_string()));
        j.set("gbdt_path", Json::Str(self.gbdt_path.display().to_string()));
        j.set("snapshot_path", Json::Str(self.snapshot_path.display().to_string()));
        j.set("bind", Json::Str(self.bind.clone()));
        j.set("backend", Json::Str(self.backend.clone()));
        j.set("stage1_simd", Json::Str(self.stage1_simd.clone()));
        j.set("max_batch", Json::Num(self.max_batch as f64));
        j.set("max_wait_us", Json::Num(self.max_wait_us as f64));
        j.set("workers", Json::Num(self.workers as f64));
        j.set("reactor", Json::Bool(self.reactor));
        j.set("reactor_loops", Json::Num(self.reactor_loops as f64));
        j.set(
            "write_queue_frames",
            Json::Num(self.write_queue_frames as f64),
        );
        j.set("netsim_base_us", Json::Num(self.netsim_base_us));
        j.set("netsim_sigma", Json::Num(self.netsim_sigma));
        j.set("seed", Json::Num(self.seed as f64));
        j.set("retry_max", Json::Num(self.retry_max as f64));
        j.set(
            "retry_base_backoff_ms",
            Json::Num(self.retry_base_backoff_ms as f64),
        );
        j.set("breaker_failures", Json::Num(self.breaker_failures as f64));
        j.set(
            "breaker_cooldown_ms",
            Json::Num(self.breaker_cooldown_ms as f64),
        );
        j.set("degrade", Json::Str(self.degrade.clone()));
        j.set("deadline_ms", Json::Num(self.deadline_ms as f64));
        j.set("admit_global_rows", Json::Num(self.admit_global_rows as f64));
        j.set("admit_tenant_rate", Json::Num(self.admit_tenant_rate));
        j.set("admit_tenant_burst", Json::Num(self.admit_tenant_burst));
        j.set("sojourn_slo_us", Json::Num(self.sojourn_slo_us as f64));
        j.set("slo_p99_ms", Json::Num(self.slo_p99_ms as f64));
        j.set(
            "rollout_shadow_sample_permille",
            Json::Num(self.rollout_shadow_sample_permille as f64),
        );
        j.set(
            "rollout_min_rows_compared",
            Json::Num(self.rollout_min_rows_compared as f64),
        );
        j.set(
            "rollout_max_disagreement",
            Json::Num(self.rollout_max_disagreement),
        );
        j.set(
            "rollout_max_score_delta",
            Json::Num(self.rollout_max_score_delta),
        );
        j.set(
            "rollout_min_shadow_ticks",
            Json::Num(self.rollout_min_shadow_ticks as f64),
        );
        j.set(
            "rollout_canary_steps",
            Json::Str(self.rollout_canary_steps.clone()),
        );
        j.set("rollout_step_ticks", Json::Num(self.rollout_step_ticks as f64));
        j.set(
            "rollout_error_budget_rows",
            Json::Num(self.rollout_error_budget_rows as f64),
        );
        j.set(
            "rollout_canary_p99_bound_us",
            Json::Num(self.rollout_canary_p99_bound_us as f64),
        );
        j.set(
            "rollout_max_shadow_latency_ratio",
            Json::Num(self.rollout_max_shadow_latency_ratio),
        );
        j.set(
            "rollout_shadow_timeout_ms",
            Json::Num(self.rollout_shadow_timeout_ms as f64),
        );
        j
    }

    pub fn from_json(j: &Json) -> Result<ServeConfig, String> {
        let d = ServeConfig::default();
        let s = |k: &str, dft: &str| -> String {
            j.get(k).and_then(Json::as_str).unwrap_or(dft).to_string()
        };
        let n = |k: &str, dft: f64| j.get(k).and_then(Json::as_f64).unwrap_or(dft);
        let cfg = ServeConfig {
            artifacts_dir: PathBuf::from(s("artifacts_dir", &d.artifacts_dir.display().to_string())),
            tables_path: PathBuf::from(s("tables_path", &d.tables_path.display().to_string())),
            gbdt_path: PathBuf::from(s("gbdt_path", &d.gbdt_path.display().to_string())),
            snapshot_path: PathBuf::from(s("snapshot_path", &d.snapshot_path.display().to_string())),
            bind: s("bind", &d.bind),
            backend: s("backend", &d.backend),
            stage1_simd: s("stage1_simd", &d.stage1_simd),
            max_batch: n("max_batch", d.max_batch as f64) as usize,
            max_wait_us: n("max_wait_us", d.max_wait_us as f64) as u64,
            workers: n("workers", d.workers as f64) as usize,
            reactor: j.get("reactor").and_then(Json::as_bool).unwrap_or(d.reactor),
            reactor_loops: n("reactor_loops", d.reactor_loops as f64) as usize,
            write_queue_frames: n("write_queue_frames", d.write_queue_frames as f64) as usize,
            netsim_base_us: n("netsim_base_us", d.netsim_base_us),
            netsim_sigma: n("netsim_sigma", d.netsim_sigma),
            seed: n("seed", d.seed as f64) as u64,
            retry_max: n("retry_max", d.retry_max as f64) as u32,
            retry_base_backoff_ms: n("retry_base_backoff_ms", d.retry_base_backoff_ms as f64)
                as u64,
            breaker_failures: n("breaker_failures", d.breaker_failures as f64) as u32,
            breaker_cooldown_ms: n("breaker_cooldown_ms", d.breaker_cooldown_ms as f64) as u64,
            degrade: s("degrade", &d.degrade),
            deadline_ms: n("deadline_ms", d.deadline_ms as f64) as u64,
            admit_global_rows: n("admit_global_rows", d.admit_global_rows as f64) as usize,
            admit_tenant_rate: n("admit_tenant_rate", d.admit_tenant_rate),
            admit_tenant_burst: n("admit_tenant_burst", d.admit_tenant_burst),
            sojourn_slo_us: n("sojourn_slo_us", d.sojourn_slo_us as f64) as u64,
            slo_p99_ms: n("slo_p99_ms", d.slo_p99_ms as f64) as u64,
            rollout_shadow_sample_permille: n(
                "rollout_shadow_sample_permille",
                d.rollout_shadow_sample_permille as f64,
            ) as u64,
            rollout_min_rows_compared: n(
                "rollout_min_rows_compared",
                d.rollout_min_rows_compared as f64,
            ) as u64,
            rollout_max_disagreement: n("rollout_max_disagreement", d.rollout_max_disagreement),
            rollout_max_score_delta: n("rollout_max_score_delta", d.rollout_max_score_delta),
            rollout_min_shadow_ticks: n(
                "rollout_min_shadow_ticks",
                d.rollout_min_shadow_ticks as f64,
            ) as u64,
            rollout_canary_steps: s("rollout_canary_steps", &d.rollout_canary_steps),
            rollout_step_ticks: n("rollout_step_ticks", d.rollout_step_ticks as f64) as u64,
            rollout_error_budget_rows: n(
                "rollout_error_budget_rows",
                d.rollout_error_budget_rows as f64,
            ) as u64,
            rollout_canary_p99_bound_us: n(
                "rollout_canary_p99_bound_us",
                d.rollout_canary_p99_bound_us as f64,
            ) as u64,
            rollout_max_shadow_latency_ratio: n(
                "rollout_max_shadow_latency_ratio",
                d.rollout_max_shadow_latency_ratio,
            ),
            rollout_shadow_timeout_ms: n(
                "rollout_shadow_timeout_ms",
                d.rollout_shadow_timeout_ms as f64,
            ) as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parsed stage-1 kernel override (`None` = auto-detect).
    pub fn stage1_dispatch(&self) -> Result<Option<crate::lrwbins::Stage1Dispatch>, String> {
        crate::lrwbins::Stage1Dispatch::parse(&self.stage1_simd)
    }

    /// Parsed degrade policy for the coordinator.
    pub fn degrade_mode(&self) -> Result<crate::coordinator::DegradeMode, String> {
        use crate::coordinator::DegradeMode;
        match self.degrade.as_str() {
            "fail" => Ok(DegradeMode::Fail),
            "stage1-prior" => Ok(DegradeMode::Stage1Prior),
            "block" => Ok(DegradeMode::Block),
            other => Err(format!(
                "degrade must be fail|stage1-prior|block, got '{other}'"
            )),
        }
    }

    /// Client transport config (retry policy + breaker thresholds) built
    /// from the failure-model knobs.
    pub fn client_config(&self) -> crate::rpc::ClientConfig {
        use std::time::Duration;
        crate::rpc::ClientConfig {
            retry: crate::rpc::RetryPolicy {
                max_retries: self.retry_max,
                base_backoff: Duration::from_millis(self.retry_base_backoff_ms),
                ..Default::default()
            },
            breaker: crate::rpc::BreakerConfig {
                failure_threshold: self.breaker_failures,
                cooldown: Duration::from_millis(self.breaker_cooldown_ms),
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Admission control from the overload knobs: `None` (admit
    /// everything) unless at least one knob is set; knobs left at 0 take
    /// the `rpc::AdmissionConfig` defaults.
    pub fn admission_config(&self) -> Option<crate::rpc::AdmissionConfig> {
        if self.admit_global_rows == 0
            && self.admit_tenant_rate == 0.0
            && self.admit_tenant_burst == 0.0
        {
            return None;
        }
        let d = crate::rpc::AdmissionConfig::default();
        Some(crate::rpc::AdmissionConfig {
            tenant_rate_rows_per_s: if self.admit_tenant_rate > 0.0 {
                self.admit_tenant_rate
            } else {
                d.tenant_rate_rows_per_s
            },
            tenant_burst_rows: if self.admit_tenant_burst > 0.0 {
                self.admit_tenant_burst
            } else {
                d.tenant_burst_rows
            },
            global_inflight_rows: self.admit_global_rows,
        })
    }

    /// The parsed canary ramp schedule (permille steps, each 1..=1000).
    pub fn rollout_canary_steps(&self) -> Result<Vec<u32>, String> {
        let mut steps = Vec::new();
        for part in self.rollout_canary_steps.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let p: u32 = part
                .parse()
                .map_err(|_| format!("rollout_canary_steps: '{part}' is not an integer"))?;
            if p == 0 || p > 1000 {
                return Err(format!(
                    "rollout_canary_steps: step {p}‰ out of range (1..=1000)"
                ));
            }
            steps.push(p);
        }
        if steps.is_empty() {
            return Err("rollout_canary_steps must name at least one step".into());
        }
        Ok(steps)
    }

    /// Guarded-rollout policy from the `rollout_*` knobs (see
    /// `coordinator::RolloutConfig`).
    pub fn rollout_config(&self) -> Result<crate::coordinator::RolloutConfig, String> {
        Ok(crate::coordinator::RolloutConfig {
            shadow_sample_permille: self.rollout_shadow_sample_permille.min(1000) as u32,
            min_rows_compared: self.rollout_min_rows_compared,
            max_disagreement: self.rollout_max_disagreement,
            max_score_delta: self.rollout_max_score_delta,
            min_shadow_ticks: self.rollout_min_shadow_ticks as u32,
            canary_steps_permille: self.rollout_canary_steps()?,
            step_ticks: self.rollout_step_ticks.max(1) as u32,
            error_budget_rows: self.rollout_error_budget_rows,
            canary_p99_bound_us: self.rollout_canary_p99_bound_us,
            max_shadow_latency_ratio: self.rollout_max_shadow_latency_ratio,
            shadow_timeout: std::time::Duration::from_millis(self.rollout_shadow_timeout_ms),
        })
    }

    /// Per-request options from the configured default deadline budget.
    pub fn predict_options(&self) -> crate::rpc::PredictOptions {
        if self.deadline_ms == 0 {
            crate::rpc::PredictOptions::default()
        } else {
            crate::rpc::PredictOptions::with_budget(std::time::Duration::from_millis(
                self.deadline_ms,
            ))
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.backend != "pjrt" && self.backend != "native" {
            return Err(format!("backend must be pjrt|native, got '{}'", self.backend));
        }
        self.stage1_dispatch()?;
        self.degrade_mode()?;
        if self.max_batch == 0 {
            return Err("max_batch must be > 0".into());
        }
        if self.workers == 0 {
            return Err("workers must be > 0".into());
        }
        if self.write_queue_frames == 0 {
            return Err("write_queue_frames must be > 0".into());
        }
        if self.breaker_failures == 0 {
            return Err("breaker_failures must be > 0 (use a huge value to disable)".into());
        }
        if !self.admit_tenant_rate.is_finite() || self.admit_tenant_rate < 0.0 {
            return Err("admit_tenant_rate must be finite and >= 0".into());
        }
        if !self.admit_tenant_burst.is_finite() || self.admit_tenant_burst < 0.0 {
            return Err("admit_tenant_burst must be finite and >= 0".into());
        }
        self.rollout_canary_steps()?;
        if self.rollout_shadow_sample_permille > 1000 {
            return Err("rollout_shadow_sample_permille must be <= 1000".into());
        }
        if !self.rollout_max_disagreement.is_finite()
            || !(0.0..=1.0).contains(&self.rollout_max_disagreement)
        {
            return Err("rollout_max_disagreement must be in 0..=1".into());
        }
        if !self.rollout_max_score_delta.is_finite() || self.rollout_max_score_delta <= 0.0 {
            return Err("rollout_max_score_delta must be finite and > 0".into());
        }
        if !self.rollout_max_shadow_latency_ratio.is_finite()
            || self.rollout_max_shadow_latency_ratio < 0.0
        {
            return Err("rollout_max_shadow_latency_ratio must be finite and >= 0".into());
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ServeConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
    }
}

/// Training configuration (the launcher's `train` subcommand).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Dataset preset name or CSV path.
    pub dataset: String,
    /// Row cap (0 = preset default).
    pub rows: usize,
    pub seed: u64,
    /// AutoML pipeline settings.
    pub quick: bool,
    pub tolerance: f64,
    pub coverage_target: f64,
    /// Output directory for model files.
    pub out_dir: PathBuf,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dataset: "aci".into(),
            rows: 0,
            seed: 1,
            quick: false,
            tolerance: 0.002,
            coverage_target: 0.5,
            out_dir: PathBuf::from("data"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_roundtrip() {
        let c = ServeConfig {
            bind: "0.0.0.0:9999".into(),
            backend: "native".into(),
            max_batch: 7,
            ..Default::default()
        };
        let c2 = ServeConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(c2.bind, "0.0.0.0:9999");
        assert_eq!(c2.backend, "native");
        assert_eq!(c2.max_batch, 7);
    }

    #[test]
    fn defaults_fill_missing_keys() {
        let c = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(c.bind, ServeConfig::default().bind);
    }

    #[test]
    fn rejects_bad_backend() {
        let j = Json::parse(r#"{"backend": "gpu"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn stage1_simd_parses_and_rejects() {
        let j = Json::parse(r#"{"backend": "native", "stage1_simd": "scalar"}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(
            c.stage1_dispatch().unwrap(),
            Some(crate::lrwbins::Stage1Dispatch::Scalar)
        );
        // Default is auto (None = runtime detection).
        assert_eq!(ServeConfig::default().stage1_dispatch().unwrap(), None);
        let j = Json::parse(r#"{"backend": "native", "stage1_simd": "sse9"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_zero_batch() {
        let j = Json::parse(r#"{"max_batch": 0}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn failure_model_knobs_roundtrip() {
        let c = ServeConfig {
            retry_max: 4,
            retry_base_backoff_ms: 11,
            breaker_failures: 3,
            breaker_cooldown_ms: 77,
            degrade: "stage1-prior".into(),
            deadline_ms: 25,
            ..Default::default()
        };
        let c2 = ServeConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(c2.retry_max, 4);
        assert_eq!(c2.retry_base_backoff_ms, 11);
        assert_eq!(c2.breaker_failures, 3);
        assert_eq!(c2.breaker_cooldown_ms, 77);
        assert_eq!(
            c2.degrade_mode().unwrap(),
            crate::coordinator::DegradeMode::Stage1Prior
        );
        let cc = c2.client_config();
        assert_eq!(cc.retry.max_retries, 4);
        assert_eq!(cc.breaker.failure_threshold, 3);
        assert_eq!(
            cc.breaker.cooldown,
            std::time::Duration::from_millis(77)
        );
        let opts = c2.predict_options();
        assert!(opts.deadline.is_some());
        assert!(ServeConfig::default().predict_options().deadline.is_none());
    }

    #[test]
    fn overload_knobs_roundtrip_and_gate_admission() {
        // Defaults: no admission, no sojourn shedding, controller default.
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(d.admission_config().is_none());
        assert_eq!(d.sojourn_slo_us, 0);
        assert_eq!(d.slo_p99_ms, 0);

        let c = ServeConfig {
            admit_global_rows: 4096,
            admit_tenant_rate: 1500.0,
            admit_tenant_burst: 300.0,
            sojourn_slo_us: 2500,
            slo_p99_ms: 40,
            ..Default::default()
        };
        let c2 = ServeConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(c2.admit_global_rows, 4096);
        assert_eq!(c2.admit_tenant_rate, 1500.0);
        assert_eq!(c2.admit_tenant_burst, 300.0);
        assert_eq!(c2.sojourn_slo_us, 2500);
        assert_eq!(c2.slo_p99_ms, 40);
        let a = c2.admission_config().expect("knobs set → admission on");
        assert_eq!(a.global_inflight_rows, 4096);
        assert_eq!(a.tenant_rate_rows_per_s, 1500.0);
        assert_eq!(a.tenant_burst_rows, 300.0);

        // One knob is enough to arm admission; zeros take the defaults.
        let c3 = ServeConfig {
            admit_global_rows: 64,
            ..Default::default()
        };
        let a3 = c3.admission_config().unwrap();
        assert_eq!(a3.global_inflight_rows, 64);
        assert_eq!(
            a3.tenant_rate_rows_per_s,
            crate::rpc::AdmissionConfig::default().tenant_rate_rows_per_s
        );

        // Negative / non-finite rates are rejected at validation.
        let j = Json::parse(r#"{"admit_tenant_rate": -2.0}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn snapshot_path_roundtrips_and_defaults_empty() {
        // Default: no snapshot — the JSON pair is the model source.
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.snapshot_path, PathBuf::new());

        let c = ServeConfig {
            snapshot_path: PathBuf::from("data/model.snap"),
            ..Default::default()
        };
        let c2 = ServeConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(c2.snapshot_path, PathBuf::from("data/model.snap"));
    }

    #[test]
    fn reactor_knobs_roundtrip_and_validate() {
        // Defaults: reactor on, auto loop count, bounded write queue.
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(d.reactor);
        assert_eq!(d.reactor_loops, 0);
        assert_eq!(d.write_queue_frames, 1024);

        let c = ServeConfig {
            reactor: false,
            reactor_loops: 3,
            write_queue_frames: 64,
            ..Default::default()
        };
        let c2 = ServeConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert!(!c2.reactor);
        assert_eq!(c2.reactor_loops, 3);
        assert_eq!(c2.write_queue_frames, 64);

        let j = Json::parse(r#"{"write_queue_frames": 0}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn rollout_knobs_roundtrip_and_validate() {
        // Defaults mirror coordinator::RolloutConfig::default().
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        let rc = d.rollout_config().unwrap();
        assert_eq!(rc.shadow_sample_permille, 250);
        assert_eq!(rc.canary_steps_permille, vec![50, 200, 500]);
        assert_eq!(rc.error_budget_rows, 10_000);
        assert_eq!(rc.shadow_timeout, std::time::Duration::from_millis(250));

        let c = ServeConfig {
            rollout_shadow_sample_permille: 1000,
            rollout_min_rows_compared: 32,
            rollout_max_disagreement: 0.05,
            rollout_max_score_delta: 0.1,
            rollout_min_shadow_ticks: 1,
            rollout_canary_steps: "100, 900".into(),
            rollout_step_ticks: 3,
            rollout_error_budget_rows: 512,
            rollout_canary_p99_bound_us: 40_000,
            rollout_max_shadow_latency_ratio: 8.0,
            rollout_shadow_timeout_ms: 50,
            ..Default::default()
        };
        let c2 = ServeConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        let rc = c2.rollout_config().unwrap();
        assert_eq!(rc.shadow_sample_permille, 1000);
        assert_eq!(rc.min_rows_compared, 32);
        assert_eq!(rc.max_disagreement, 0.05);
        assert_eq!(rc.canary_steps_permille, vec![100, 900]);
        assert_eq!(rc.step_ticks, 3);
        assert_eq!(rc.error_budget_rows, 512);
        assert_eq!(rc.canary_p99_bound_us, 40_000);
        assert_eq!(rc.max_shadow_latency_ratio, 8.0);
        assert_eq!(rc.shadow_timeout, std::time::Duration::from_millis(50));

        // Bad ramp schedules and out-of-range bounds are rejected.
        for bad in [
            r#"{"rollout_canary_steps": "50,frog"}"#,
            r#"{"rollout_canary_steps": "0"}"#,
            r#"{"rollout_canary_steps": "1500"}"#,
            r#"{"rollout_canary_steps": ""}"#,
            r#"{"rollout_max_disagreement": 1.5}"#,
            r#"{"rollout_max_score_delta": 0.0}"#,
            r#"{"rollout_max_shadow_latency_ratio": -1.0}"#,
        ] {
            assert!(
                ServeConfig::from_json(&Json::parse(bad).unwrap()).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn rejects_bad_degrade_and_zero_breaker_threshold() {
        let j = Json::parse(r#"{"degrade": "shrug"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"breaker_failures": 0}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
        // Defaults stay degrade=fail, no deadline.
        let c = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(
            c.degrade_mode().unwrap(),
            crate::coordinator::DegradeMode::Fail
        );
    }
}
