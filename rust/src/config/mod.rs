//! Deployment configuration: JSON files + CLI overrides.
//!
//! A deployable framework needs a real config system; this one covers the
//! three lifecycle stages — data generation, training, serving — with
//! validated JSON round-trips (`util::json`, no serde offline).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Directory with AOT artifacts (`manifest.json` + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Stage-1 serving tables (JSON from `lrwbins::tables`).
    pub tables_path: PathBuf,
    /// Second-stage GBDT model (JSON from `gbdt`).
    pub gbdt_path: PathBuf,
    /// Bind address for the backend service.
    pub bind: String,
    /// Backend kind: "pjrt" (AOT artifact) or "native" (Rust GBDT).
    pub backend: String,
    /// Stage-1 block-kernel tier: "auto" (runtime detection) or a forced
    /// "scalar" | "tiled" | "avx2" for A/B runs — every tier is
    /// bit-identical (see `lrwbins::tables`), so this is a perf switch,
    /// never a correctness one.
    pub stage1_simd: String,
    /// Dynamic batcher.
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub workers: usize,
    /// Simulated datacenter RTT (one way), microseconds; 0 disables.
    pub netsim_base_us: f64,
    pub netsim_sigma: f64,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            tables_path: PathBuf::from("data/model.tables.json"),
            gbdt_path: PathBuf::from("data/model.gbdt.json"),
            bind: "127.0.0.1:7171".into(),
            backend: "pjrt".into(),
            stage1_simd: "auto".into(),
            max_batch: 128,
            max_wait_us: 200,
            workers: 2,
            netsim_base_us: 250.0,
            netsim_sigma: 0.25,
            seed: 7,
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("artifacts_dir", Json::Str(self.artifacts_dir.display().to_string()));
        j.set("tables_path", Json::Str(self.tables_path.display().to_string()));
        j.set("gbdt_path", Json::Str(self.gbdt_path.display().to_string()));
        j.set("bind", Json::Str(self.bind.clone()));
        j.set("backend", Json::Str(self.backend.clone()));
        j.set("stage1_simd", Json::Str(self.stage1_simd.clone()));
        j.set("max_batch", Json::Num(self.max_batch as f64));
        j.set("max_wait_us", Json::Num(self.max_wait_us as f64));
        j.set("workers", Json::Num(self.workers as f64));
        j.set("netsim_base_us", Json::Num(self.netsim_base_us));
        j.set("netsim_sigma", Json::Num(self.netsim_sigma));
        j.set("seed", Json::Num(self.seed as f64));
        j
    }

    pub fn from_json(j: &Json) -> Result<ServeConfig, String> {
        let d = ServeConfig::default();
        let s = |k: &str, dft: &str| -> String {
            j.get(k).and_then(Json::as_str).unwrap_or(dft).to_string()
        };
        let n = |k: &str, dft: f64| j.get(k).and_then(Json::as_f64).unwrap_or(dft);
        let cfg = ServeConfig {
            artifacts_dir: PathBuf::from(s("artifacts_dir", &d.artifacts_dir.display().to_string())),
            tables_path: PathBuf::from(s("tables_path", &d.tables_path.display().to_string())),
            gbdt_path: PathBuf::from(s("gbdt_path", &d.gbdt_path.display().to_string())),
            bind: s("bind", &d.bind),
            backend: s("backend", &d.backend),
            stage1_simd: s("stage1_simd", &d.stage1_simd),
            max_batch: n("max_batch", d.max_batch as f64) as usize,
            max_wait_us: n("max_wait_us", d.max_wait_us as f64) as u64,
            workers: n("workers", d.workers as f64) as usize,
            netsim_base_us: n("netsim_base_us", d.netsim_base_us),
            netsim_sigma: n("netsim_sigma", d.netsim_sigma),
            seed: n("seed", d.seed as f64) as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parsed stage-1 kernel override (`None` = auto-detect).
    pub fn stage1_dispatch(&self) -> Result<Option<crate::lrwbins::Stage1Dispatch>, String> {
        crate::lrwbins::Stage1Dispatch::parse(&self.stage1_simd)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.backend != "pjrt" && self.backend != "native" {
            return Err(format!("backend must be pjrt|native, got '{}'", self.backend));
        }
        self.stage1_dispatch()?;
        if self.max_batch == 0 {
            return Err("max_batch must be > 0".into());
        }
        if self.workers == 0 {
            return Err("workers must be > 0".into());
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ServeConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
    }
}

/// Training configuration (the launcher's `train` subcommand).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Dataset preset name or CSV path.
    pub dataset: String,
    /// Row cap (0 = preset default).
    pub rows: usize,
    pub seed: u64,
    /// AutoML pipeline settings.
    pub quick: bool,
    pub tolerance: f64,
    pub coverage_target: f64,
    /// Output directory for model files.
    pub out_dir: PathBuf,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dataset: "aci".into(),
            rows: 0,
            seed: 1,
            quick: false,
            tolerance: 0.002,
            coverage_target: 0.5,
            out_dir: PathBuf::from("data"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_roundtrip() {
        let c = ServeConfig {
            bind: "0.0.0.0:9999".into(),
            backend: "native".into(),
            max_batch: 7,
            ..Default::default()
        };
        let c2 = ServeConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(c2.bind, "0.0.0.0:9999");
        assert_eq!(c2.backend, "native");
        assert_eq!(c2.max_batch, 7);
    }

    #[test]
    fn defaults_fill_missing_keys() {
        let c = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(c.bind, ServeConfig::default().bind);
    }

    #[test]
    fn rejects_bad_backend() {
        let j = Json::parse(r#"{"backend": "gpu"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn stage1_simd_parses_and_rejects() {
        let j = Json::parse(r#"{"backend": "native", "stage1_simd": "scalar"}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(
            c.stage1_dispatch().unwrap(),
            Some(crate::lrwbins::Stage1Dispatch::Scalar)
        );
        // Default is auto (None = runtime detection).
        assert_eq!(ServeConfig::default().stage1_dispatch().unwrap(), None);
        let j = Json::parse(r#"{"backend": "native", "stage1_simd": "sse9"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_zero_batch() {
        let j = Json::parse(r#"{"max_batch": 0}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }
}
