//! SLO control plane: a seeded trace-driven load harness plus an
//! InferLine-style controller over the serving stack's live knobs.
//!
//! Three pieces, each independently testable:
//!
//! * **Trace generator** ([`generate_trace`]) — a deterministic open-loop
//!   arrival schedule: non-homogeneous Poisson arrivals (thinning against
//!   the peak rate) under a diurnal ramp, with correlated burst windows
//!   and a hot-tenant skew. Same [`TraceConfig`] + seed, same trace,
//!   bit-for-bit — load experiments replay exactly.
//! * **Controller** ([`SloController`]) — a pure decision function
//!   (`Obs → Decision`, no I/O, no clocks) that walks the overload ladder
//!   to hold an admitted-p99 target at minimum CPU. Escalation order
//!   under pressure: grow the shard pool's active set (and split tasks
//!   finer so steals spread the surge), then brown out low-priority →
//!   all traffic, then throttle admission multiplicatively. De-escalation
//!   relaxes the same rungs in reverse — admission first, capacity last —
//!   and *shrinks* the pool when it is comfortably idle, so a quiet
//!   stack pays for the cores it needs, not the cores it has.
//! * **Runner** ([`run_trace`]) — drives per-tenant [`Coordinator`]s
//!   against a live server per the trace, applies each controller tick's
//!   [`Decision`] to the real knobs ([`AdmissionControl::set_rate_factor`],
//!   [`Coordinator::set_brownout`](Coordinator::set_brownout),
//!   [`ShardPool::set_active_shards`], [`ShardPool::set_min_task_rows`]),
//!   and records a [`SloReport`] trajectory — per tick: offered/served/
//!   degraded/rejected counts, measured p50/p99, CPU cores burned, and
//!   every knob setting. `BENCH_slo.json` is this report serialized.

use crate::coordinator::{Coordinator, Served, BROWNOUT_ALL};
use crate::rpc::admission::AdmissionControl;
use crate::rpc::fault::{self, Deadline, PredictOptions};
use crate::runtime::ShardPool;
use crate::telemetry::{process_cpu_ns, ServeMetrics};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Trace generation

/// Shape of a synthetic load trace. Rates are requests/second.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub duration: Duration,
    /// Arrival rate at the diurnal trough.
    pub base_rps: f64,
    /// Arrival rate at the diurnal peak (≥ `base_rps`).
    pub peak_rps: f64,
    /// Full diurnal cycles over the trace (1.0 = one trough→peak→trough).
    pub diurnal_periods: f64,
    /// Correlated-burst cadence (`ZERO` disables bursts).
    pub burst_every: Duration,
    /// Burst window length (clipped to the cadence).
    pub burst_len: Duration,
    /// Rate multiplier inside a burst window (≥ 1).
    pub burst_mult: f64,
    /// Tenant id space: arrivals carry `0..n_tenants`.
    pub n_tenants: u32,
    /// Tenant receiving `hot_share` of the traffic (`None` = uniform).
    pub hot_tenant: Option<u32>,
    /// Fraction of arrivals billed to the hot tenant (0..1).
    pub hot_share: f64,
    /// Per-request row counts, uniform in `rows_min..=rows_max`.
    pub rows_min: usize,
    pub rows_max: usize,
    /// Fraction of requests marked low-priority (brownout's first rung).
    pub low_priority_share: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            duration: Duration::from_secs(10),
            base_rps: 50.0,
            peak_rps: 200.0,
            diurnal_periods: 1.0,
            burst_every: Duration::from_secs(3),
            burst_len: Duration::from_millis(400),
            burst_mult: 3.0,
            n_tenants: 4,
            hot_tenant: Some(0),
            hot_share: 0.5,
            rows_min: 1,
            rows_max: 8,
            low_priority_share: 0.3,
            seed: 1,
        }
    }
}

/// One scheduled request of an open-loop trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Arrival {
    /// Offset from trace start.
    pub at: Duration,
    pub tenant: u32,
    pub n_rows: usize,
    pub low_priority: bool,
}

impl TraceConfig {
    /// Instantaneous arrival rate at offset `t` seconds: diurnal ramp
    /// (raised-cosine between base and peak) times the burst multiplier
    /// when `t` falls inside a burst window.
    pub fn rate_at(&self, t: f64) -> f64 {
        let dur = self.duration.as_secs_f64().max(f64::MIN_POSITIVE);
        let phase = (2.0 * std::f64::consts::PI * self.diurnal_periods * t / dur).cos();
        let ramp = 0.5 * (1.0 - phase); // 0 at the trough, 1 at the peak
        let mut lam = self.base_rps + (self.peak_rps - self.base_rps).max(0.0) * ramp;
        if self.in_burst(t) {
            lam *= self.burst_mult.max(1.0);
        }
        lam
    }

    /// Is offset `t` seconds inside a correlated-burst window?
    pub fn in_burst(&self, t: f64) -> bool {
        let every = self.burst_every.as_secs_f64();
        every > 0.0 && t.rem_euclid(every) < self.burst_len.as_secs_f64()
    }

    /// The thinning envelope: the largest rate `rate_at` can return.
    fn rate_max(&self) -> f64 {
        let peak = self.peak_rps.max(self.base_rps);
        if self.burst_every > Duration::ZERO {
            peak * self.burst_mult.max(1.0)
        } else {
            peak
        }
    }
}

/// Generate the deterministic arrival schedule for `cfg` — Poisson
/// thinning against the peak rate, so inter-arrival statistics are exact
/// for the non-homogeneous rate without any discretization grid. Arrivals
/// are strictly ordered by `at`.
pub fn generate_trace(cfg: &TraceConfig) -> Vec<Arrival> {
    assert!(cfg.rows_min >= 1 && cfg.rows_max >= cfg.rows_min, "bad rows range");
    let lambda_max = cfg.rate_max();
    assert!(lambda_max > 0.0, "trace needs a positive rate");
    let mut rng = Rng::new(cfg.seed ^ 0x510c_ace5_0f_7ace);
    let dur = cfg.duration.as_secs_f64();
    let mut t = 0.0f64;
    let mut out = Vec::new();
    loop {
        t += rng.exponential(lambda_max);
        if t >= dur {
            break;
        }
        // Thinning: keep this candidate with probability λ(t)/λ_max.
        if rng.f64() * lambda_max > cfg.rate_at(t) {
            continue;
        }
        let tenant = match cfg.hot_tenant {
            Some(hot) if cfg.n_tenants > 0 && rng.bool(cfg.hot_share.clamp(0.0, 1.0)) => {
                hot % cfg.n_tenants.max(1)
            }
            _ if cfg.n_tenants > 0 => rng.below(cfg.n_tenants as u64) as u32,
            _ => 0,
        };
        out.push(Arrival {
            at: Duration::from_secs_f64(t),
            tenant,
            n_rows: cfg.rows_min + rng.index(cfg.rows_max - cfg.rows_min + 1),
            low_priority: rng.bool(cfg.low_priority_share.clamp(0.0, 1.0)),
        });
    }
    out
}

// ---------------------------------------------------------------------------
// Controller

/// Controller tuning.
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// The admitted-request p99 the controller holds.
    pub p99_target: Duration,
    /// De-escalate only when measured p99 < `relax_below` × target — the
    /// hysteresis band that keeps the knobs from oscillating on noise.
    pub relax_below: f64,
    /// The pool's physical shard count (the active-set ceiling).
    pub max_shards: usize,
    /// Task-granularity floor under pressure (fine → steals spread load).
    pub fine_task_rows: usize,
    /// Task-granularity floor when calm (coarse → less scheduling spend).
    pub coarse_task_rows: usize,
    /// Admission-throttle floor (never starve a tenant to zero).
    pub min_rate_factor: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            p99_target: Duration::from_millis(50),
            relax_below: 0.5,
            max_shards: crate::util::threadpool::default_threads(),
            fine_task_rows: 16,
            coarse_task_rows: 64,
            min_rate_factor: 0.05,
        }
    }
}

/// One controller tick's view of the stack (assembled by the runner; any
/// monitoring pipeline could produce it).
#[derive(Clone, Copy, Debug, Default)]
pub struct Obs {
    /// Measured p99 of ADMITTED requests in the window (served or
    /// degraded — rejected requests are excluded: they completed fast by
    /// refusing, and must not flatter the latency signal).
    pub p99: Duration,
    /// Rows shed by the batcher's CoDel in the window.
    pub sojourn_shed: u64,
    /// Requests explicitly rejected at admission in the window.
    pub rejected: u64,
    /// Tasks queued across the pool's rings at tick time.
    pub queue_depth: usize,
    /// Shards executing a task at tick time.
    pub busy_shards: usize,
}

/// Knob settings the controller wants applied.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    /// Admission refill-rate multiplier, `min_rate_factor..=1.0`.
    pub rate_factor: f64,
    /// Brownout rung: 0 (off), `BROWNOUT_LOW_PRIORITY`, [`BROWNOUT_ALL`].
    pub brownout: u8,
    /// Shard-pool active set, `1..=max_shards`.
    pub active_shards: usize,
    /// Shard-pool task-granularity floor.
    pub min_task_rows: usize,
}

/// The overload-ladder controller: AIMD on the admitted p99. Pure state
/// machine — [`SloController::plan`] never reads a clock or touches I/O,
/// so every trajectory is unit-testable.
pub struct SloController {
    cfg: ControllerConfig,
    cur: Decision,
}

impl SloController {
    pub fn new(cfg: ControllerConfig) -> SloController {
        assert!(cfg.p99_target > Duration::ZERO, "p99 target must be positive");
        assert!(cfg.max_shards >= 1);
        let cur = Decision {
            rate_factor: 1.0,
            brownout: 0,
            active_shards: cfg.max_shards,
            min_task_rows: cfg.coarse_task_rows.max(1),
        };
        SloController { cfg, cur }
    }

    /// The current (last-planned) knob settings.
    pub fn current(&self) -> Decision {
        self.cur
    }

    /// One control tick: escalate one rung when the SLO is breached (or
    /// the batcher is shedding standing queues), de-escalate one rung when
    /// comfortably under target. One rung per tick in both directions —
    /// multiplicative throttle down, additive recovery up.
    pub fn plan(&mut self, obs: &Obs) -> Decision {
        let pressure = obs.p99.as_secs_f64() / self.cfg.p99_target.as_secs_f64();
        let breached = pressure > 1.0 || obs.sojourn_shed > 0;
        if breached {
            if self.cur.active_shards < self.cfg.max_shards {
                // Rung 1: more capacity, finer tasks so steals spread it.
                self.cur.active_shards =
                    (self.cur.active_shards * 2).min(self.cfg.max_shards);
                self.cur.min_task_rows = self.cfg.fine_task_rows.max(1);
            } else if self.cur.min_task_rows > self.cfg.fine_task_rows {
                self.cur.min_task_rows = self.cfg.fine_task_rows.max(1);
            } else if self.cur.brownout < BROWNOUT_ALL {
                // Rung 2: degrade before dropping.
                self.cur.brownout += 1;
            } else {
                // Rung 3: throttle admission (multiplicative decrease).
                self.cur.rate_factor =
                    (self.cur.rate_factor * 0.7).max(self.cfg.min_rate_factor);
            }
        } else if pressure < self.cfg.relax_below {
            if self.cur.rate_factor < 1.0 {
                // Recover admission first (additive increase).
                self.cur.rate_factor = (self.cur.rate_factor + 0.1).min(1.0);
            } else if self.cur.brownout > 0 {
                self.cur.brownout -= 1;
            } else if self.cur.active_shards > 1
                && obs.queue_depth == 0
                && obs.rejected == 0
                && obs.busy_shards * 2 < self.cur.active_shards
            {
                // Fully recovered AND mostly idle: shed cores — the
                // minimum-CPU half of the objective.
                self.cur.active_shards -= 1;
                self.cur.min_task_rows = self.cfg.coarse_task_rows.max(1);
            }
        }
        self.cur
    }
}

// ---------------------------------------------------------------------------
// Runner

/// Open-loop runner tuning.
#[derive(Clone, Debug)]
pub struct HarnessConfig {
    /// Controller cadence (also the trajectory sampling period).
    pub tick: Duration,
    /// Sender threads dispatching arrivals (open-loop up to this
    /// parallelism; a saturated sender pool shows up as offered-load lag,
    /// which is itself an overload signal).
    pub senders: usize,
    /// Per-request deadline budget (`None` = unbounded).
    pub deadline: Option<Duration>,
}

impl Default for HarnessConfig {
    fn default() -> Self {
        HarnessConfig {
            tick: Duration::from_millis(200),
            senders: 8,
            deadline: Some(Duration::from_millis(500)),
        }
    }
}

/// One trajectory sample: counts are for the tick's window, knobs are the
/// settings applied at the END of the tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct Tick {
    pub at_ms: u64,
    pub offered: u64,
    pub served: u64,
    pub degraded: u64,
    pub rejected: u64,
    pub deadline_shed: u64,
    pub errors: u64,
    /// Admitted-request latency quantiles in the window (µs; 0 if none).
    pub p50_us: u64,
    pub p99_us: u64,
    /// Process CPU burned this window, in cores (cpu-seconds per second).
    pub cpu_cores: f64,
    pub rate_factor: f64,
    pub brownout: u8,
    pub active_shards: usize,
    pub min_task_rows: usize,
}

/// A finished run's trajectory plus totals.
#[derive(Clone, Debug, Default)]
pub struct SloReport {
    pub ticks: Vec<Tick>,
    pub offered: u64,
    pub served: u64,
    pub degraded: u64,
    pub rejected: u64,
    pub deadline_shed: u64,
    pub errors: u64,
    /// p99 over every admitted request of the whole run (µs).
    pub overall_p99_us: u64,
}

impl SloReport {
    /// Conservation: every offered request is accounted exactly once.
    pub fn accounted(&self) -> u64 {
        self.served + self.degraded + self.rejected + self.deadline_shed + self.errors
    }

    /// Serialize the trajectory (the `BENCH_slo.json` payload).
    pub fn to_json(&self, title: &str) -> Json {
        let mut j = Json::obj();
        j.set("title", Json::Str(title.into()));
        j.set("offered", Json::Num(self.offered as f64));
        j.set("served", Json::Num(self.served as f64));
        j.set("degraded", Json::Num(self.degraded as f64));
        j.set("rejected", Json::Num(self.rejected as f64));
        j.set("deadline_shed", Json::Num(self.deadline_shed as f64));
        j.set("errors", Json::Num(self.errors as f64));
        j.set("overall_p99_us", Json::Num(self.overall_p99_us as f64));
        let ticks = self
            .ticks
            .iter()
            .map(|t| {
                let mut o = Json::obj();
                o.set("at_ms", Json::Num(t.at_ms as f64));
                o.set("offered", Json::Num(t.offered as f64));
                o.set("served", Json::Num(t.served as f64));
                o.set("degraded", Json::Num(t.degraded as f64));
                o.set("rejected", Json::Num(t.rejected as f64));
                o.set("deadline_shed", Json::Num(t.deadline_shed as f64));
                o.set("errors", Json::Num(t.errors as f64));
                o.set("p50_us", Json::Num(t.p50_us as f64));
                o.set("p99_us", Json::Num(t.p99_us as f64));
                o.set("cpu_cores", Json::Num(t.cpu_cores));
                o.set("rate_factor", Json::Num(t.rate_factor));
                o.set("brownout", Json::Num(t.brownout as f64));
                o.set("active_shards", Json::Num(t.active_shards as f64));
                o.set("min_task_rows", Json::Num(t.min_task_rows as f64));
                o
            })
            .collect();
        j.set("trajectory", Json::Arr(ticks));
        j
    }
}

/// Window accumulator shared by the sender pool and the controller loop.
#[derive(Default)]
struct Window {
    lat_us: Vec<u64>,
    offered: u64,
    served: u64,
    degraded: u64,
    rejected: u64,
    deadline_shed: u64,
    errors: u64,
}

/// The live knobs [`run_trace`] steers. Any handle may be absent (e.g. a
/// PJRT backend has no shard pool; a server without admission control has
/// no throttle) — the controller's decisions for missing knobs are still
/// recorded in the trajectory, just not applied.
pub struct Knobs<'a> {
    pub admission: Option<&'a Arc<AdmissionControl>>,
    pub pool: Option<&'a Arc<ShardPool>>,
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn quantile_us(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Drive `trace` against per-tenant coordinators (arrival tenant `t` maps
/// to `coords[t % coords.len()]`), ticking `controller` every
/// `cfg.tick` and applying its decisions to `knobs` + every coordinator's
/// brownout rung. `rows` supplies request payloads (cycled by arrival
/// index). Returns the full trajectory.
pub fn run_trace(
    coords: &[Arc<Coordinator>],
    knobs: &Knobs<'_>,
    metrics: &ServeMetrics,
    trace: &[Arrival],
    rows: &[Vec<f32>],
    controller: &mut SloController,
    cfg: &HarnessConfig,
) -> SloReport {
    assert!(!coords.is_empty(), "need at least one coordinator");
    assert!(!rows.is_empty(), "need request payload rows");
    let window = Mutex::new(Window::default());
    let all_lat = Mutex::new(Vec::<u64>::new());
    let cursor = AtomicUsize::new(0);
    let live_senders = AtomicUsize::new(cfg.senders.max(1));
    let start = Instant::now();

    let mut report = SloReport::default();
    std::thread::scope(|s| {
        for _ in 0..cfg.senders.max(1) {
            s.spawn(|| {
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    let Some(a) = trace.get(i) else { break };
                    let target = start + a.at;
                    let now = Instant::now();
                    if now < target {
                        std::thread::sleep(target - now);
                    }
                    let coord = &coords[a.tenant as usize % coords.len()];
                    let k = a.n_rows.clamp(1, rows.len());
                    let base = i % (rows.len() - k + 1);
                    let mut opts = PredictOptions {
                        deadline: cfg.deadline.map(Deadline::after),
                        ..PredictOptions::default()
                    };
                    if a.low_priority {
                        opts = opts.low_priority();
                    }
                    let t0 = Instant::now();
                    let res = coord.predict_batch_opts(&rows[base..base + k], &opts);
                    let lat = t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
                    let mut w = lock(&window);
                    w.offered += 1;
                    match res {
                        Ok(out) => {
                            if out.iter().any(|(_, s)| *s == Served::Degraded) {
                                w.degraded += 1;
                            } else {
                                w.served += 1;
                            }
                            w.lat_us.push(lat);
                        }
                        Err(e) if fault::is_overloaded(&e) => w.rejected += 1,
                        Err(e) if fault::is_deadline_exceeded(&e) => w.deadline_shed += 1,
                        Err(_) => w.errors += 1,
                    }
                }
                live_senders.fetch_sub(1, Ordering::Release);
            });
        }

        // Controller loop on this thread: tick until every sender drained.
        let mut cpu_prev = process_cpu_ns();
        let mut shed_prev = metrics.sojourn_shed_rows.load(Ordering::Relaxed);
        let mut rej_prev = metrics.rejected_requests.load(Ordering::Relaxed);
        loop {
            let done = live_senders.load(Ordering::Acquire) == 0;
            std::thread::sleep(cfg.tick);
            let mut w = {
                let mut g = lock(&window);
                std::mem::take(&mut *g)
            };
            w.lat_us.sort_unstable();
            let shed_now = metrics.sojourn_shed_rows.load(Ordering::Relaxed);
            // Server-side rejections count too: a coordinator under
            // `Stage1Prior` absorbs refusals into degraded answers, so the
            // caller-observed bucket alone under-reports door pressure.
            let rej_now = metrics.rejected_requests.load(Ordering::Relaxed);
            let obs = Obs {
                p99: Duration::from_micros(quantile_us(&w.lat_us, 0.99)),
                sojourn_shed: shed_now - shed_prev,
                rejected: w.rejected + (rej_now - rej_prev),
                queue_depth: knobs.pool.map_or(0, |p| p.queue_depth()),
                busy_shards: knobs.pool.map_or(0, |p| p.stats().busy_shards()),
            };
            shed_prev = shed_now;
            rej_prev = rej_now;
            let d = controller.plan(&obs);
            if let Some(ac) = knobs.admission {
                ac.set_rate_factor(d.rate_factor);
            }
            for c in coords {
                c.set_brownout(d.brownout);
            }
            // Guarded rollouts ride the same tick: an escalated controller
            // (brownout active or admission throttled) freezes any
            // in-flight canary ramp — an overloaded stack must not widen a
            // model experiment while it is shedding load.
            let escalated = d.brownout > 0 || d.rate_factor < 1.0;
            for c in coords {
                c.rollout_tick(escalated);
            }
            if let Some(pool) = knobs.pool {
                pool.set_active_shards(d.active_shards);
                pool.set_min_task_rows(d.min_task_rows);
            }
            let cpu_now = process_cpu_ns();
            let tick = Tick {
                at_ms: start.elapsed().as_millis().min(u64::MAX as u128) as u64,
                offered: w.offered,
                served: w.served,
                degraded: w.degraded,
                rejected: w.rejected,
                deadline_shed: w.deadline_shed,
                errors: w.errors,
                p50_us: quantile_us(&w.lat_us, 0.50),
                p99_us: quantile_us(&w.lat_us, 0.99),
                cpu_cores: (cpu_now.saturating_sub(cpu_prev)) as f64
                    / cfg.tick.as_nanos().max(1) as f64,
                rate_factor: d.rate_factor,
                brownout: d.brownout,
                active_shards: d.active_shards,
                min_task_rows: d.min_task_rows,
            };
            cpu_prev = cpu_now;
            report.offered += tick.offered;
            report.served += tick.served;
            report.degraded += tick.degraded;
            report.rejected += tick.rejected;
            report.deadline_shed += tick.deadline_shed;
            report.errors += tick.errors;
            lock(&all_lat).extend_from_slice(&w.lat_us);
            report.ticks.push(tick);
            if done {
                break;
            }
        }
    });

    let mut lat = std::mem::take(&mut *lock(&all_lat));
    lat.sort_unstable();
    report.overall_p99_us = quantile_us(&lat, 0.99);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> TraceConfig {
        TraceConfig {
            duration: Duration::from_secs(4),
            base_rps: 40.0,
            peak_rps: 160.0,
            burst_every: Duration::from_secs(1),
            burst_len: Duration::from_millis(200),
            burst_mult: 3.0,
            ..Default::default()
        }
    }

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let cfg = quick_cfg();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a, b, "same seed must replay bit-for-bit");
        assert!(!a.is_empty());
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at, "arrivals must be time-ordered");
        }
        assert!(a.iter().all(|x| x.at < cfg.duration));
        assert!(a
            .iter()
            .all(|x| (cfg.rows_min..=cfg.rows_max).contains(&x.n_rows)));
        let c = generate_trace(&TraceConfig { seed: 2, ..cfg });
        assert_ne!(a, c, "a different seed must give a different trace");
    }

    #[test]
    fn trace_bursts_and_hot_tenant_shape_the_load() {
        let cfg = TraceConfig {
            duration: Duration::from_secs(20),
            base_rps: 100.0,
            peak_rps: 100.0, // flat ramp isolates the burst signal
            burst_every: Duration::from_secs(2),
            burst_len: Duration::from_millis(500),
            burst_mult: 4.0,
            n_tenants: 4,
            hot_tenant: Some(2),
            hot_share: 0.6,
            ..Default::default()
        };
        let trace = generate_trace(&cfg);
        // Burst windows cover 25% of the time but at 4× rate: they should
        // hold a clear majority of arrivals (4/(4·0.25+0.75) ≈ 57%).
        let in_burst = trace.iter().filter(|a| cfg.in_burst(a.at.as_secs_f64())).count();
        assert!(
            in_burst * 2 > trace.len(),
            "bursts must dominate: {in_burst}/{}",
            trace.len()
        );
        // Hot tenant takes ~60% + its uniform share; everyone else gets
        // traffic too.
        let hot = trace.iter().filter(|a| a.tenant == 2).count();
        assert!(
            hot as f64 > 0.5 * trace.len() as f64,
            "hot-tenant skew missing: {hot}/{}",
            trace.len()
        );
        for t in [0u32, 1, 3] {
            assert!(
                trace.iter().any(|a| a.tenant == t),
                "tenant {t} got no traffic"
            );
        }
        // Diurnal ramp: with a real ramp, the middle half out-rates the
        // edges.
        let ramped = generate_trace(&TraceConfig {
            duration: Duration::from_secs(20),
            base_rps: 20.0,
            peak_rps: 200.0,
            burst_every: Duration::ZERO,
            ..Default::default()
        });
        let mid = ramped
            .iter()
            .filter(|a| (5.0..15.0).contains(&a.at.as_secs_f64()))
            .count();
        assert!(
            mid * 2 > ramped.len(),
            "diurnal peak must concentrate arrivals: {mid}/{}",
            ramped.len()
        );
    }

    #[test]
    fn controller_walks_the_ladder_up_and_down() {
        let cfg = ControllerConfig {
            p99_target: Duration::from_millis(10),
            relax_below: 0.5,
            max_shards: 4,
            fine_task_rows: 8,
            coarse_task_rows: 64,
            min_rate_factor: 0.05,
        };
        let mut c = SloController::new(cfg);
        // Start shrunk (as a long-idle controller would be).
        c.cur.active_shards = 1;
        let hot = Obs { p99: Duration::from_millis(40), ..Default::default() };

        // Escalation order: capacity → brownout rungs → admission.
        let d = c.plan(&hot);
        assert_eq!(d.active_shards, 2, "capacity first");
        assert_eq!(d.min_task_rows, 8, "pressure splits tasks finer");
        assert_eq!((d.brownout, d.rate_factor), (0, 1.0));
        let d = c.plan(&hot);
        assert_eq!(d.active_shards, 4);
        let d = c.plan(&hot);
        assert_eq!(d.brownout, 1, "degrade low-priority before dropping");
        let d = c.plan(&hot);
        assert_eq!(d.brownout, 2);
        let d = c.plan(&hot);
        assert!(d.rate_factor < 1.0, "last rung: throttle admission");
        let floor = (0..100).fold(d, |_, _| c.plan(&hot));
        assert!(floor.rate_factor >= 0.05, "throttle must floor, not starve");

        // A shedding batcher counts as pressure even with a quiet p99.
        let mut c2 = SloController::new(ControllerConfig {
            max_shards: 2,
            ..ControllerConfig::default()
        });
        c2.cur.active_shards = 1;
        let shedding = Obs { sojourn_shed: 5, ..Default::default() };
        assert_eq!(c2.plan(&shedding).active_shards, 2);

        // De-escalation in reverse: admission recovers first, then the
        // brownout lifts, then idle capacity sheds.
        let calm = Obs { p99: Duration::from_millis(1), ..Default::default() };
        let mut d = c.plan(&calm);
        while d.rate_factor < 1.0 {
            let next = c.plan(&calm);
            assert!(next.rate_factor >= d.rate_factor);
            assert_eq!(next.brownout, 2, "brownout holds until admission recovers");
            d = next;
        }
        let d = c.plan(&calm);
        assert_eq!(d.brownout, 1);
        let d = c.plan(&calm);
        assert_eq!(d.brownout, 0);
        let d = c.plan(&calm);
        assert_eq!(d.active_shards, 3, "idle pool sheds cores last");
        assert_eq!(d.min_task_rows, 64, "calm pool coarsens tasks");

        // Mid-band (hysteresis): nothing moves.
        let mid = Obs { p99: Duration::from_millis(8), ..Default::default() };
        let before = c.current();
        assert_eq!(c.plan(&mid), before, "inside the band the knobs hold");

        // A busy-but-meeting-SLO pool must NOT shrink.
        let busy_calm = Obs {
            p99: Duration::from_millis(1),
            busy_shards: 3,
            ..Default::default()
        };
        let held = c.plan(&busy_calm);
        assert_eq!(held.active_shards, 3, "occupied cores are not shed");
    }

    #[test]
    fn report_json_has_the_trajectory_sections() {
        let report = SloReport {
            ticks: vec![Tick { at_ms: 200, offered: 10, served: 9, rejected: 1, ..Default::default() }],
            offered: 10,
            served: 9,
            rejected: 1,
            ..Default::default()
        };
        assert_eq!(report.accounted(), 10);
        let j = report.to_json("slo_trace");
        let text = j.to_string();
        let back = Json::parse(&text).expect("report JSON must round-trip");
        assert_eq!(back.get("offered").and_then(Json::as_usize), Some(10));
        let traj = back.get("trajectory").expect("trajectory section");
        match traj {
            Json::Arr(items) => {
                assert_eq!(items.len(), 1);
                assert_eq!(items[0].get("rejected").and_then(Json::as_usize), Some(1));
            }
            _ => panic!("trajectory must be an array"),
        }
    }
}
