//! Synthetic tabular dataset generators.
//!
//! The paper evaluates on four proprietary Meta datasets (Case 1–4) and 20+
//! public tabular sets; neither is available offline, so this module builds
//! seeded synthetic *clones* with matched row counts, feature counts and
//! feature-type mixes (DESIGN.md §6). Labels come from a structured teacher:
//!
//! ```text
//! logit(x) = lin·(w·x)  +  pw·(w_{region(x)}·x)  +  inter·Σ_k c_k·rule_k(x)  +  noise·ε
//! ```
//!
//! * the **global linear** term gives plain LR its signal;
//! * the **region-local linear** term (regions = sign pattern of the top
//!   informative features) is exactly the structure LRwBins exploits — a
//!   separating surface that is *locally* linear but globally bent
//!   (paper Fig. 1);
//! * the **interaction rules** (conjunctions of threshold indicators) are
//!   tree-friendly structure that keeps the GBDT strictly ahead;
//! * noise sets the overall Bayes ceiling.
//!
//! The per-dataset mix is calibrated so LR < LRwBins < GBDT with gaps in the
//! paper's ballpark (EXPERIMENTS.md records paper-vs-measured side by side).

use crate::tabular::{ColType, Dataset, Schema};
use crate::util::rng::Rng;
use crate::util::sigmoid;

/// Distribution shapes for numeric features — tabular features "exhibit
/// different scales and do not correlate" (paper §1).
#[derive(Clone, Copy, Debug)]
enum NumDist {
    Normal { mean: f64, std: f64 },
    LogNormal { mu: f64, sigma: f64 },
    Uniform { lo: f64, hi: f64 },
    /// Student-t-ish heavy tail via normal ratio.
    HeavyTail { scale: f64 },
}

impl NumDist {
    fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            NumDist::Normal { mean, std } => rng.normal_ms(mean, std),
            NumDist::LogNormal { mu, sigma } => rng.lognormal(mu, sigma),
            NumDist::Uniform { lo, hi } => rng.range_f64(lo, hi),
            NumDist::HeavyTail { scale } => {
                let z = rng.normal();
                let d = rng.normal().abs().max(0.25);
                scale * z / d
            }
        }
    }

    fn random(rng: &mut Rng) -> NumDist {
        match rng.index(4) {
            0 => NumDist::Normal {
                mean: rng.range_f64(-5.0, 5.0),
                std: rng.range_f64(0.3, 3.0),
            },
            1 => NumDist::LogNormal {
                mu: rng.range_f64(-1.0, 2.0),
                sigma: rng.range_f64(0.3, 1.0),
            },
            2 => NumDist::Uniform {
                lo: rng.range_f64(-10.0, 0.0),
                hi: rng.range_f64(0.5, 10.0),
            },
            _ => NumDist::HeavyTail {
                scale: rng.range_f64(0.5, 2.0),
            },
        }
    }
}

/// Specification of a synthetic dataset (clone of one paper dataset).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub rows: usize,
    pub n_numeric: usize,
    pub n_boolean: usize,
    pub n_categorical: usize,
    /// Number of informative features (teacher inputs).
    pub informative: usize,
    /// Teacher mix weights.
    pub linear_w: f64,
    pub piecewise_w: f64,
    pub interaction_w: f64,
    /// Label noise: std of the logit perturbation.
    pub noise: f64,
    /// Overall logit scale (higher → more separable → higher AUC ceiling).
    pub scale: f64,
    /// Target positive rate.
    pub pos_rate: f64,
    /// Structure seed: teacher parameters depend on this (fixed per dataset),
    /// while the sampling seed varies per experiment repetition.
    pub structure_seed: u64,
}

impl DatasetSpec {
    pub fn n_features(&self) -> usize {
        self.n_numeric + self.n_boolean + self.n_categorical
    }

    /// Copy with a different row count (Fig. 6 scaling study).
    pub fn with_rows(&self, rows: usize) -> DatasetSpec {
        DatasetSpec {
            rows,
            ..self.clone()
        }
    }
}

/// Teacher parameters (deterministic given the structure seed).
struct Teacher {
    /// Indices of informative features.
    informative: Vec<usize>,
    /// Global linear weights over informative features.
    w_global: Vec<f64>,
    /// Region-defining features (subset of informative, up to 3 → 8 regions).
    region_feats: Vec<usize>,
    /// Region thresholds (median-ish of the feature distribution).
    region_thresh: Vec<f64>,
    /// Per-region local linear weights.
    w_region: Vec<Vec<f64>>,
    /// Interaction rules: (feature a, thresh a, feature b, thresh b, coeff).
    rules: Vec<(usize, f64, usize, f64, f64)>,
    /// Per-category offsets for categorical informative features.
    cat_effects: Vec<(usize, Vec<f64>)>,
    /// Bias calibrated for the target positive rate.
    bias: f64,
}

/// Generate the dataset for `spec`. `sample_seed` drives row sampling; the
/// teacher structure is fixed by `spec.structure_seed` so repeated
/// experiments (Table 1's 20 seeds) draw fresh rows from the *same* world.
pub fn generate(spec: &DatasetSpec, sample_seed: u64) -> Dataset {
    let nf = spec.n_features();
    // --- structure RNG: feature distributions + teacher ---
    let mut srng = Rng::new(spec.structure_seed ^ 0x5EED_5EED);
    let mut types = Vec::with_capacity(nf);
    let mut names = Vec::with_capacity(nf);
    let mut dists = Vec::with_capacity(nf);
    for i in 0..spec.n_numeric {
        types.push(ColType::Numeric);
        names.push(format!("num{i}"));
        dists.push(Some(NumDist::random(&mut srng)));
    }
    let mut bool_p = Vec::new();
    for i in 0..spec.n_boolean {
        types.push(ColType::Boolean);
        names.push(format!("bool{i}"));
        dists.push(None);
        bool_p.push(srng.range_f64(0.1, 0.9));
    }
    let mut cat_card = Vec::new();
    let mut cat_weights: Vec<Vec<f64>> = Vec::new();
    for i in 0..spec.n_categorical {
        let card = 3 + srng.index(6); // 3..8 categories
        types.push(ColType::Categorical { cardinality: card });
        names.push(format!("cat{i}"));
        dists.push(None);
        cat_card.push(card);
        cat_weights.push((0..card).map(|_| srng.range_f64(0.2, 1.0)).collect());
    }

    let teacher = build_teacher(spec, &types, &dists, &bool_p, &cat_weights, &mut srng);

    // --- sampling RNG ---
    let mut rng = Rng::new(sample_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ spec.structure_seed);
    let mut data = Dataset::new(Schema { names, types });
    let mut row = vec![0f32; nf];
    for _ in 0..spec.rows {
        // Sample features.
        let mut bi = 0;
        let mut ci = 0;
        for f in 0..nf {
            row[f] = match &data.schema.types[f] {
                ColType::Numeric => dists[f].as_ref().unwrap().sample(&mut rng) as f32,
                ColType::Boolean => {
                    let v = rng.bool(bool_p[bi]) as u8 as f32;
                    bi += 1;
                    v
                }
                ColType::Categorical { .. } => {
                    let v = rng.categorical(&cat_weights[ci]) as f32;
                    ci += 1;
                    v
                }
            };
        }
        if bi > 0 {
            bi = 0; // silence unused in release
            let _ = bi;
        }
        let logit = teacher_logit(&teacher, spec, &row, &mut rng);
        let y = rng.bool(sigmoid(logit)) as u8 as f32;
        data.push_row(&row, y);
    }
    data
}

fn build_teacher(
    spec: &DatasetSpec,
    types: &[ColType],
    dists: &[Option<NumDist>],
    bool_p: &[f64],
    cat_weights: &[Vec<f64>],
    srng: &mut Rng,
) -> Teacher {
    let nf = types.len();
    let k = spec.informative.clamp(1, nf);
    let informative = srng.sample_indices(nf, k);

    // Per-feature standardization constants so weights are comparable:
    // estimate mean/std of each informative feature analytically-ish by
    // sampling the distribution.
    let mut feat_stats = vec![(0.0f64, 1.0f64); nf];
    for &f in &informative {
        let (m, s) = match &types[f] {
            ColType::Numeric => {
                let mut probe = srng.fork();
                let xs: Vec<f64> = (0..512).map(|_| dists[f].as_ref().unwrap().sample(&mut probe)).collect();
                let mean = xs.iter().sum::<f64>() / xs.len() as f64;
                let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
                (mean, var.sqrt().max(1e-6))
            }
            _ => (0.0, 1.0),
        };
        feat_stats[f] = (m, s);
    }

    let decaying_weight = |i: usize, srng: &mut Rng| {
        // Importance decays with rank → a clear "most important features"
        // ordering, as the paper's Fig. 5 shows.
        let mag = 1.0 / (1.0 + 0.35 * i as f64);
        let sign = if srng.bool(0.5) { 1.0 } else { -1.0 };
        sign * mag * srng.range_f64(0.6, 1.4)
    };

    let w_global: Vec<f64> = (0..k).map(|i| decaying_weight(i, srng)).collect();

    // Regions from the top ≤3 informative features.
    let nr_feats = k.min(3);
    let region_feats: Vec<usize> = informative[..nr_feats].to_vec();
    let region_thresh: Vec<f64> = region_feats.iter().map(|&f| feat_stats[f].0).collect();
    let n_regions = 1usize << nr_feats;
    let w_region: Vec<Vec<f64>> = (0..n_regions)
        .map(|_| (0..k).map(|i| decaying_weight(i, srng)).collect())
        .collect();

    // Interaction rules: conjunctions of two thresholds on informative feats.
    let n_rules = (k * 2).clamp(4, 24);
    let rules = (0..n_rules)
        .map(|_| {
            let a = informative[srng.index(k)];
            let b = informative[srng.index(k)];
            let ta = feat_stats[a].0 + feat_stats[a].1 * srng.range_f64(-1.0, 1.0);
            let tb = feat_stats[b].0 + feat_stats[b].1 * srng.range_f64(-1.0, 1.0);
            let c = srng.range_f64(0.5, 1.5) * if srng.bool(0.5) { 1.0 } else { -1.0 };
            (a, ta, b, tb, c)
        })
        .collect();

    // Categorical informative features get per-category offsets.
    let cat_effects = informative
        .iter()
        .filter_map(|&f| match types[f] {
            ColType::Categorical { cardinality } => Some((
                f,
                (0..cardinality).map(|_| srng.range_f64(-1.0, 1.0)).collect(),
            )),
            _ => None,
        })
        .collect();

    let mut teacher = Teacher {
        informative,
        w_global,
        region_feats,
        region_thresh,
        w_region,
        rules,
        cat_effects,
        bias: 0.0,
    };

    // Calibrate the bias to hit the target positive rate: draw a probe
    // sample with the SAME feature samplers the generator uses and bisect
    // the bias (mean sigmoid is monotone in bias, so bisection is robust
    // where Newton can explode on saturated logits).
    let mut probe_rng = Rng::new(spec.structure_seed ^ 0xCA11_B4A7E);
    let probe_rows = 4096.min(spec.rows.max(512));
    let mut logits = Vec::with_capacity(probe_rows);
    let nfeat = types.len();
    let mut row = vec![0f32; nfeat];
    for _ in 0..probe_rows {
        let mut bi = 0;
        let mut ci = 0;
        for f in 0..nfeat {
            row[f] = match &types[f] {
                ColType::Numeric => dists[f].as_ref().unwrap().sample(&mut probe_rng) as f32,
                ColType::Boolean => {
                    let v = probe_rng.bool(bool_p[bi]) as u8 as f32;
                    bi += 1;
                    v
                }
                ColType::Categorical { .. } => {
                    let v = probe_rng.categorical(&cat_weights[ci]) as f32;
                    ci += 1;
                    v
                }
            };
        }
        // Include the label-noise term: it pulls the mean sigmoid toward
        // 0.5, so calibrating without it misses the target on noisy specs.
        logits.push(teacher_logit_raw(&teacher, spec, &row) + spec.noise * probe_rng.normal());
    }
    let mean_p = |bias: f64| -> f64 {
        logits.iter().map(|&l| sigmoid(l + bias)).sum::<f64>() / logits.len() as f64
    };
    let (mut lo, mut hi) = (-60.0f64, 60.0f64);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if mean_p(mid) < spec.pos_rate {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    teacher.bias = 0.5 * (lo + hi);
    teacher
}

/// Teacher logit without noise (used for bias calibration).
fn teacher_logit_raw(t: &Teacher, spec: &DatasetSpec, row: &[f32]) -> f64 {
    let k = t.informative.len();
    // Region id from sign pattern.
    let mut region = 0usize;
    for (j, (&f, &th)) in t.region_feats.iter().zip(&t.region_thresh).enumerate() {
        if row[f] as f64 > th {
            region |= 1 << j;
        }
    }
    let mut lin = 0.0;
    let mut pw = 0.0;
    for (i, &f) in t.informative.iter().enumerate() {
        let x = row[f] as f64;
        // standardize-ish via tanh squash to keep heavy tails bounded
        let z = (x / 3.0).tanh() * 3.0;
        lin += t.w_global[i] * z;
        pw += t.w_region[region][i] * z;
    }
    let mut inter = 0.0;
    for &(a, ta, b, tb, c) in &t.rules {
        if row[a] as f64 > ta && row[b] as f64 > tb {
            inter += c;
        }
    }
    let mut cat = 0.0;
    for (f, effects) in &t.cat_effects {
        let idx = (row[*f] as usize).min(effects.len() - 1);
        cat += effects[idx];
    }
    let norm = (k as f64).sqrt().max(1.0);
    // Categorical code effects are linear in one-hot space but invisible to
    // an LR over raw codes — i.e. tree-capturable signal. Scale them with
    // the interaction mix so LR-friendly presets stay LR-friendly.
    spec.scale
        * (spec.linear_w * lin / norm
            + spec.piecewise_w * pw / norm
            + spec.interaction_w * inter / (t.rules.len() as f64).sqrt()
            + spec.interaction_w * cat * 0.5)
        + t.bias
}

fn teacher_logit(t: &Teacher, spec: &DatasetSpec, row: &[f32], rng: &mut Rng) -> f64 {
    teacher_logit_raw(t, spec, row) + spec.noise * rng.normal()
}

/// Named presets cloning the paper's Table 1 datasets. Feature-type mixes
/// are chosen to match each dataset's description; teacher mixes are
/// calibrated so the LR / LRwBins / XGB ordering and gap sizes land near the
/// paper's (see EXPERIMENTS.md §Table 1 for measured values).
pub fn preset(name: &str) -> Option<DatasetSpec> {
    let s = |name: &str,
             rows: usize,
             nn: usize,
             nb: usize,
             nc: usize,
             informative: usize,
             linear_w: f64,
             piecewise_w: f64,
             interaction_w: f64,
             noise: f64,
             scale: f64,
             pos_rate: f64,
             seed: u64| DatasetSpec {
        name: name.to_string(),
        rows,
        n_numeric: nn,
        n_boolean: nb,
        n_categorical: nc,
        informative,
        linear_w,
        piecewise_w,
        interaction_w,
        noise,
        scale,
        pos_rate,
        structure_seed: seed,
    };
    Some(match name {
        // Production cases: big, heterogeneous, moderate-to-hard.
        "case1" => s("case1", 1_000_000, 48, 8, 6, 12, 1.0, 0.6, 0.5, 0.8, 4.2, 0.20, 101),
        "case2" => s("case2", 1_000_000, 140, 20, 16, 16, 0.85, 0.55, 0.55, 1.7, 3.1, 0.12, 102),
        "case3" => s("case3", 59_000, 16, 4, 2, 8, 0.2, 0.9, 1.1, 2.4, 1.7, 0.30, 103),
        "case4" => s("case4", 73_000, 220, 28, 20, 12, 0.45, 0.35, 1.1, 2.5, 2.1, 0.10, 104),
        // Public dataset clones.
        "aci" => s("aci", 33_000, 6, 3, 6, 10, 1.4, 0.1, 0.35, 0.45, 4.5, 0.24, 105),
        "blastchar" => s("blastchar", 7_000, 4, 10, 6, 12, 1.4, 0.05, 0.05, 0.7, 3.8, 0.27, 106),
        "shrutime" => s("shrutime", 10_000, 6, 3, 2, 8, 0.5, 1.7, 0.4, 0.7, 2.6, 0.20, 107),
        "patient" => s("patient", 92_000, 150, 20, 16, 14, 1.1, 0.35, 0.6, 0.7, 3.8, 0.08, 108),
        "banknote" => s("banknote", 1_400, 4, 0, 0, 4, 0.9, 1.4, 0.8, 0.15, 5.5, 0.44, 109),
        "jasmine" => s("jasmine", 3_000, 100, 36, 8, 10, 1.0, 0.45, 0.4, 0.8, 2.6, 0.50, 110),
        "higgs" => s("higgs", 98_000, 28, 2, 2, 14, 0.45, 1.5, 0.7, 2.0, 1.6, 0.53, 111),
        _ => return None,
    })
}

/// All preset names, in the order of the paper's Table 1.
pub const PRESET_NAMES: &[&str] = &[
    "case1", "case2", "case3", "case4", "aci", "blastchar", "shrutime", "patient", "banknote",
    "jasmine", "higgs",
];

/// Names of the "public" clones (std errors reported over 20 seeds).
pub const PUBLIC_NAMES: &[&str] = &[
    "aci", "blastchar", "shrutime", "patient", "banknote", "jasmine", "higgs",
];

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec() -> DatasetSpec {
        let mut s = preset("aci").unwrap();
        s.rows = 4000;
        s
    }

    #[test]
    fn generates_requested_shape() {
        let spec = quick_spec();
        let d = generate(&spec, 1);
        assert_eq!(d.n_rows(), 4000);
        assert_eq!(d.n_features(), spec.n_features());
        d.validate().unwrap();
    }

    #[test]
    fn positive_rate_near_target() {
        let spec = quick_spec();
        let d = generate(&spec, 2);
        let rate = d.positive_rate();
        assert!(
            (rate - spec.pos_rate).abs() < 0.08,
            "rate={rate} target={}",
            spec.pos_rate
        );
    }

    #[test]
    fn same_structure_different_samples() {
        let spec = quick_spec();
        let d1 = generate(&spec, 1);
        let d2 = generate(&spec, 2);
        // Different rows...
        assert_ne!(d1.cols[0][..50], d2.cols[0][..50]);
        // ...but same schema and similar label rates (same world).
        assert_eq!(d1.schema.names, d2.schema.names);
        assert!((d1.positive_rate() - d2.positive_rate()).abs() < 0.05);
    }

    #[test]
    fn deterministic_given_seeds() {
        let spec = quick_spec();
        let d1 = generate(&spec, 7);
        let d2 = generate(&spec, 7);
        assert_eq!(d1.cols[0], d2.cols[0]);
        assert_eq!(d1.labels, d2.labels);
    }

    #[test]
    fn labels_are_learnable() {
        // The teacher signal must be recoverable: a trivial single-feature
        // threshold on an informative feature should beat random.
        let spec = quick_spec();
        let d = generate(&spec, 3);
        // Use |corr| of best feature with label as a learnability proxy.
        let n = d.n_rows() as f64;
        let ybar = d.positive_rate();
        let mut best = 0.0f64;
        for c in &d.cols {
            let xbar = c.iter().map(|&v| v as f64).sum::<f64>() / n;
            let mut cov = 0.0;
            let mut vx = 0.0;
            let mut vy = 0.0;
            for (&x, &y) in c.iter().zip(&d.labels) {
                let dx = x as f64 - xbar;
                let dy = y as f64 - ybar;
                cov += dx * dy;
                vx += dx * dx;
                vy += dy * dy;
            }
            if vx > 0.0 && vy > 0.0 {
                best = best.max((cov / (vx.sqrt() * vy.sqrt())).abs());
            }
        }
        assert!(best > 0.08, "no informative feature found, best corr {best}");
    }

    #[test]
    fn all_presets_construct() {
        for name in PRESET_NAMES {
            let p = preset(name).unwrap();
            assert!(p.n_features() > 0);
            assert!(p.informative <= p.n_features());
            // Tiny sample generates cleanly.
            let d = generate(&p.with_rows(200), 1);
            assert_eq!(d.n_rows(), 200);
            d.validate().unwrap();
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn feature_counts_match_paper() {
        // Table 1 feature counts.
        for (name, feats) in [
            ("case1", 62),
            ("case2", 176),
            ("case3", 22),
            ("case4", 268),
            ("aci", 15),
            ("blastchar", 20),
            ("shrutime", 11),
            ("patient", 186),
            ("banknote", 4),
            ("jasmine", 144),
            ("higgs", 32),
        ] {
            assert_eq!(preset(name).unwrap().n_features(), feats, "{name}");
        }
    }
}
