//! MRMR feature selection (Ding & Peng 2005) — the paper's model-free
//! `RankFeatures` option.
//!
//! Relevance = mutual information I(f; y); redundancy = mean I(f; s) over
//! already-selected features s. Greedy selection maximizes
//! `relevance − redundancy`. MI is estimated on quantile-binned features
//! (16 bins), which is standard for continuous tabular data.

use crate::tabular::{ColType, Dataset};

const MI_BINS: usize = 16;

/// Discretize a column into ≤ `MI_BINS` integer codes.
fn discretize(col: &[f32], ctype: &ColType) -> (Vec<u8>, usize) {
    match ctype {
        ColType::Boolean => (col.iter().map(|&v| (v > 0.5) as u8).collect(), 2),
        ColType::Categorical { cardinality } => {
            let k = (*cardinality).min(MI_BINS);
            (
                col.iter().map(|&v| (v as usize).min(k - 1) as u8).collect(),
                k,
            )
        }
        ColType::Numeric => {
            let edges = crate::tabular::stats::bin_boundaries(col, MI_BINS);
            let mut uniq = edges.clone();
            uniq.dedup();
            let codes: Vec<u8> = col
                .iter()
                .map(|&v| uniq.partition_point(|&e| e < v) as u8)
                .collect();
            (codes, uniq.len() + 1)
        }
    }
}

/// Mutual information (nats) between two discrete code vectors.
fn mutual_information(a: &[u8], ka: usize, b: &[u8], kb: usize) -> f64 {
    let n = a.len();
    if n == 0 {
        return 0.0;
    }
    let mut joint = vec![0u32; ka * kb];
    let mut pa = vec![0u32; ka];
    let mut pb = vec![0u32; kb];
    for (&x, &y) in a.iter().zip(b) {
        joint[x as usize * kb + y as usize] += 1;
        pa[x as usize] += 1;
        pb[y as usize] += 1;
    }
    let nf = n as f64;
    let mut mi = 0.0;
    for x in 0..ka {
        if pa[x] == 0 {
            continue;
        }
        for y in 0..kb {
            let j = joint[x * kb + y];
            if j == 0 || pb[y] == 0 {
                continue;
            }
            let pxy = j as f64 / nf;
            mi += pxy * (pxy / ((pa[x] as f64 / nf) * (pb[y] as f64 / nf))).ln();
        }
    }
    mi.max(0.0)
}

/// Full MRMR ranking of all features.
pub fn mrmr_ranking(data: &Dataset) -> super::Ranking {
    let nf = data.n_features();
    let n = data.n_rows();
    // Subsample rows for MI estimation speed.
    let (codes, cards): (Vec<Vec<u8>>, Vec<usize>) = {
        let max_rows = 20_000;
        let cols: Vec<Vec<f32>> = if n > max_rows {
            let stride = n / max_rows;
            data.cols
                .iter()
                .map(|c| c.iter().step_by(stride).copied().collect())
                .collect()
        } else {
            data.cols.clone()
        };
        let mut codes = Vec::with_capacity(nf);
        let mut cards = Vec::with_capacity(nf);
        for (f, c) in cols.iter().enumerate() {
            let (cc, k) = discretize(c, &data.schema.types[f]);
            codes.push(cc);
            cards.push(k);
        }
        (codes, cards)
    };
    let labels: Vec<u8> = {
        let max_rows = 20_000;
        let l: Vec<u8> = if n > max_rows {
            let stride = n / max_rows;
            data.labels.iter().step_by(stride).map(|&y| (y > 0.5) as u8).collect()
        } else {
            data.labels.iter().map(|&y| (y > 0.5) as u8).collect()
        };
        l
    };

    // Relevance.
    let relevance: Vec<f64> = (0..nf)
        .map(|f| mutual_information(&codes[f], cards[f], &labels, 2))
        .collect();

    // Greedy MRMR. Pairwise MI is only computed lazily against selected
    // features (O(nf · selected) MI evaluations).
    let mut selected: Vec<usize> = Vec::with_capacity(nf);
    let mut scores: Vec<f64> = Vec::with_capacity(nf);
    let mut remaining: Vec<usize> = (0..nf).collect();
    // redundancy_sum[f] = Σ_{s ∈ selected} I(f; s)
    let mut redundancy_sum = vec![0.0f64; nf];

    while !remaining.is_empty() {
        let mut best_i = 0;
        let mut best_score = f64::NEG_INFINITY;
        for (i, &f) in remaining.iter().enumerate() {
            let red = if selected.is_empty() {
                0.0
            } else {
                redundancy_sum[f] / selected.len() as f64
            };
            let s = relevance[f] - red;
            if s > best_score {
                best_score = s;
                best_i = i;
            }
        }
        let f = remaining.swap_remove(best_i);
        selected.push(f);
        scores.push(best_score);
        // Update redundancy sums with the newly-selected feature.
        if !remaining.is_empty() {
            for &r in &remaining {
                redundancy_sum[r] += mutual_information(&codes[r], cards[r], &codes[f], cards[f]);
            }
        }
    }

    super::Ranking {
        order: selected,
        scores,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tabular::{Dataset, Schema};
    use crate::util::rng::Rng;

    #[test]
    fn mi_of_identical_is_entropy() {
        let a = vec![0u8, 0, 1, 1, 1, 1];
        // I(X;X) = H(X) = -(1/3 ln 1/3 + 2/3 ln 2/3)
        let h = -((1.0f64 / 3.0) * (1.0f64 / 3.0).ln() + (2.0 / 3.0) * (2.0f64 / 3.0).ln());
        assert!((mutual_information(&a, 2, &a, 2) - h).abs() < 1e-12);
    }

    #[test]
    fn mi_of_independent_near_zero() {
        let mut rng = Rng::new(1);
        let a: Vec<u8> = (0..20_000).map(|_| rng.index(4) as u8).collect();
        let b: Vec<u8> = (0..20_000).map(|_| rng.index(4) as u8).collect();
        assert!(mutual_information(&a, 4, &b, 4) < 0.005);
    }

    #[test]
    fn mi_nonnegative_property() {
        use crate::prop_assert;
        crate::util::proptest::check(50, |g| {
            let n = g.usize(1..500);
            let a: Vec<u8> = (0..n).map(|_| g.usize(0..5) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| g.usize(0..3) as u8).collect();
            let mi = mutual_information(&a, 5, &b, 3);
            prop_assert!(mi >= 0.0, "mi={mi}");
            prop_assert!(mi.is_finite());
            Ok(())
        });
    }

    #[test]
    fn mrmr_prefers_informative_and_penalizes_redundant() {
        // f0 informative; f1 = copy of f0 (redundant); f2 weak independent.
        let mut rng = Rng::new(2);
        let mut d = Dataset::new(Schema::numeric(3));
        for _ in 0..5000 {
            let a = rng.normal() as f32;
            let c = rng.normal() as f32;
            let logit = 2.5 * a as f64 + 0.6 * c as f64;
            let y = rng.bool(crate::util::sigmoid(logit)) as u8 as f32;
            d.push_row(&[a, a + 0.01 * rng.normal() as f32, c], y);
        }
        let r = mrmr_ranking(&d);
        // First pick: f0 or f1 (equally relevant). Second pick must NOT be
        // the redundant twin — MRMR should pick f2.
        assert!(r.order[0] == 0 || r.order[0] == 1);
        assert_eq!(r.order[1], 2, "order={:?}", r.order);
    }

    #[test]
    fn ranking_covers_all_features() {
        let mut rng = Rng::new(3);
        let mut d = Dataset::new(Schema::numeric(5));
        for _ in 0..500 {
            let row: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
            let y = (row[0] > 0.0) as u8 as f32;
            d.push_row(&row, y);
        }
        let r = mrmr_ranking(&d);
        let mut o = r.order.clone();
        o.sort_unstable();
        assert_eq!(o, vec![0, 1, 2, 3, 4]);
    }
}
