//! Feature-importance ranking — Algorithm 1 line 1 (`RankFeatures`).
//!
//! Two interchangeable methods, as in the paper §3:
//! * **model-free**: MRMR (minimum-redundancy maximum-relevance) on
//!   mutual information over quantile-binned features [`mrmr`];
//! * **model-based**: gain importance from a small GBDT [`gain_ranking`].

pub mod mrmr;

use crate::gbdt::{self, GbdtParams};
use crate::tabular::Dataset;

/// Ranking method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankMethod {
    Mrmr,
    GbdtGain,
}

/// Feature ranking result: indices sorted by decreasing importance, plus the
/// raw scores (aligned with `order`).
#[derive(Clone, Debug)]
pub struct Ranking {
    pub order: Vec<usize>,
    pub scores: Vec<f64>,
}

impl Ranking {
    /// The `n` most important features.
    pub fn top(&self, n: usize) -> Vec<usize> {
        self.order[..n.min(self.order.len())].to_vec()
    }
}

/// Rank features with the chosen method.
pub fn rank_features(data: &Dataset, method: RankMethod, seed: u64) -> Ranking {
    match method {
        RankMethod::Mrmr => mrmr::mrmr_ranking(data),
        RankMethod::GbdtGain => gain_ranking(data, seed),
    }
}

/// Model-based ranking: train a small GBDT and sort by accumulated gain.
pub fn gain_ranking(data: &Dataset, seed: u64) -> Ranking {
    // Subsample rows for speed — importance is stable under subsampling.
    let sub = if data.n_rows() > 50_000 {
        let idx: Vec<usize> = (0..data.n_rows()).step_by(data.n_rows() / 50_000).collect();
        data.take_rows(&idx)
    } else {
        data.clone()
    };
    let params = GbdtParams {
        n_trees: 30,
        max_depth: 5,
        learning_rate: 0.2,
        colsample: 0.9,
        seed,
        ..Default::default()
    };
    let model = gbdt::train(&sub, &params);
    let order = model.importance_ranking();
    let scores = order.iter().map(|&f| model.feature_gain[f]).collect();
    Ranking { order, scores }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tabular::Schema;
    use crate::util::rng::Rng;

    /// Feature 0 strongly informative, 1 weakly, 2 pure noise.
    fn graded_dataset(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut d = Dataset::new(Schema::numeric(3));
        for _ in 0..n {
            let a = rng.normal() as f32;
            let b = rng.normal() as f32;
            let c = rng.normal() as f32;
            let logit = 3.0 * a as f64 + 0.7 * b as f64;
            let y = rng.bool(crate::util::sigmoid(logit)) as u8 as f32;
            d.push_row(&[a, b, c], y);
        }
        d
    }

    #[test]
    fn gain_ranking_orders_by_signal() {
        let d = graded_dataset(4000, 1);
        let r = gain_ranking(&d, 1);
        assert_eq!(r.order[0], 0, "scores={:?} order={:?}", r.scores, r.order);
        assert_eq!(r.order[2], 2);
        assert!(r.scores[0] > r.scores[1]);
    }

    #[test]
    fn both_methods_agree_on_top_feature() {
        let d = graded_dataset(4000, 2);
        let g = rank_features(&d, RankMethod::GbdtGain, 2);
        let m = rank_features(&d, RankMethod::Mrmr, 2);
        assert_eq!(g.order[0], 0);
        assert_eq!(m.order[0], 0);
    }

    #[test]
    fn top_n_truncates() {
        let d = graded_dataset(500, 3);
        let r = gain_ranking(&d, 3);
        assert_eq!(r.top(2).len(), 2);
        assert_eq!(r.top(10).len(), 3);
    }
}
