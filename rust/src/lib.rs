//! # LRwBins — multistage inference on tabular data
//!
//! Production-quality reproduction of *"Efficient Multistage Inference on
//! Tabular Data"* (Johnson & Markov, 2023) as a three-layer Rust + JAX +
//! Pallas serving stack:
//!
//! * **Layer 3 (this crate)** — the multistage coordinator: an embedded,
//!   dependency-free first-stage LRwBins evaluator in the request path, a
//!   dynamic-batched RPC fallback to the second-stage GBDT service, plus all
//!   training substrates (GBDT, logistic regression, binning, allocation,
//!   AutoML) built from scratch.
//! * **Layer 2** — JAX compute graphs (`python/compile/model.py`) lowered
//!   AOT to HLO text artifacts executed through PJRT (`runtime`).
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   stage-1 LRwBins batch evaluator and the stage-2 forest traversal.
//!
//! ## Serving engines
//!
//! Second-stage (GBDT) predictions execute on the persistent
//! **shard-per-core engine** [`runtime::ShardPool`]: one long-lived worker
//! thread per shard, each owning its own [`gbdt::FlatForest`] replica and
//! scratch, fed by a bounded lock-free MPMC queue — no per-request or
//! per-batch thread churn. Two deployment shapes share the engine:
//!
//! * **RPC service** — [`rpc::server::NativeBackend`] splits every batch
//!   into per-shard sub-ranges and awaits completion; a panicking shard
//!   degrades to error frames for its sub-batch only.
//! * **Embedded multi-tenant** — several [`coordinator::Coordinator`]s
//!   (tenants), each with their own stage-1 tables and second-stage model,
//!   register their forests in ONE shared pool
//!   ([`runtime::ShardPool::register`] +
//!   [`coordinator::Coordinator::new_embedded`]) and fall back to it
//!   in-process instead of over RPC: per-shard replicas are materialized
//!   lazily per model, so co-tenants share cores without sharing hot state.
//!
//! See DESIGN.md for the full system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod allocation;
pub mod automl;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod datagen;
pub mod features;
pub mod gbdt;
pub mod linalg;
pub mod lr;
pub mod lrwbins;
pub mod metrics;
pub mod picasso;
pub mod rpc;
/// Execution runtime (Layer 2): the always-compiled shard-per-core serving
/// engine ([`runtime::ShardPool`]) plus the PJRT engine, which needs
/// `--features pjrt` (the `xla` bindings are not on crates.io; see
/// `Cargo.toml` for how to enable it).
pub mod runtime;
pub mod telemetry;
pub mod tabular;
pub mod util;

pub use util::{sigmoid, sigmoid_f32};
