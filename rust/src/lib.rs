//! # LRwBins — multistage inference on tabular data
//!
//! Production-quality reproduction of *"Efficient Multistage Inference on
//! Tabular Data"* (Johnson & Markov, 2023) as a three-layer Rust + JAX +
//! Pallas serving stack:
//!
//! * **Layer 3 (this crate)** — the multistage coordinator: an embedded,
//!   dependency-free first-stage LRwBins evaluator in the request path, a
//!   dynamic-batched RPC fallback to the second-stage GBDT service, plus all
//!   training substrates (GBDT, logistic regression, binning, allocation,
//!   AutoML) built from scratch.
//! * **Layer 2** — JAX compute graphs (`python/compile/model.py`) lowered
//!   AOT to HLO text artifacts executed through PJRT (`runtime`).
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   stage-1 LRwBins batch evaluator and the stage-2 forest traversal.
//!
//! See DESIGN.md for the full system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod allocation;
pub mod automl;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod datagen;
pub mod features;
pub mod gbdt;
pub mod linalg;
pub mod lr;
pub mod lrwbins;
pub mod metrics;
pub mod picasso;
pub mod rpc;
/// PJRT runtime (Layer 2). Compiled only with `--features pjrt`: the `xla`
/// bindings are not on crates.io, so the default build serves through the
/// dependency-free native backend and this module is gated off (see
/// `Cargo.toml` for how to enable it).
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod telemetry;
pub mod tabular;
pub mod util;

pub use util::{sigmoid, sigmoid_f32};
