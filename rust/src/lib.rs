//! # LRwBins — multistage inference on tabular data
//!
//! Production-quality reproduction of *"Efficient Multistage Inference on
//! Tabular Data"* (Johnson & Markov, 2023) as a three-layer Rust + JAX +
//! Pallas serving stack:
//!
//! * **Layer 3 (this crate)** — the multistage coordinator: an embedded,
//!   dependency-free first-stage LRwBins evaluator in the request path, a
//!   dynamic-batched RPC fallback to the second-stage GBDT service, plus all
//!   training substrates (GBDT, logistic regression, binning, allocation,
//!   AutoML) built from scratch.
//! * **Layer 2** — JAX compute graphs (`python/compile/model.py`) lowered
//!   AOT to HLO text artifacts executed through PJRT (`runtime`).
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   stage-1 LRwBins batch evaluator and the stage-2 forest traversal.
//!
//! ## Serving engines
//!
//! Second-stage (GBDT) predictions execute on the persistent
//! **shard-per-core engine** [`runtime::ShardPool`]: one long-lived worker
//! thread per shard, each owning its own [`gbdt::FlatForest`] replica and
//! scratch, fed by per-shard bounded lock-free MPMC rings with
//! **work-stealing** — an idle shard pops a hot neighbor's ring, splitting
//! big spans in half (adaptive task granularity from live occupancy), so a
//! straggler shard no longer gates a block's tail. No per-request or
//! per-batch thread churn. Two deployment shapes share the engine:
//!
//! * **RPC service** — [`rpc::server::NativeBackend`] splits every batch
//!   into sub-range tasks and **streams**: each completed sub-range leaves
//!   the server immediately as a `CHUNK` frame (terminator carries the
//!   chunk count; a poisoned sub-range error-frames only its span), the
//!   pipelined client reassembles bit-identically and surfaces spans
//!   incrementally ([`rpc::client::PendingPredict::poll_spans`],
//!   [`coordinator::BlockPending::poll_fallback`]). A panicking shard
//!   degrades to error frames for its sub-batch only.
//! * **Embedded multi-tenant** — several [`coordinator::Coordinator`]s
//!   (tenants), each with their own stage-1 tables and second-stage model,
//!   register their forests in ONE shared pool
//!   ([`runtime::ShardPool::register`] +
//!   [`coordinator::Coordinator::new_embedded`]) and fall back to it
//!   in-process instead of over RPC: per-shard replicas are pre-materialized
//!   off the hot path at `register`/`swap` time and carry a version stamp,
//!   so co-tenants share cores without sharing hot state and a model swap
//!   never stalls a serving shard.
//!
//! Block serving overlaps stages end to end: stage-1 hits are readable
//! while the coalesced miss RPC is in flight, fallback spans are consumable
//! as their chunks land, and [`coordinator::BlockPipeline`] keeps as many
//! blocks outstanding as the live stage1-done/rpc-done completion gap
//! warrants (adaptive depth 1–4).
//!
//! Both hot kernels are lane-tiled SIMD with runtime dispatch: the stage-1
//! block evaluator runs a forced-scalar / portable-tiled / AVX2-intrinsics
//! tier chosen per machine at table construction
//! ([`lrwbins::Stage1Dispatch`], forceable for A/B), and the flat forest is
//! a structure-of-arrays arena walked sixteen row-lanes at a time — every
//! tier bit-identical to the scalar path by construction (vectorized
//! across rows; see [`lrwbins::tables`] and [`gbdt::flat`]).
//!
//! ## Event-driven server core (Linux)
//!
//! On Linux the RPC server's I/O is an **epoll reactor**
//! (`rpc::reactor`, on by default; [`rpc::BatcherConfig`]`::reactor =
//! false` forces the legacy thread-per-connection path for A/B runs):
//!
//! * **Loops** — one nonblocking acceptor plus a small fixed set of event
//!   loops (`reactor_loops`, default `min(4, cores)`); accepted sockets are
//!   handed round-robin to a loop and stay pinned to it for life. Thread
//!   count is a function of the machine, not the connection count — 10k
//!   concurrent connections run on the same handful of threads
//!   (`tests/concurrency_stress.rs` C10K leg).
//! * **Connection state machine** — each loop owns a slab of per-connection
//!   states: an incremental [`rpc::proto::FrameDecoder`] accumulates
//!   partial reads and yields complete request frames; decoded requests
//!   hand off to the same dynamic batcher / shard pool as the threaded
//!   path, so everything behind the socket is byte-for-byte identical.
//! * **Write-queue backpressure** — responses and streamed
//!   `CHUNK`/`STREAM_END` frames are enqueued on a **bounded**
//!   per-connection write queue (`write_queue_frames`) and flushed by the
//!   owning loop under writable-interest; a batcher worker that outruns a
//!   slow client blocks briefly on the bound (counted as a backpressure
//!   stall), and a connection that stays unwritable past the write timeout
//!   is condemned — its queued frames and jobs error-complete and are
//!   counted, never silently dropped.
//! * **Simulated hop + chaos without threads** — `netsim` pacing becomes a
//!   per-frame *due time* served by loop timers
//!   ([`rpc::NetSim::due_after`], monotone per connection) instead of a
//!   sleeping pacing thread per job, and `ChaosPlan` faults are drawn at
//!   the reactor's flush point with the same per-frame indexing as the
//!   threaded writer — the chaos battery runs every scenario on both paths.
//! * **Failure-model mapping** — `deadline_us` still re-anchors when the
//!   request is admitted (after its simulated inbound hop), expired work is
//!   still shed pre-execution, error frames and per-span error chunks are
//!   emitted unchanged, and a dead connection error-completes its in-flight
//!   jobs (`dead_conn_jobs`) exactly like a dead reader thread did.
//!   [`telemetry::ReactorStats`] exposes per-loop connection counts, epoll
//!   wakeups, write-queue high-water marks, and backpressure stalls.
//!
//! ## Model lifecycle
//!
//! Deployment is a product-code concern here (the paper embeds stage 1 *in*
//! the product), so the crate owns the full model lifecycle:
//!
//! * **Snapshot format** ([`snapshot`]) — a trained stack (stage-1
//!   [`lrwbins::ServingTables`] + SoA [`gbdt::FlatForest`]) serializes to
//!   one length-prefixed, checksummed, 8-byte-aligned binary buffer,
//!   section-per-array:
//!
//!   | region        | contents                                            |
//!   |---------------|-----------------------------------------------------|
//!   | header (24 B) | magic `LRWBSNAP`, version, section count, total len |
//!   | section table | per section: tag, offset, length, FNV-1a-64 checksum|
//!   | payloads      | raw LE array bytes, every offset 8-aligned          |
//!
//!   A parsed [`snapshot::Snapshot`] serves the forest **zero-copy** out of
//!   the buffer ([`snapshot::Snapshot::forest_view`] →
//!   [`gbdt::ForestView`]) — no node rebuild; materializing an owned forest
//!   is five `memcpy`s. `lrwbins train` writes `<name>.snap`;
//!   `lrwbins predict --snapshot` serves from it.
//! * **Panic-free load** — [`snapshot::Snapshot::parse`] is fallible end to
//!   end: structural checks (magic/version/section table/bounds/checksums,
//!   overflow-safe, no allocation sized by untrusted bytes) then semantic
//!   checks over borrowed slices ([`lrwbins::TablePartsRef::validate`],
//!   [`gbdt::ForestView::validate`] — every feature id in range, every
//!   child edge in-arena and forward so walks terminate). Corrupt bytes are
//!   an `Err` at load, never a panic mid-batch.
//! * **Live hot-swap** — [`runtime::ShardPool::swap`] flips a model's
//!   registry `Arc` between batches and bumps its version; every span is
//!   stamped with the version current at submit, so a batch is served
//!   entirely by one model version, bit-stable, even with a swap racing it.
//!   Worker replica caches re-materialize from pre-built clones on stamp
//!   mismatch and **evict** the drained old version (counted in
//!   [`telemetry::ShardStats`]). A **two-version window** keeps the
//!   previous forest resolvable while its in-flight spans drain — and
//!   doubles as the shadow-scoring hook ([`runtime::ShardPool::shadow`]).
//!   [`coordinator::Coordinator::reload`] ties it together: parse snapshot
//!   → validate → swap tables + embedded forest, under traffic.
//!
//! ## Failure model
//!
//! The serving stack has an explicit request lifecycle under failure
//! (ROADMAP §Failure model; proven end to end by `tests/chaos_battery.rs`):
//!
//! * **Deadlines** — [`rpc::PredictOptions`] carries a per-request latency
//!   budget ([`rpc::Deadline`]). The client refuses to send once it is
//!   spent, the **remaining** budget rides the request frame (microseconds,
//!   re-anchored against the receiver's clock so skew never accumulates),
//!   the server batcher sheds expired requests before execution, and the
//!   shard pool sheds expired not-yet-started spans — work nobody can use
//!   is dropped at every hop, and shed work is counted
//!   ([`telemetry::ServeMetrics::deadline_shed_rows`], per-shard
//!   `deadline_shed`).
//! * **Retries + circuit breaker** — every transport failure goes through
//!   ONE policy ([`rpc::RetryPolicy`]: bounded attempts, exponential
//!   backoff with jitter, a client-wide retry *budget*) and one
//!   [`rpc::CircuitBreaker`] (closed → open on consecutive failures or a
//!   p99 breach, open → half-open probe after cooldown). A connection whose
//!   reader dies error-completes **every** pending request on it
//!   immediately — waits fail fast, they never dangle.
//! * **Graceful degradation** — when the second stage cannot serve a miss
//!   (breaker open, deadline spent, retries exhausted), the coordinator's
//!   [`coordinator::DegradeMode`] decides: propagate the error (`Fail`,
//!   default), answer with the row's stage-1 prior explicitly marked
//!   [`coordinator::Served::Degraded`] (`Stage1Prior`), or wait out the
//!   breaker bounded by the deadline (`Block`). Degraded rows are counted
//!   separately (`degraded_rows`/`degraded_requests`) and never as
//!   second-stage answers; stage-1-amenable rows are unaffected.
//! * **Embedded differences** — the in-process fallback has no wire, so no
//!   retries and no breaker: panics are contained per-span by the shard
//!   pool, and `Stage1Prior` degradation applies only if the pool itself
//!   fails the batch.
//! * **Chaos substrate** — [`rpc::ChaosPlan`] scripts per-frame faults
//!   (reset, stall, truncation, header corruption, batcher pause) into the
//!   server; the battery proves no hang, no wrong bits, and exact
//!   hit/miss/error/degraded accounting under each.
//!
//! ## Overload model
//!
//! Failure handling assumes the stack *wants* to serve; overload handling
//! decides what it *refuses* to serve, explicitly and early, so the work it
//! does accept still meets its SLO. The ladder, cheapest refusal first:
//!
//! * **Admission control** — [`rpc::AdmissionControl`] sits at the
//!   admission edge of BOTH I/O paths (epoll reactor and
//!   thread-per-connection). Per-tenant token buckets metered in **rows**
//!   (requests carry a tenant id on the wire; a misbehaving tenant exhausts
//!   its own bucket, not its neighbors') plus a global in-flight row cap.
//!   A refused request gets an explicit `REJECTED` frame with a
//!   **retry-after hint** — distinct from a deadline shed, classified by
//!   [`rpc::fault::is_overloaded`], and the client honors it: rejections
//!   never burn circuit-breaker counts and back off by at least the hint,
//!   so retry storms cannot amplify offered load (bounded by the retry
//!   budget; proven in `rpc::client` tests).
//! * **Sojourn shedding** — the server batcher runs a CoDel-style control
//!   law ([`rpc::Codel`]) on **measured queue delay**: when the minimum
//!   sojourn over an interval exceeds the SLO target, it sheds at the
//!   `interval/√n` cadence instead of letting a standing queue grow.
//!   Counted in [`telemetry::ServeMetrics::sojourn_shed_rows`].
//! * **Brownout** — before dropping anything, the coordinator degrades:
//!   under `DegradeMode::Stage1Prior` a brownout rung
//!   ([`coordinator::Coordinator::set_brownout`]) answers low-priority
//!   tenants (rung 1) or everyone (rung 2) with their stage-1 prior,
//!   marked [`coordinator::Served::Degraded`] — cheaper than serving,
//!   honest in the accounting.
//! * **SLO controller** — [`slo::SloController`] closes the loop: a pure
//!   AIMD state machine watching admitted p99 + shed/queue signals,
//!   escalating capacity (live [`runtime::ShardPool::set_active_shards`] /
//!   `set_min_task_rows`) → brownout → admission throttle, and relaxing in
//!   reverse — including *shrinking* the pool when idle, so the p99 target
//!   is held at minimum CPU. [`slo::run_trace`] drives it from a seeded
//!   open-loop trace ([`slo::generate_trace`]: diurnal ramp, Poisson
//!   arrivals, correlated bursts, hot-tenant skew) and emits the
//!   `BENCH_slo.json` trajectory.
//!
//! Conservation under all of it: every submitted row is accounted exactly
//! once — `stage1 + rpc + degraded + rejected + deadline_shed + errors`
//! equals rows submitted (chaos and overload batteries assert this
//! exactly).
//!
//! ## Model rollout
//!
//! Hot-swap answers *how* to install a model; the rollout subsystem
//! ([`coordinator::Rollout`]) answers *whether it is safe to*. A candidate
//! snapshot walks a guarded state machine, driven by
//! [`coordinator::Coordinator::begin_rollout`] and ticked by the SLO
//! controller's cadence ([`coordinator::Coordinator::rollout_tick`]):
//!
//! ```text
//! Idle ──begin_rollout──▶ Shadow ──▶ Canary(p‰ ramp) ──▶ Promoted
//!                            │             │
//!                            └── guard ────┴──▶ RolledBack{reason}
//! ```
//!
//! * **Shadow** — a deterministic sample of admitted batches is re-scored
//!   on the candidate at **strictly lower priority** than live work: the
//!   shard pool runs shadow jobs only when its rings are empty, sheds them
//!   first under pressure, and bills them to a separate `shadow_rows`
//!   bucket — the six-bucket conservation law above is untouched, and the
//!   served bits stay bit-identical to a rollout-free run. The divergence
//!   monitor accumulates stage-1 routing disagreement, a |Δscore|
//!   histogram, and shadow-vs-live execution latency
//!   ([`telemetry::RolloutStats`]).
//! * **Canary** — a `splitmix64` hash of the request id routes p‰ of
//!   traffic to the candidate (replayable given the seed, and **never mixed
//!   within a batch**: a canary batch is served end to end on the candidate
//!   or, if the candidate fails mid-serve, re-served end to end on the
//!   incumbent). The ramp advances on controller ticks and **freezes while
//!   the controller is escalated** (brownout or throttled) — a canary never
//!   widens during an incident. Candidate-answered rows draw from a bounded
//!   **error budget**; when it is exhausted, traffic stays on the incumbent.
//! * **Guards → rollback** — disagreement rate, max score delta, and
//!   shadow/canary p99 bounds each trip an instant revert: permille drops
//!   to zero, the staged pool version unstages, and the typed reason lands
//!   in [`coordinator::RollbackReason`] + the `rollout_rolled_back` metric.
//!   Promotion ([`coordinator::Coordinator::finalize_rollout`]) installs
//!   the candidate tables and flips the pool version — the same two-version
//!   window as a plain hot-swap.
//!
//! `lrwbins rollout` is the scripted drill; `tests/rollout_battery.rs`
//! proves divergent candidates (perturbed leaves, poisoned subtrees) roll
//! back within the error budget on both I/O paths.
//!
//! See DESIGN.md for the full system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod allocation;
pub mod automl;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod datagen;
pub mod features;
pub mod gbdt;
pub mod linalg;
pub mod lr;
pub mod lrwbins;
pub mod metrics;
pub mod picasso;
pub mod rpc;
/// Execution runtime (Layer 2): the always-compiled shard-per-core serving
/// engine ([`runtime::ShardPool`]) plus the PJRT engine, which needs
/// `--features pjrt` (the `xla` bindings are not on crates.io; see
/// `Cargo.toml` for how to enable it).
pub mod runtime;
pub mod slo;
pub mod snapshot;
pub mod telemetry;
pub mod tabular;
pub mod util;

pub use util::{sigmoid, sigmoid_f32};
