//! Serving telemetry: per-stage latency histograms, request counters and
//! CPU-time accounting.
//!
//! The paper's §5.2 claims are about mean latency (1.3×) and CPU resources
//! (30% reduction); this module measures both: wall latency through
//! `util::histogram`, CPU through `clock_gettime(CLOCK_THREAD_CPUTIME_ID)`
//! (per-thread) and `getrusage` (whole process).

use crate::util::histogram::Histogram;
use std::sync::atomic::{AtomicU64, Ordering};

/// Thread CPU time in nanoseconds.
pub fn thread_cpu_ns() -> u64 {
    let mut ts = libc::timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // SAFETY: ts is a valid out-pointer; clockid is a constant.
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

/// Process CPU time (user + system) in nanoseconds via getrusage.
pub fn process_cpu_ns() -> u64 {
    let mut ru: libc::rusage = unsafe { std::mem::zeroed() };
    // SAFETY: ru is a valid out-pointer.
    unsafe {
        libc::getrusage(libc::RUSAGE_SELF, &mut ru);
    }
    let tv = |t: libc::timeval| t.tv_sec as u64 * 1_000_000_000 + t.tv_usec as u64 * 1_000;
    tv(ru.ru_utime) + tv(ru.ru_stime)
}

/// Scoped CPU-time measurement on the current thread.
pub struct CpuTimer {
    start: u64,
}

impl CpuTimer {
    pub fn start() -> CpuTimer {
        CpuTimer {
            start: thread_cpu_ns(),
        }
    }

    pub fn elapsed_ns(&self) -> u64 {
        thread_cpu_ns().saturating_sub(self.start)
    }
}

/// All serving-side metrics, shared across threads.
#[derive(Default)]
pub struct ServeMetrics {
    /// End-to-end request latency (wall).
    pub e2e: Histogram,
    /// Stage-1 embedded evaluation latency.
    pub stage1: Histogram,
    /// RPC (second-stage) round-trip latency.
    pub rpc: Histogram,
    /// Backend batch-execution latency.
    pub backend_exec: Histogram,
    /// Block path, per-stage completion timestamps: nanoseconds from block
    /// arrival until (a) the embedded stage-1 pass delivered its hits and
    /// (b) the coalesced fallback RPC delivered the misses. Recorded once
    /// per block; the gap between the two is exactly the window the
    /// pipelined coordinator overlaps with the next block's stage-1 pass.
    /// Keeping them separate is what lets hit latency and miss latency be
    /// reported as measured instead of amortized out of one wall clock.
    pub block_stage1_complete: Histogram,
    pub block_rpc_complete: Histogram,
    /// Requests served by stage 1 / by RPC.
    pub stage1_hits: AtomicU64,
    pub rpc_calls: AtomicU64,
    /// CPU nanoseconds attributed to each stage (request-path threads).
    pub stage1_cpu_ns: AtomicU64,
    pub rpc_cpu_ns: AtomicU64,
    /// Features fetched (the paper's feature-fetch cost: stage 1 fetches a
    /// subset, the full model fetches everything — §5.2's 1.2×/70% claim).
    pub features_fetched: AtomicU64,
    /// Bytes moved over the RPC boundary (network-communication claim).
    pub rpc_bytes: AtomicU64,
    /// Streamed sub-batch chunk frames (emitted server-side / consumed
    /// client-side, whichever side owns this instance).
    pub stream_chunks: AtomicU64,
    /// Server side: backend batch start → each streamed chunk's emission.
    /// The head of this distribution is the latency win streaming buys over
    /// buffering a whole block into one monolithic response.
    pub chunk_emit: Histogram,
    /// Client side: block arrival → each fallback sub-span's completion
    /// (the per-chunk analogue of `block_rpc_complete`).
    pub block_span_complete: Histogram,
    /// Failure model (PR 6). Rows/requests shed because their deadline
    /// expired before execution (server batcher or shard pool).
    pub deadline_shed_rows: AtomicU64,
    pub deadline_shed_requests: AtomicU64,
    /// Rows answered degraded (stage-1 prior or explicit error in place of
    /// the full model) and the requests that contained at least one such
    /// row. Degraded rows are NEVER double-counted as rpc_calls.
    pub degraded_rows: AtomicU64,
    pub degraded_requests: AtomicU64,
    /// RPC attempts beyond the first (client retry loop).
    pub rpc_retries: AtomicU64,
    /// Circuit-breaker closed→open transitions observed by the serving
    /// layer (copied from the client breaker at report time or bumped by
    /// the coordinator when it observes a trip).
    pub breaker_trips: AtomicU64,
    /// Server side: streamed frames that could not be delivered because the
    /// owning connection died mid-stream. Never silent — every undeliverable
    /// frame is counted here (PR 7 regression guard for the old
    /// `let _ = sender.send(..)` drop).
    pub stream_drop_frames: AtomicU64,
    /// Server side: jobs whose response (monolithic or streamed) found its
    /// connection already dead — the work is abandoned but accounted, one
    /// count per job.
    pub dead_conn_jobs: AtomicU64,
    /// Successful live model reloads through
    /// `Coordinator::reload` (snapshot parsed, tables replaced, embedded
    /// forest swapped).
    pub model_reloads: AtomicU64,
    /// Overload model (PR 9). Rows/requests refused at admission — tenant
    /// quota breach or global in-flight cap — answered with an explicit
    /// `Rejected` frame (never executed, never counted as errors).
    pub rejected_rows: AtomicU64,
    pub rejected_requests: AtomicU64,
    /// Rows/requests shed by the batcher's CoDel sojourn controller: their
    /// measured queue delay said the SLO was already lost, even though the
    /// deadline had not yet expired. Also answered with `Rejected`.
    pub sojourn_shed_rows: AtomicU64,
    pub sojourn_shed_requests: AtomicU64,
    /// Guarded rollout (PR 10). Rows re-scored on a candidate version via
    /// the shadow path. Billed HERE, never in the six real-traffic buckets:
    /// shadow work is extra comparison traffic, and the conservation
    /// invariant for what callers actually sent must not see it.
    pub shadow_rows: AtomicU64,
    /// Shadow rows shed before candidate scoring (queue full, deadline
    /// expired, pool drained) — shadow work sheds first under pressure.
    /// `shadow_rows + shadow_shed_rows` equals exactly the rows sampled
    /// into the shadow path (reconciles against `RolloutStats`).
    pub shadow_shed_rows: AtomicU64,
    /// Rows whose REAL answer came from the candidate version through the
    /// canary route (a strict subset of the normal served buckets — canary
    /// rows are real traffic, this only marks which version answered).
    pub canary_rows: AtomicU64,
    /// Rollouts aborted by a guard rule; the typed reason lives in the
    /// coordinator's rollout state (`RollbackReason`).
    pub rollout_rolled_back: AtomicU64,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn hit_stage1(&self, wall_ns: u64, cpu_ns: u64, feats: u64) {
        self.stage1.record(wall_ns);
        self.stage1_hits.fetch_add(1, Ordering::Relaxed);
        self.stage1_cpu_ns.fetch_add(cpu_ns, Ordering::Relaxed);
        self.features_fetched.fetch_add(feats, Ordering::Relaxed);
    }

    pub fn hit_rpc(&self, wall_ns: u64, cpu_ns: u64, feats: u64, bytes: u64) {
        self.rpc.record(wall_ns);
        self.rpc_calls.fetch_add(1, Ordering::Relaxed);
        self.rpc_cpu_ns.fetch_add(cpu_ns, Ordering::Relaxed);
        self.features_fetched.fetch_add(feats, Ordering::Relaxed);
        self.rpc_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Reset every histogram and counter (between experiment phases).
    pub fn reset_all(&self) {
        self.e2e.reset();
        self.stage1.reset();
        self.rpc.reset();
        self.backend_exec.reset();
        self.block_stage1_complete.reset();
        self.block_rpc_complete.reset();
        self.chunk_emit.reset();
        self.block_span_complete.reset();
        for c in [
            &self.stage1_hits,
            &self.rpc_calls,
            &self.stage1_cpu_ns,
            &self.rpc_cpu_ns,
            &self.features_fetched,
            &self.rpc_bytes,
            &self.stream_chunks,
            &self.deadline_shed_rows,
            &self.deadline_shed_requests,
            &self.degraded_rows,
            &self.degraded_requests,
            &self.rpc_retries,
            &self.breaker_trips,
            &self.stream_drop_frames,
            &self.dead_conn_jobs,
            &self.model_reloads,
            &self.rejected_rows,
            &self.rejected_requests,
            &self.sojourn_shed_rows,
            &self.sojourn_shed_requests,
            &self.shadow_rows,
            &self.shadow_shed_rows,
            &self.canary_rows,
            &self.rollout_rolled_back,
        ] {
            c.store(0, Ordering::Relaxed);
        }
    }

    /// Pick a block-pipeline overlap depth (1–4) from the live per-stage
    /// completion gap: while one block's fallback RPC is outstanding
    /// (`block_rpc_complete` mean), roughly `gap / stage1` further blocks
    /// can run their stage-1 pass (`block_stage1_complete` mean) under it.
    /// With no history (or an RPC that completes as fast as stage 1) the
    /// answer is 1 — no overlap is worth holding results back for.
    pub fn suggested_pipeline_depth(&self) -> usize {
        let s1 = self.block_stage1_complete.mean_ns();
        let rpc = self.block_rpc_complete.mean_ns();
        if self.block_rpc_complete.count() == 0 || s1 <= 0.0 || rpc <= s1 {
            return 1;
        }
        (1.0 + (rpc - s1) / s1).min(4.0) as usize
    }

    /// Fraction of requests served by stage 1.
    pub fn coverage(&self) -> f64 {
        let s1 = self.stage1_hits.load(Ordering::Relaxed) as f64;
        let rpc = self.rpc_calls.load(Ordering::Relaxed) as f64;
        if s1 + rpc == 0.0 {
            0.0
        } else {
            s1 / (s1 + rpc)
        }
    }

    /// Multi-line report for logs / EXPERIMENTS.md.
    pub fn report(&self) -> String {
        let mut s = format!(
            "e2e:     {}\nstage1:  {}\nrpc:     {}\nbackend: {}\ncoverage: {:.1}%  stage1_cpu: {:.3}ms  rpc_cpu: {:.3}ms  feats: {}  rpc_bytes: {}",
            self.e2e.summary_ms(),
            self.stage1.summary_ms(),
            self.rpc.summary_ms(),
            self.backend_exec.summary_ms(),
            self.coverage() * 100.0,
            self.stage1_cpu_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.rpc_cpu_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.features_fetched.load(Ordering::Relaxed),
            self.rpc_bytes.load(Ordering::Relaxed),
        );
        if self.block_stage1_complete.count() > 0 {
            s.push_str(&format!(
                "\nblock stage1-done: {}\nblock rpc-done:    {}",
                self.block_stage1_complete.summary_ms(),
                self.block_rpc_complete.summary_ms(),
            ));
        }
        let chunks = self.stream_chunks.load(Ordering::Relaxed);
        if chunks > 0 {
            s.push_str(&format!("\nstream chunks: {chunks}"));
            if self.chunk_emit.count() > 0 {
                s.push_str(&format!("  chunk-emit: {}", self.chunk_emit.summary_ms()));
            }
            if self.block_span_complete.count() > 0 {
                s.push_str(&format!(
                    "  span-done: {}",
                    self.block_span_complete.summary_ms()
                ));
            }
        }
        let degraded_rows = self.degraded_rows.load(Ordering::Relaxed);
        let shed_rows = self.deadline_shed_rows.load(Ordering::Relaxed);
        let retries = self.rpc_retries.load(Ordering::Relaxed);
        let trips = self.breaker_trips.load(Ordering::Relaxed);
        if degraded_rows + shed_rows + retries + trips > 0 {
            s.push_str(&format!(
                "\ndegraded rows: {degraded_rows} (reqs: {})  deadline-shed rows: {shed_rows} (reqs: {})  retries: {retries}  breaker trips: {trips}",
                self.degraded_requests.load(Ordering::Relaxed),
                self.deadline_shed_requests.load(Ordering::Relaxed),
            ));
        }
        let dropped = self.stream_drop_frames.load(Ordering::Relaxed);
        let dead_jobs = self.dead_conn_jobs.load(Ordering::Relaxed);
        if dropped + dead_jobs > 0 {
            s.push_str(&format!(
                "\ndead-conn jobs: {dead_jobs}  undeliverable stream frames: {dropped}"
            ));
        }
        let reloads = self.model_reloads.load(Ordering::Relaxed);
        if reloads > 0 {
            s.push_str(&format!("\nmodel reloads: {reloads}"));
        }
        let rejected = self.rejected_rows.load(Ordering::Relaxed);
        let sojourn = self.sojourn_shed_rows.load(Ordering::Relaxed);
        if rejected + sojourn > 0 {
            s.push_str(&format!(
                "\nrejected rows: {rejected} (reqs: {})  sojourn-shed rows: {sojourn} (reqs: {})",
                self.rejected_requests.load(Ordering::Relaxed),
                self.sojourn_shed_requests.load(Ordering::Relaxed),
            ));
        }
        let shadow = self.shadow_rows.load(Ordering::Relaxed);
        let shadow_shed = self.shadow_shed_rows.load(Ordering::Relaxed);
        let canary = self.canary_rows.load(Ordering::Relaxed);
        let rolled_back = self.rollout_rolled_back.load(Ordering::Relaxed);
        if shadow + shadow_shed + canary + rolled_back > 0 {
            s.push_str(&format!(
                "\nshadow rows: {shadow} (shed: {shadow_shed})  canary rows: {canary}  rollbacks: {rolled_back}"
            ));
        }
        s
    }
}

/// Shard-per-core pool telemetry: per-shard occupancy, task and steal
/// counters plus queue-depth tracking for the per-shard MPMC rings
/// (see [`crate::runtime::ShardPool`]).
///
/// Gauges are racy by design (monitoring, not synchronization); counters
/// follow a strict discipline — every count is recorded *before* the
/// batch's completion latch opens, so a submitter returning from a pool
/// call observes totals that already include its own batch. (Steal/split
/// counters are the exception: a steal is a scheduling event, not a
/// completion, and may land just after the latch it raced.)
#[derive(Default)]
pub struct ShardStats {
    /// Per-shard executed task counts.
    shard_tasks: Vec<AtomicU64>,
    /// Per-shard busy gauge (1 while a task is executing on that shard).
    shard_busy: Vec<AtomicU64>,
    /// Per-shard counts of tasks stolen BY that shard from a neighbor's
    /// ring (the thief's side of the work-stealing protocol).
    shard_steals: Vec<AtomicU64>,
    /// CPU id each shard's worker pinned itself to (+1, so 0 means "not
    /// pinned" — workers only write on a successful `sched_setaffinity`).
    shard_pinned: Vec<AtomicU64>,
    /// Workers that requested pinning but could not (non-Linux target or a
    /// failing `sched_setaffinity`, e.g. restricted container cpusets).
    pub pin_failures: AtomicU64,
    /// Sub-range tasks submitted across all batches (split remainders
    /// count as new spans when requeued).
    pub spans_submitted: AtomicU64,
    /// Stolen tasks split in half (thief kept the back half, remainder
    /// requeued on the victim's ring).
    pub steal_splits: AtomicU64,
    /// Tasks run inline on the submitter because the rings were full
    /// (backpressure events).
    pub inline_runs: AtomicU64,
    /// Shard panics contained to their task span.
    pub shard_panics: AtomicU64,
    /// Sub-range tasks shed on the shards because their deadline expired
    /// before execution (the span completes as failed, never silently).
    pub deadline_shed: AtomicU64,
    /// High-water mark of the total queued depth across the rings.
    pub queue_depth_hwm: AtomicU64,
    /// Per-chunk (sub-range task) execution latency on the shards — the
    /// granularity at which streamed responses complete.
    pub chunk_exec: Histogram,
    /// Model lifecycle (hot-swap). Per-shard forest replicas deep-cloned —
    /// at `register`/`swap` time (the pre-built path) or, rarely, on a
    /// worker when racing swaps exhausted the prepared set.
    pub replica_builds: AtomicU64,
    /// Drained old-version replicas dropped by workers on a version-stamp
    /// mismatch (the cache holds at most one replica per model).
    pub replicas_evicted: AtomicU64,
    /// Successful [`ShardPool::swap`](crate::runtime::ShardPool::swap)
    /// calls.
    pub model_swaps: AtomicU64,
    /// Spans whose version stamp left the two-version window before they
    /// ran (two swaps raced a queued span): completed as failed spans,
    /// never served with wrong-version bits.
    pub stale_spans: AtomicU64,
    /// Replica deep-clone build time (both the pre-built and the fallback
    /// path — the cost the hot path no longer pays).
    pub replica_build: Histogram,
    /// Shadow-scoring jobs accepted onto the pool's lowest-priority queue
    /// (guarded rollout; see
    /// [`ShardPool::submit_shadow`](crate::runtime::ShardPool::submit_shadow)).
    pub shadow_jobs: AtomicU64,
    /// Shadow jobs shed instead of executed: queue full at submit, deadline
    /// expired, version no longer resolvable, or pool shutdown. Shadow work
    /// is strictly lower priority than live spans — it sheds first, and
    /// every shed is delivered to the job's callback so rollout accounting
    /// stays exact.
    pub shadow_shed: AtomicU64,
    /// Candidate panics contained on the shadow path (a poisoned candidate
    /// must never take a worker down — the outcome is delivered as failed).
    pub shadow_panics: AtomicU64,
}

impl ShardStats {
    pub fn new(n_shards: usize) -> ShardStats {
        ShardStats {
            shard_tasks: (0..n_shards).map(|_| AtomicU64::new(0)).collect(),
            shard_busy: (0..n_shards).map(|_| AtomicU64::new(0)).collect(),
            shard_steals: (0..n_shards).map(|_| AtomicU64::new(0)).collect(),
            shard_pinned: (0..n_shards).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shard_tasks.len()
    }

    pub fn record_task(&self, shard: usize) {
        self.shard_tasks[shard].fetch_add(1, Ordering::Relaxed);
    }

    pub fn set_busy(&self, shard: usize, busy: bool) {
        self.shard_busy[shard].store(u64::from(busy), Ordering::Relaxed);
    }

    pub fn note_queue_depth(&self, depth: usize) {
        self.queue_depth_hwm.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Tasks executed by shard `i`.
    pub fn tasks_on(&self, shard: usize) -> u64 {
        self.shard_tasks[shard].load(Ordering::Relaxed)
    }

    /// Tasks executed across all shards (excludes inline runs).
    pub fn spans_completed(&self) -> u64 {
        self.shard_tasks.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Shards currently executing a task (occupancy gauge).
    pub fn busy_shards(&self) -> usize {
        self.shard_busy
            .iter()
            .filter(|b| b.load(Ordering::Relaxed) != 0)
            .count()
    }

    pub fn panics(&self) -> u64 {
        self.shard_panics.load(Ordering::Relaxed)
    }

    /// Record the CPU a shard's worker successfully pinned itself to.
    pub fn set_pinned(&self, shard: usize, cpu: u32) {
        self.shard_pinned[shard].store(cpu as u64 + 1, Ordering::Relaxed);
    }

    /// CPU id shard `i`'s worker is pinned to (`None` = not pinned).
    pub fn pinned_cpu(&self, shard: usize) -> Option<u32> {
        match self.shard_pinned[shard].load(Ordering::Relaxed) {
            0 => None,
            v => Some((v - 1) as u32),
        }
    }

    /// Record a task stolen by `thief` from a neighbor's ring.
    pub fn record_steal(&self, thief: usize) {
        self.shard_steals[thief].fetch_add(1, Ordering::Relaxed);
    }

    /// Tasks stolen by shard `i`.
    pub fn steals_by(&self, shard: usize) -> u64 {
        self.shard_steals[shard].load(Ordering::Relaxed)
    }

    /// Tasks stolen across all shards.
    pub fn steals(&self) -> u64 {
        self.shard_steals.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// One-line report for logs: per-shard task counts + global counters.
    pub fn report(&self) -> String {
        let per_shard: Vec<String> = self
            .shard_tasks
            .iter()
            .map(|c| c.load(Ordering::Relaxed).to_string())
            .collect();
        let steals: Vec<String> = self
            .shard_steals
            .iter()
            .map(|c| c.load(Ordering::Relaxed).to_string())
            .collect();
        let mut s = format!(
            "shards[{}] tasks/shard=[{}] steals/shard=[{}] submitted={} splits={} inline={} panics={} busy={} q_hwm={}",
            self.n_shards(),
            per_shard.join(","),
            steals.join(","),
            self.spans_submitted.load(Ordering::Relaxed),
            self.steal_splits.load(Ordering::Relaxed),
            self.inline_runs.load(Ordering::Relaxed),
            self.panics(),
            self.busy_shards(),
            self.queue_depth_hwm.load(Ordering::Relaxed),
        );
        let shed = self.deadline_shed.load(Ordering::Relaxed);
        if shed > 0 {
            s.push_str(&format!(" deadline_shed={shed}"));
        }
        let swaps = self.model_swaps.load(Ordering::Relaxed);
        let builds = self.replica_builds.load(Ordering::Relaxed);
        if swaps + builds > 0 {
            s.push_str(&format!(
                " swaps={swaps} replica_builds={builds} evicted={}",
                self.replicas_evicted.load(Ordering::Relaxed)
            ));
        }
        let stale = self.stale_spans.load(Ordering::Relaxed);
        if stale > 0 {
            s.push_str(&format!(" stale_spans={stale}"));
        }
        let shadow = self.shadow_jobs.load(Ordering::Relaxed);
        let shadow_shed = self.shadow_shed.load(Ordering::Relaxed);
        if shadow + shadow_shed > 0 {
            s.push_str(&format!(
                " shadow_jobs={shadow} shadow_shed={shadow_shed} shadow_panics={}",
                self.shadow_panics.load(Ordering::Relaxed)
            ));
        }
        let pin_failures = self.pin_failures.load(Ordering::Relaxed);
        if pin_failures > 0 || (0..self.n_shards()).any(|i| self.pinned_cpu(i).is_some()) {
            let pinned: Vec<String> = (0..self.n_shards())
                .map(|i| {
                    self.pinned_cpu(i)
                        .map_or_else(|| "-".into(), |c| c.to_string())
                })
                .collect();
            s.push_str(&format!(
                " pinned_cpu=[{}] pin_failures={pin_failures}",
                pinned.join(",")
            ));
        }
        s
    }
}

/// Guarded-rollout telemetry: the divergence monitor's accumulators (see
/// the crate docs' "Model rollout" section and
/// [`crate::coordinator::Rollout`]).
///
/// Accounting contract (what the batteries reconcile exactly):
/// `shadow_rows + shadow_shed_rows` equals the rows sampled into the shadow
/// path; `shadow_rows`/`shadow_shed_rows`/`canary_rows` mirror the same-
/// named [`ServeMetrics`] buckets one-for-one; `rows_compared ≤ shadow
/// sampled rows` (only rows with BOTH a live and a candidate score
/// compare); `disagreements ≤ rows_compared`.
#[derive(Default)]
pub struct RolloutStats {
    /// Batches sampled into the shadow comparison.
    pub shadow_batches: AtomicU64,
    /// Rows re-scored on the candidate (stage-1 comparison always runs
    /// inline; rows needing the candidate second stage go through the
    /// pool's shadow queue).
    pub shadow_rows: AtomicU64,
    /// Sampled rows whose candidate score was shed before it was computed
    /// (shadow queue full, deadline, pool pressure).
    pub shadow_shed_rows: AtomicU64,
    /// Rows with both a live and a candidate score (the divergence
    /// denominator).
    pub rows_compared: AtomicU64,
    /// Rows whose stage-1 ROUTING decision differed between incumbent and
    /// candidate tables.
    pub disagreements: AtomicU64,
    /// Largest |candidate − live| score delta seen, in micro-units
    /// (`fetch_max`; divide by 1e6 for the probability-scale value).
    pub max_score_delta_micro: AtomicU64,
    /// |candidate − live| score-delta distribution, micro-units (the
    /// histogram's log buckets are unit-agnostic).
    pub score_delta_micro: Histogram,
    /// Candidate re-score latency (shadow path), wall ns.
    pub shadow_exec: Histogram,
    /// Live serving latency of the SAME sampled batches, wall ns — the
    /// shadow-vs-live comparison baseline.
    pub live_exec: Histogram,
    /// Batches/rows actually routed to the candidate by the canary hash.
    pub canary_batches: AtomicU64,
    pub canary_rows: AtomicU64,
    /// Canary batch serving latency, wall ns.
    pub canary_exec: Histogram,
    /// Candidate scoring failures (panic or stale on the candidate
    /// version) — maximal divergence, an immediate guard trip.
    pub candidate_failures: AtomicU64,
    /// Rows the error budget refused to route to the candidate (the batch
    /// served the incumbent instead — budget enforcement, not a shed).
    pub budget_held_rows: AtomicU64,
    /// Controller ticks observed while escalated: the canary ramp held its
    /// step instead of advancing.
    pub ramp_freezes: AtomicU64,
    /// Controller ticks delivered to the rollout.
    pub ticks: AtomicU64,
}

impl RolloutStats {
    pub fn new() -> RolloutStats {
        RolloutStats::default()
    }

    /// Record one live-vs-candidate score delta (absolute, probability
    /// scale) into the histogram and the running max.
    pub fn note_score_delta(&self, delta: f32) {
        let micro = (delta.abs() as f64 * 1e6).round() as u64;
        self.score_delta_micro.record(micro);
        self.max_score_delta_micro.fetch_max(micro, Ordering::Relaxed);
    }

    /// Largest |candidate − live| score delta seen, probability scale.
    pub fn max_score_delta(&self) -> f64 {
        self.max_score_delta_micro.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Disagreement rate over compared rows (0 when nothing compared).
    pub fn disagreement_rate(&self) -> f64 {
        let n = self.rows_compared.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.disagreements.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// One-line report for logs.
    pub fn report(&self) -> String {
        let mut s = format!(
            "rollout: shadow_batches={} shadow_rows={} shadow_shed={} compared={} \
             disagree={} ({:.3}%) max_delta={:.6} canary_batches={} canary_rows={} \
             budget_held={} freezes={} ticks={}",
            self.shadow_batches.load(Ordering::Relaxed),
            self.shadow_rows.load(Ordering::Relaxed),
            self.shadow_shed_rows.load(Ordering::Relaxed),
            self.rows_compared.load(Ordering::Relaxed),
            self.disagreements.load(Ordering::Relaxed),
            self.disagreement_rate() * 100.0,
            self.max_score_delta(),
            self.canary_batches.load(Ordering::Relaxed),
            self.canary_rows.load(Ordering::Relaxed),
            self.budget_held_rows.load(Ordering::Relaxed),
            self.ramp_freezes.load(Ordering::Relaxed),
            self.ticks.load(Ordering::Relaxed),
        );
        let failures = self.candidate_failures.load(Ordering::Relaxed);
        if failures > 0 {
            s.push_str(&format!(" candidate_failures={failures}"));
        }
        if self.shadow_exec.count() > 0 {
            s.push_str(&format!(
                "\n  shadow-exec: {}  live-exec: {}",
                self.shadow_exec.summary_ms(),
                self.live_exec.summary_ms()
            ));
        }
        if self.canary_exec.count() > 0 {
            s.push_str(&format!("\n  canary-exec: {}", self.canary_exec.summary_ms()));
        }
        s
    }
}

/// Event-driven server core telemetry: per-loop connection gauges and
/// wakeup counters for the epoll reactor (see [`crate::rpc::server`]'s
/// reactor path), plus write-queue pressure accounting.
///
/// Same discipline as [`ShardStats`]: gauges are racy monitoring aids,
/// counters are bumped by the thread that owns the event (the loop for
/// wakeups/flushes, the producer for backpressure stalls).
#[derive(Default)]
pub struct ReactorStats {
    /// Per-loop live connection gauge (incremented on assignment,
    /// decremented on close by the owning loop).
    loop_conns: Vec<AtomicU64>,
    /// Per-loop `epoll_wait` returns (each return may carry many events).
    loop_wakeups: Vec<AtomicU64>,
    /// Connections accepted over the reactor's lifetime.
    pub accepted: AtomicU64,
    /// High-water mark of any single connection's write-queue depth
    /// (frames), across all connections.
    pub write_queue_hwm: AtomicU64,
    /// Producer-side stalls: a batcher worker found a connection's write
    /// queue full and had to wait for the loop to drain it (backpressure).
    pub backpressure_stalls: AtomicU64,
    /// Frames still queued on a connection when it died — never written,
    /// never silently forgotten.
    pub dead_conn_frames: AtomicU64,
    /// Frames whose flush was deferred to a timer (netsim hop delay or an
    /// injected stall) instead of a sleeping thread.
    pub deferred_flushes: AtomicU64,
}

impl ReactorStats {
    pub fn new(n_loops: usize) -> ReactorStats {
        ReactorStats {
            loop_conns: (0..n_loops).map(|_| AtomicU64::new(0)).collect(),
            loop_wakeups: (0..n_loops).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    pub fn n_loops(&self) -> usize {
        self.loop_conns.len()
    }

    pub fn conn_opened(&self, lp: usize) {
        self.loop_conns[lp].fetch_add(1, Ordering::Relaxed);
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conn_closed(&self, lp: usize) {
        self.loop_conns[lp].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn record_wakeup(&self, lp: usize) {
        self.loop_wakeups[lp].fetch_add(1, Ordering::Relaxed);
    }

    /// Live connections owned by loop `lp`.
    pub fn conns_on(&self, lp: usize) -> u64 {
        self.loop_conns[lp].load(Ordering::Relaxed)
    }

    /// Live connections across all loops.
    pub fn live_conns(&self) -> u64 {
        self.loop_conns.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn wakeups(&self) -> u64 {
        self.loop_wakeups.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn note_queue_depth(&self, depth: usize) {
        self.write_queue_hwm.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// One-line report for logs: per-loop gauges + global counters.
    pub fn report(&self) -> String {
        let conns: Vec<String> = self
            .loop_conns
            .iter()
            .map(|c| c.load(Ordering::Relaxed).to_string())
            .collect();
        let wakeups: Vec<String> = self
            .loop_wakeups
            .iter()
            .map(|c| c.load(Ordering::Relaxed).to_string())
            .collect();
        let mut s = format!(
            "reactor[{}] conns/loop=[{}] wakeups/loop=[{}] accepted={} wq_hwm={} stalls={}",
            self.n_loops(),
            conns.join(","),
            wakeups.join(","),
            self.accepted.load(Ordering::Relaxed),
            self.write_queue_hwm.load(Ordering::Relaxed),
            self.backpressure_stalls.load(Ordering::Relaxed),
        );
        let dead = self.dead_conn_frames.load(Ordering::Relaxed);
        if dead > 0 {
            s.push_str(&format!(" dead_conn_frames={dead}"));
        }
        let deferred = self.deferred_flushes.load(Ordering::Relaxed);
        if deferred > 0 {
            s.push_str(&format!(" deferred_flushes={deferred}"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reactor_stats_gauges_and_report() {
        let r = ReactorStats::new(2);
        assert_eq!(r.n_loops(), 2);
        r.conn_opened(0);
        r.conn_opened(1);
        r.conn_opened(1);
        r.record_wakeup(0);
        r.record_wakeup(1);
        r.record_wakeup(1);
        r.note_queue_depth(7);
        r.note_queue_depth(3); // hwm keeps the max
        r.backpressure_stalls.fetch_add(1, Ordering::Relaxed);
        assert_eq!(r.live_conns(), 3);
        assert_eq!(r.conns_on(1), 2);
        assert_eq!(r.wakeups(), 3);
        assert_eq!(r.accepted.load(Ordering::Relaxed), 3);
        let rep = r.report();
        assert!(rep.contains("conns/loop=[1,2]"), "{rep}");
        assert!(rep.contains("wakeups/loop=[1,2]"), "{rep}");
        assert!(rep.contains("wq_hwm=7"), "{rep}");
        assert!(rep.contains("stalls=1"), "{rep}");
        // Quiet sections stay absent until nonzero.
        assert!(!rep.contains("dead_conn_frames"), "{rep}");
        assert!(!rep.contains("deferred_flushes"), "{rep}");
        r.dead_conn_frames.fetch_add(2, Ordering::Relaxed);
        r.deferred_flushes.fetch_add(5, Ordering::Relaxed);
        let rep = r.report();
        assert!(rep.contains("dead_conn_frames=2"), "{rep}");
        assert!(rep.contains("deferred_flushes=5"), "{rep}");
        r.conn_closed(1);
        assert_eq!(r.live_conns(), 2);
    }

    #[test]
    fn dead_conn_accounting_reported_and_reset() {
        let m = ServeMetrics::new();
        assert!(!m.report().contains("dead-conn jobs"), "quiet when clean");
        m.dead_conn_jobs.fetch_add(2, Ordering::Relaxed);
        m.stream_drop_frames.fetch_add(9, Ordering::Relaxed);
        let rep = m.report();
        assert!(rep.contains("dead-conn jobs: 2"), "{rep}");
        assert!(rep.contains("undeliverable stream frames: 9"), "{rep}");
        m.reset_all();
        assert_eq!(m.dead_conn_jobs.load(Ordering::Relaxed), 0);
        assert_eq!(m.stream_drop_frames.load(Ordering::Relaxed), 0);
        assert!(!m.report().contains("dead-conn jobs"));
    }

    #[test]
    fn shard_stats_counters_and_report() {
        let s = ShardStats::new(3);
        assert_eq!(s.n_shards(), 3);
        s.record_task(0);
        s.record_task(2);
        s.record_task(2);
        s.set_busy(1, true);
        s.note_queue_depth(5);
        s.note_queue_depth(2); // hwm keeps the max
        s.spans_submitted.fetch_add(4, Ordering::Relaxed);
        s.inline_runs.fetch_add(1, Ordering::Relaxed);
        s.record_steal(1);
        s.record_steal(1);
        s.steal_splits.fetch_add(1, Ordering::Relaxed);
        s.chunk_exec.record(1_000);
        assert_eq!(s.spans_completed(), 3);
        assert_eq!(s.tasks_on(2), 2);
        assert_eq!(s.busy_shards(), 1);
        assert_eq!(s.steals_by(1), 2);
        assert_eq!(s.steals(), 2);
        assert_eq!(s.queue_depth_hwm.load(Ordering::Relaxed), 5);
        assert_eq!(s.chunk_exec.count(), 1);
        let rep = s.report();
        assert!(rep.contains("tasks/shard=[1,0,2]"), "{rep}");
        assert!(rep.contains("steals/shard=[0,2,0]"), "{rep}");
        assert!(rep.contains("splits=1"), "{rep}");
        assert!(rep.contains("q_hwm=5"), "{rep}");
        // No pinning requested: the report omits the affinity section.
        assert!(!rep.contains("pinned_cpu"), "{rep}");
        assert_eq!(s.pinned_cpu(0), None);
        s.set_pinned(0, 3);
        s.set_pinned(2, 0);
        s.pin_failures.fetch_add(1, Ordering::Relaxed);
        assert_eq!(s.pinned_cpu(0), Some(3));
        assert_eq!(s.pinned_cpu(1), None);
        assert_eq!(s.pinned_cpu(2), Some(0));
        let rep = s.report();
        assert!(rep.contains("pinned_cpu=[3,-,0]"), "{rep}");
        assert!(rep.contains("pin_failures=1"), "{rep}");
        s.set_busy(1, false);
        assert_eq!(s.busy_shards(), 0);
        // Model-lifecycle counters: quiet until a swap/build happens.
        assert!(!rep.contains("swaps="), "{rep}");
        assert!(!rep.contains("stale_spans"), "{rep}");
        s.model_swaps.fetch_add(1, Ordering::Relaxed);
        s.replica_builds.fetch_add(4, Ordering::Relaxed);
        s.replicas_evicted.fetch_add(2, Ordering::Relaxed);
        s.stale_spans.fetch_add(1, Ordering::Relaxed);
        s.replica_build.record(10_000);
        let rep = s.report();
        assert!(rep.contains("swaps=1 replica_builds=4 evicted=2"), "{rep}");
        assert!(rep.contains("stale_spans=1"), "{rep}");
        assert_eq!(s.replica_build.count(), 1);
    }

    #[test]
    fn suggested_depth_tracks_completion_gap() {
        let m = ServeMetrics::new();
        // No history: no overlap worth holding results for.
        assert_eq!(m.suggested_pipeline_depth(), 1);
        // RPC as fast as stage 1: still depth 1.
        m.block_stage1_complete.record(1_000);
        m.block_rpc_complete.record(1_000);
        assert_eq!(m.suggested_pipeline_depth(), 1);
        // RPC ~3× stage 1: two extra blocks fit under the outstanding RPC.
        m.reset_all();
        m.block_stage1_complete.record(1_000);
        m.block_rpc_complete.record(3_000);
        assert_eq!(m.suggested_pipeline_depth(), 3);
        // A huge gap saturates at the depth-4 cap.
        m.reset_all();
        m.block_stage1_complete.record(1_000);
        m.block_rpc_complete.record(1_000_000);
        assert_eq!(m.suggested_pipeline_depth(), 4);
    }

    #[test]
    fn stream_metrics_recorded_and_reported() {
        let m = ServeMetrics::new();
        assert!(!m.report().contains("stream chunks"));
        m.stream_chunks.fetch_add(3, Ordering::Relaxed);
        m.chunk_emit.record(2_000);
        m.block_span_complete.record(4_000);
        let rep = m.report();
        assert!(rep.contains("stream chunks: 3"), "{rep}");
        assert!(rep.contains("chunk-emit"), "{rep}");
        assert!(rep.contains("span-done"), "{rep}");
        m.reset_all();
        assert_eq!(m.stream_chunks.load(Ordering::Relaxed), 0);
        assert_eq!(m.chunk_emit.count(), 0);
        assert_eq!(m.block_span_complete.count(), 0);
    }

    #[test]
    fn thread_cpu_advances_under_work() {
        let t = CpuTimer::start();
        let mut acc = 0u64;
        for i in 0..5_000_000u64 {
            // black_box inside the loop defeats closed-form folding.
            acc = acc.wrapping_add(std::hint::black_box(i) * i);
        }
        std::hint::black_box(acc);
        assert!(t.elapsed_ns() > 100_000, "cpu={}ns", t.elapsed_ns());
    }

    #[test]
    fn thread_cpu_ignores_sleep() {
        let t = CpuTimer::start();
        std::thread::sleep(std::time::Duration::from_millis(30));
        // Sleeping burns (almost) no CPU.
        assert!(t.elapsed_ns() < 10_000_000, "cpu={}ns", t.elapsed_ns());
    }

    #[test]
    fn process_cpu_monotone() {
        let a = process_cpu_ns();
        let mut acc = 0u64;
        for i in 0..1_000_000u64 {
            acc = acc.wrapping_add(i);
        }
        std::hint::black_box(acc);
        let b = process_cpu_ns();
        assert!(b >= a);
    }

    #[test]
    fn block_completion_recorded_and_reported() {
        let m = ServeMetrics::new();
        assert!(!m.report().contains("block stage1-done"));
        m.block_stage1_complete.record(1_000);
        m.block_rpc_complete.record(5_000);
        assert!(m.report().contains("block stage1-done"));
        m.reset_all();
        assert_eq!(m.block_stage1_complete.count(), 0);
        assert_eq!(m.block_rpc_complete.count(), 0);
    }

    #[test]
    fn failure_counters_reported_and_reset() {
        let m = ServeMetrics::new();
        assert!(!m.report().contains("degraded rows"), "quiet when clean");
        m.degraded_rows.fetch_add(7, Ordering::Relaxed);
        m.degraded_requests.fetch_add(2, Ordering::Relaxed);
        m.deadline_shed_rows.fetch_add(3, Ordering::Relaxed);
        m.deadline_shed_requests.fetch_add(1, Ordering::Relaxed);
        m.rpc_retries.fetch_add(4, Ordering::Relaxed);
        m.breaker_trips.fetch_add(1, Ordering::Relaxed);
        let rep = m.report();
        assert!(rep.contains("degraded rows: 7 (reqs: 2)"), "{rep}");
        assert!(rep.contains("deadline-shed rows: 3 (reqs: 1)"), "{rep}");
        assert!(rep.contains("retries: 4"), "{rep}");
        assert!(rep.contains("breaker trips: 1"), "{rep}");
        m.reset_all();
        assert_eq!(m.degraded_rows.load(Ordering::Relaxed), 0);
        assert_eq!(m.breaker_trips.load(Ordering::Relaxed), 0);
        assert!(!m.report().contains("degraded rows"));
    }

    #[test]
    fn overload_counters_reported_and_reset() {
        let m = ServeMetrics::new();
        assert!(!m.report().contains("rejected rows"), "quiet when clean");
        m.rejected_rows.fetch_add(40, Ordering::Relaxed);
        m.rejected_requests.fetch_add(4, Ordering::Relaxed);
        m.sojourn_shed_rows.fetch_add(16, Ordering::Relaxed);
        m.sojourn_shed_requests.fetch_add(2, Ordering::Relaxed);
        let rep = m.report();
        assert!(rep.contains("rejected rows: 40 (reqs: 4)"), "{rep}");
        assert!(rep.contains("sojourn-shed rows: 16 (reqs: 2)"), "{rep}");
        m.reset_all();
        assert_eq!(m.rejected_rows.load(Ordering::Relaxed), 0);
        assert_eq!(m.rejected_requests.load(Ordering::Relaxed), 0);
        assert_eq!(m.sojourn_shed_rows.load(Ordering::Relaxed), 0);
        assert_eq!(m.sojourn_shed_requests.load(Ordering::Relaxed), 0);
        assert!(!m.report().contains("rejected rows"));
    }

    #[test]
    fn shard_deadline_shed_in_report_when_nonzero() {
        let s = ShardStats::new(2);
        assert!(!s.report().contains("deadline_shed"));
        s.deadline_shed.fetch_add(5, Ordering::Relaxed);
        assert!(s.report().contains("deadline_shed=5"), "{}", s.report());
    }

    #[test]
    fn model_reloads_reported_and_reset() {
        let m = ServeMetrics::new();
        assert!(!m.report().contains("model reloads"), "quiet when clean");
        m.model_reloads.fetch_add(3, Ordering::Relaxed);
        assert!(m.report().contains("model reloads: 3"), "{}", m.report());
        m.reset_all();
        assert_eq!(m.model_reloads.load(Ordering::Relaxed), 0);
        assert!(!m.report().contains("model reloads"));
    }

    #[test]
    fn rollout_counters_reported_and_reset() {
        let m = ServeMetrics::new();
        assert!(!m.report().contains("shadow rows"), "quiet when clean");
        m.shadow_rows.fetch_add(12, Ordering::Relaxed);
        m.shadow_shed_rows.fetch_add(3, Ordering::Relaxed);
        m.canary_rows.fetch_add(5, Ordering::Relaxed);
        m.rollout_rolled_back.fetch_add(1, Ordering::Relaxed);
        let rep = m.report();
        assert!(rep.contains("shadow rows: 12 (shed: 3)"), "{rep}");
        assert!(rep.contains("canary rows: 5"), "{rep}");
        assert!(rep.contains("rollbacks: 1"), "{rep}");
        m.reset_all();
        assert_eq!(m.shadow_rows.load(Ordering::Relaxed), 0);
        assert_eq!(m.shadow_shed_rows.load(Ordering::Relaxed), 0);
        assert_eq!(m.canary_rows.load(Ordering::Relaxed), 0);
        assert_eq!(m.rollout_rolled_back.load(Ordering::Relaxed), 0);
        assert!(!m.report().contains("shadow rows"));
    }

    #[test]
    fn rollout_stats_accumulators() {
        let r = RolloutStats::new();
        assert_eq!(r.disagreement_rate(), 0.0, "no comparisons yet");
        r.rows_compared.fetch_add(100, Ordering::Relaxed);
        r.disagreements.fetch_add(4, Ordering::Relaxed);
        assert!((r.disagreement_rate() - 0.04).abs() < 1e-12);
        r.note_score_delta(0.25);
        r.note_score_delta(-0.5); // absolute value recorded
        r.note_score_delta(0.125);
        assert!((r.max_score_delta() - 0.5).abs() < 1e-9);
        assert_eq!(r.score_delta_micro.count(), 3);
        let rep = r.report();
        assert!(rep.contains("compared=100"), "{rep}");
        assert!(rep.contains("disagree=4"), "{rep}");
        assert!(!rep.contains("candidate_failures"), "quiet until nonzero: {rep}");
        r.candidate_failures.fetch_add(2, Ordering::Relaxed);
        assert!(r.report().contains("candidate_failures=2"));
    }

    #[test]
    fn shard_shadow_counters_in_report_when_nonzero() {
        let s = ShardStats::new(2);
        assert!(!s.report().contains("shadow_jobs"));
        s.shadow_jobs.fetch_add(7, Ordering::Relaxed);
        s.shadow_shed.fetch_add(2, Ordering::Relaxed);
        let rep = s.report();
        assert!(rep.contains("shadow_jobs=7 shadow_shed=2 shadow_panics=0"), "{rep}");
    }

    #[test]
    fn metrics_coverage() {
        let m = ServeMetrics::new();
        m.hit_stage1(1000, 500, 8);
        m.hit_stage1(1000, 500, 8);
        m.hit_rpc(5000, 1000, 32, 128);
        assert!((m.coverage() - 2.0 / 3.0).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("coverage: 66.7%"));
    }
}
