//! Column statistics: quantiles, moments, z-score normalization.
//!
//! Quantile computation is the heart of Algorithm 1's binning ("split each of
//! the n most important features into b bins dictated by the quantiles of the
//! feature over the normalized training set"). We use exact order-statistic
//! quantiles with linear interpolation (type-7, the numpy default) so the
//! Rust trainer, the Python reference and the Pallas kernel all agree on bin
//! boundaries.

/// Exact quantile (type-7 / linear interpolation) of unsorted data.
/// `q` in [0,1]. Returns NaN on empty input.
pub fn quantile(xs: &[f32], q: f64) -> f32 {
    if xs.is_empty() {
        return f32::NAN;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Quantile of already-sorted data.
pub fn quantile_sorted(sorted: &[f32], q: f64) -> f32 {
    let n = sorted.len();
    if n == 0 {
        return f32::NAN;
    }
    if n == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = (pos - lo as f64) as f32;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// The `b-1` interior quantile boundaries that split data into `b`
/// equal-probability bins: q = 1/b, 2/b, …, (b-1)/b.
pub fn bin_boundaries(xs: &[f32], b: usize) -> Vec<f32> {
    debug_assert!(b >= 2);
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (1..b)
        .map(|k| quantile_sorted(&v, k as f64 / b as f64))
        .collect()
}

/// Mean and (population) standard deviation.
pub fn mean_std(xs: &[f32]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Z-score normalization parameters for a feature set, fit on training data
/// and applied to validation/serving inputs (paper: quantiles are over the
/// *normalized* training set).
#[derive(Clone, Debug)]
pub struct Normalizer {
    pub means: Vec<f64>,
    pub stds: Vec<f64>,
    /// Cached reciprocals; normalization is `(v - mean) * inv_std` in f64
    /// (multiply beats divide on the serving hot path; ServingTables uses
    /// the identical formula so bin ids can never diverge).
    pub inv_stds: Vec<f64>,
}

impl Normalizer {
    /// Fit per-column normalization. Non-numeric columns get identity
    /// (mean 0, std 1) so codes pass through unchanged.
    pub fn fit(data: &super::Dataset) -> Normalizer {
        let mut means = Vec::with_capacity(data.n_features());
        let mut stds = Vec::with_capacity(data.n_features());
        for (f, col) in data.cols.iter().enumerate() {
            if data.schema.types[f].is_numeric() {
                let (m, s) = mean_std(col);
                means.push(m);
                stds.push(if s > 1e-12 { s } else { 1.0 });
            } else {
                means.push(0.0);
                stds.push(1.0);
            }
        }
        let inv_stds = stds.iter().map(|&s| 1.0 / s).collect();
        Normalizer { means, stds, inv_stds }
    }

    #[inline]
    pub fn apply_value(&self, f: usize, v: f32) -> f32 {
        ((v as f64 - self.means[f]) * self.inv_stds[f]) as f32
    }

    /// Normalize a full dataset (producing a copy).
    pub fn apply(&self, data: &super::Dataset) -> super::Dataset {
        let mut out = data.clone();
        for (f, col) in out.cols.iter_mut().enumerate() {
            let (m, s) = (self.means[f], self.stds[f]);
            if m != 0.0 || s != 1.0 {
                // f64 arithmetic to match apply_value/apply_row exactly.
                let inv = 1.0 / s;
                for v in col.iter_mut() {
                    *v = ((*v as f64 - m) * inv) as f32;
                }
            }
        }
        out
    }

    /// Normalize a row in place.
    pub fn apply_row(&self, row: &mut [f32]) {
        for (f, v) in row.iter_mut().enumerate() {
            *v = ((*v as f64 - self.means[f]) * self.inv_stds[f]) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tabular::{Dataset, Schema};

    #[test]
    fn quantile_matches_numpy_type7() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        // numpy.quantile([1,2,3,4], .25) = 1.75
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-6);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-6);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
    }

    #[test]
    fn quantile_single_and_empty() {
        assert_eq!(quantile(&[7.0], 0.3), 7.0);
        assert!(quantile(&[], 0.5).is_nan());
    }

    #[test]
    fn bin_boundaries_split_evenly() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let bounds = bin_boundaries(&xs, 4);
        assert_eq!(bounds.len(), 3);
        // Quartiles of 0..999 ≈ 249.75, 499.5, 749.25
        assert!((bounds[0] - 249.75).abs() < 0.01);
        assert!((bounds[1] - 499.5).abs() < 0.01);
        assert!((bounds[2] - 749.25).abs() < 0.01);
    }

    #[test]
    fn bin_boundaries_monotone_even_with_ties() {
        let xs = vec![1.0f32; 100];
        let bounds = bin_boundaries(&xs, 3);
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn normalizer_zero_mean_unit_std() {
        let mut d = Dataset::new(Schema::numeric(1));
        for i in 0..100 {
            d.push_row(&[i as f32 * 2.0 + 5.0], (i % 2) as f32);
        }
        let norm = Normalizer::fit(&d);
        let nd = norm.apply(&d);
        let (m, s) = mean_std(&nd.cols[0]);
        assert!(m.abs() < 1e-5);
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn normalizer_identity_for_boolean() {
        use crate::tabular::ColType;
        let mut d = Dataset::new(Schema {
            names: vec!["b".into()],
            types: vec![ColType::Boolean],
        });
        d.push_row(&[1.0], 1.0);
        d.push_row(&[0.0], 0.0);
        let norm = Normalizer::fit(&d);
        let nd = norm.apply(&d);
        assert_eq!(nd.cols[0], vec![1.0, 0.0]);
    }

    #[test]
    fn normalizer_constant_column_safe() {
        let mut d = Dataset::new(Schema::numeric(1));
        for _ in 0..10 {
            d.push_row(&[3.0], 0.0);
        }
        let norm = Normalizer::fit(&d);
        let nd = norm.apply(&d);
        assert!(nd.cols[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn apply_row_matches_apply() {
        let mut d = Dataset::new(Schema::numeric(2));
        for i in 0..50 {
            d.push_row(&[i as f32, (i * i) as f32], (i % 2) as f32);
        }
        let norm = Normalizer::fit(&d);
        let nd = norm.apply(&d);
        let mut row = d.row(7);
        norm.apply_row(&mut row);
        assert_eq!(row, nd.row(7));
    }
}
