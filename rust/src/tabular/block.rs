//! Columnar row-batch abstraction (`RowBlock`) for the serving hot path.
//!
//! # Layout
//!
//! A `RowBlock` stores a batch of request rows **feature-major** (structure
//! of arrays): `data[f * n_rows + r]` is feature `f` of row `r`. This is the
//! layout every batched consumer wants:
//!
//! * the stage-1 block evaluator (`ServingTables::evaluate_block`)
//!   normalizes and edge-counts one feature column at a time, so the
//!   per-feature constants (mean, inv_std, quantile edges) stay in
//!   registers/L1 while the row dimension streams sequentially — the inner
//!   loops are straight-line, branchless and auto-vectorizable;
//! * the flat forest (`gbdt::FlatForest::predict_block`) gathers
//!   `x[r][feat]` per split; with a columnar block, consecutive rows of the
//!   same feature share cache lines, so tree-major/row-minor traversal hits
//!   warm lines as the row lanes advance in lockstep;
//! * `Dataset` is already column-major, so building a block from stored
//!   data is a straight `copy_from_slice` per feature — no per-row gather.
//!
//! Blocks are designed for reuse: every `fill_*` method recycles the
//! backing buffer, so a steady-state serving loop performs no allocation —
//! and, because each fill overwrites its whole region, no redundant
//! zero-fill pass either (only `reset` promises blank cells).

use super::Dataset;

/// A columnar (feature-major) batch of dense `f32` rows.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RowBlock {
    n_rows: usize,
    n_features: usize,
    /// Feature-major values: `data[f * n_rows + r]`.
    data: Vec<f32>,
}

impl RowBlock {
    pub fn new() -> RowBlock {
        RowBlock::default()
    }

    /// Build a block directly from row slices (all rows must share a width).
    pub fn from_rows<R: AsRef<[f32]>>(rows: &[R]) -> RowBlock {
        let mut b = RowBlock::new();
        b.fill_from_rows(rows);
        b
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    /// Reset to an all-zero `n_features × n_rows` block, reusing the buffer.
    pub fn reset(&mut self, n_features: usize, n_rows: usize) {
        self.n_features = n_features;
        self.n_rows = n_rows;
        self.data.clear();
        self.data.resize(n_features * n_rows, 0.0);
    }

    /// Shape the buffer for a fill that overwrites **every** cell:
    /// grow-only, no zeroing pass over memory the caller is about to
    /// write (the `fill_*` methods below all write the full region; a
    /// steady-state serving loop re-filling one block thus never touches
    /// a cell twice). `reset` stays the all-zero API for callers that
    /// want blank cells.
    fn reuse_for_overwrite(&mut self, n_features: usize, n_rows: usize) {
        self.n_features = n_features;
        self.n_rows = n_rows;
        let need = n_features * n_rows;
        if self.data.len() < need {
            self.data.resize(need, 0.0);
        } else {
            // Truncate adjusts the length without writing the kept cells.
            self.data.truncate(need);
        }
    }

    /// Transpose row-major `rows` into this block, reusing the buffer.
    pub fn fill_from_rows<R: AsRef<[f32]>>(&mut self, rows: &[R]) {
        let n_features = rows.first().map_or(0, |r| r.as_ref().len());
        self.reuse_for_overwrite(n_features, rows.len());
        for (r, row) in rows.iter().enumerate() {
            let row = row.as_ref();
            // Hard assert: a ragged batch zero-filled silently would serve
            // plausible-but-wrong probabilities (the per-row path panicked).
            assert_eq!(row.len(), n_features, "ragged row batch (row {r})");
            for (f, &v) in row.iter().enumerate() {
                self.data[f * self.n_rows + r] = v;
            }
        }
    }

    /// Transpose a flat row-major buffer (`rows.len() >= n_rows * row_len`),
    /// reusing the block's buffer. Extra trailing values are ignored.
    pub fn fill_from_flat(&mut self, rows: &[f32], n_rows: usize, row_len: usize) {
        debug_assert!(rows.len() >= n_rows * row_len);
        self.reuse_for_overwrite(row_len, n_rows);
        for r in 0..n_rows {
            let src = &rows[r * row_len..(r + 1) * row_len];
            for (f, &v) in src.iter().enumerate() {
                self.data[f * n_rows + r] = v;
            }
        }
    }

    /// Copy `n` rows starting at `start` out of a (column-major) dataset —
    /// one straight `copy_from_slice` per feature column.
    pub fn fill_from_dataset(&mut self, d: &Dataset, start: usize, n: usize) {
        debug_assert!(start + n <= d.n_rows());
        self.reuse_for_overwrite(d.n_features(), n);
        for (f, col) in d.cols.iter().enumerate() {
            self.data[f * n..(f + 1) * n].copy_from_slice(&col[start..start + n]);
        }
    }

    /// Contiguous column of feature `f` across all rows.
    #[inline]
    pub fn feature(&self, f: usize) -> &[f32] {
        &self.data[f * self.n_rows..(f + 1) * self.n_rows]
    }

    /// Single value (row `r`, feature `f`).
    #[inline]
    pub fn get(&self, r: usize, f: usize) -> f32 {
        debug_assert!(r < self.n_rows && f < self.n_features);
        self.data[f * self.n_rows + r]
    }

    /// Gather row `r` into `buf` (cleared first) in feature order.
    pub fn row_into(&self, r: usize, buf: &mut Vec<f32>) {
        buf.clear();
        buf.reserve(self.n_features);
        for f in 0..self.n_features {
            buf.push(self.data[f * self.n_rows + r]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tabular::Schema;

    fn sample_rows() -> Vec<Vec<f32>> {
        vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
            vec![-1.0, -2.0, -3.0],
        ]
    }

    #[test]
    fn from_rows_roundtrip() {
        let rows = sample_rows();
        let b = RowBlock::from_rows(&rows);
        assert_eq!(b.n_rows(), 4);
        assert_eq!(b.n_features(), 3);
        let mut buf = Vec::new();
        for (r, row) in rows.iter().enumerate() {
            b.row_into(r, &mut buf);
            assert_eq!(&buf, row, "row {r}");
            for (f, &v) in row.iter().enumerate() {
                assert_eq!(b.get(r, f), v);
            }
        }
    }

    #[test]
    fn feature_columns_contiguous() {
        let b = RowBlock::from_rows(&sample_rows());
        assert_eq!(b.feature(0), &[1.0, 4.0, 7.0, -1.0]);
        assert_eq!(b.feature(2), &[3.0, 6.0, 9.0, -3.0]);
    }

    #[test]
    fn fill_from_flat_matches_from_rows() {
        let rows = sample_rows();
        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        let mut b = RowBlock::new();
        b.fill_from_flat(&flat, rows.len(), 3);
        assert_eq!(b, RowBlock::from_rows(&rows));
    }

    #[test]
    fn fill_from_dataset_matches_rows() {
        let mut d = Dataset::new(Schema::numeric(3));
        for (i, row) in sample_rows().iter().enumerate() {
            d.push_row(row, (i % 2) as f32);
        }
        let mut b = RowBlock::new();
        b.fill_from_dataset(&d, 1, 2);
        assert_eq!(b.n_rows(), 2);
        let mut buf = Vec::new();
        b.row_into(0, &mut buf);
        assert_eq!(buf, d.row(1));
        b.row_into(1, &mut buf);
        assert_eq!(buf, d.row(2));
    }

    #[test]
    fn reuse_shrinks_and_grows() {
        let mut b = RowBlock::new();
        b.fill_from_rows(&sample_rows());
        assert_eq!(b.n_rows(), 4);
        b.fill_from_rows(&sample_rows()[..1]);
        assert_eq!(b.n_rows(), 1);
        assert_eq!(b.feature(1), &[2.0]);
        b.fill_from_rows(&sample_rows());
        assert_eq!(b.feature(1), &[2.0, 5.0, 8.0, -2.0]);
    }

    #[test]
    fn non_zeroing_reuse_never_leaks_stale_cells() {
        let mut b = RowBlock::new();
        b.fill_from_rows(&vec![vec![9.0f32; 4]; 8]); // big, dirty fill
        b.fill_from_rows(&[vec![1.0f32, 2.0], vec![3.0, 4.0]]); // smaller
        assert_eq!((b.n_rows(), b.n_features()), (2, 2));
        assert_eq!(b.feature(0), &[1.0, 3.0]);
        assert_eq!(b.feature(1), &[2.0, 4.0]);
        // Equality with a fresh block: leftover capacity must not leak
        // into the compared region.
        assert_eq!(b, RowBlock::from_rows(&[vec![1.0f32, 2.0], vec![3.0, 4.0]]));
        // reset() keeps its all-zero contract even over a dirty buffer.
        b.reset(3, 2);
        assert!((0..3).flat_map(|f| b.feature(f).iter()).all(|&v| v == 0.0));
    }

    #[test]
    fn empty_block() {
        let b = RowBlock::from_rows(&Vec::<Vec<f32>>::new());
        assert!(b.is_empty());
        assert_eq!(b.n_features(), 0);
    }
}
