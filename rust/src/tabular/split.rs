//! Train/validation/test splitting.
//!
//! Algorithm 1 needs a train set (bin quantiles + per-bin LR + GBDT) and a
//! validation set (Algorithm 2's bin allocation); evaluation uses a held-out
//! test set. Splits are seeded-shuffled index partitions.

use super::Dataset;
use crate::util::rng::Rng;

/// Two-way split.
pub struct Split {
    pub train: Dataset,
    pub test: Dataset,
}

/// Three-way split (train / validation / test).
pub struct ThreeWaySplit {
    pub train: Dataset,
    pub val: Dataset,
    pub test: Dataset,
}

/// Shuffle rows and split by fraction.
pub fn train_test_split(data: &Dataset, test_frac: f64, rng: &mut Rng) -> Split {
    let n = data.n_rows();
    let mut idx = rng.permutation(n);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let test_idx: Vec<usize> = idx.drain(..n_test.min(n)).collect();
    Split {
        train: data.take_rows(&idx),
        test: data.take_rows(&test_idx),
    }
}

/// Shuffle rows and split three ways. `fracs = (train, val, test)` must sum
/// to ~1.
pub fn three_way_split(data: &Dataset, fracs: (f64, f64, f64), rng: &mut Rng) -> ThreeWaySplit {
    let (ft, fv, fs) = fracs;
    debug_assert!((ft + fv + fs - 1.0).abs() < 1e-6);
    let n = data.n_rows();
    let idx = rng.permutation(n);
    let n_train = ((n as f64) * ft).round() as usize;
    let n_val = ((n as f64) * fv).round() as usize;
    let (train_idx, rest) = idx.split_at(n_train.min(n));
    let (val_idx, test_idx) = rest.split_at(n_val.min(rest.len()));
    ThreeWaySplit {
        train: data.take_rows(train_idx),
        val: data.take_rows(val_idx),
        test: data.take_rows(test_idx),
    }
}

/// Stratified two-way split: preserves the positive rate in both parts
/// (important for the small public datasets like Banknote, 1k rows).
pub fn stratified_split(data: &Dataset, test_frac: f64, rng: &mut Rng) -> Split {
    let mut pos: Vec<usize> = Vec::new();
    let mut neg: Vec<usize> = Vec::new();
    for (i, &y) in data.labels.iter().enumerate() {
        if y > 0.5 {
            pos.push(i);
        } else {
            neg.push(i);
        }
    }
    rng.shuffle(&mut pos);
    rng.shuffle(&mut neg);
    let np = ((pos.len() as f64) * test_frac).round() as usize;
    let nn = ((neg.len() as f64) * test_frac).round() as usize;
    let mut test_idx: Vec<usize> = pos[..np].to_vec();
    test_idx.extend_from_slice(&neg[..nn]);
    let mut train_idx: Vec<usize> = pos[np..].to_vec();
    train_idx.extend_from_slice(&neg[nn..]);
    rng.shuffle(&mut test_idx);
    rng.shuffle(&mut train_idx);
    Split {
        train: data.take_rows(&train_idx),
        test: data.take_rows(&test_idx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tabular::Schema;

    fn make(n: usize) -> Dataset {
        let mut d = Dataset::new(Schema::numeric(2));
        for i in 0..n {
            d.push_row(&[i as f32, (n - i) as f32], (i % 4 == 0) as u8 as f32);
        }
        d
    }

    #[test]
    fn split_sizes_and_disjoint() {
        let d = make(1000);
        let mut rng = Rng::new(1);
        let s = train_test_split(&d, 0.2, &mut rng);
        assert_eq!(s.test.n_rows(), 200);
        assert_eq!(s.train.n_rows(), 800);
        // Row identities: feature 0 is a unique id.
        let mut ids: Vec<i64> = s
            .train
            .cols[0]
            .iter()
            .chain(s.test.cols[0].iter())
            .map(|&v| v as i64)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 1000);
    }

    #[test]
    fn three_way_sums() {
        let d = make(500);
        let mut rng = Rng::new(2);
        let s = three_way_split(&d, (0.6, 0.2, 0.2), &mut rng);
        assert_eq!(s.train.n_rows() + s.val.n_rows() + s.test.n_rows(), 500);
        assert_eq!(s.train.n_rows(), 300);
    }

    #[test]
    fn stratified_preserves_rate() {
        let d = make(1000); // 25% positive
        let mut rng = Rng::new(3);
        let s = stratified_split(&d, 0.3, &mut rng);
        assert!((s.test.positive_rate() - 0.25).abs() < 0.01);
        assert!((s.train.positive_rate() - 0.25).abs() < 0.01);
    }

    #[test]
    fn deterministic_given_seed() {
        let d = make(100);
        let s1 = train_test_split(&d, 0.5, &mut Rng::new(9));
        let s2 = train_test_split(&d, 0.5, &mut Rng::new(9));
        assert_eq!(s1.train.cols[0], s2.train.cols[0]);
    }
}
