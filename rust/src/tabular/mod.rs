//! Tabular dataset substrate: schema, column-major storage, splits, CSV IO,
//! quantiles and normalization.
//!
//! The paper operates on medium tabular data (100K–10M rows, dozens to low
//! thousands of features) with mixed feature types — numeric, Boolean and
//! categorical — which get special handling during binning (Algorithm 1).
//! Storage is column-major `f32` (categoricals are stored as small integer
//! codes), which is the layout the histogram GBDT trainer and quantile
//! computations want; the serving path materializes row vectors on demand,
//! or whole columnar batches via [`block::RowBlock`] on the batched path.

pub mod block;
pub mod csv;
pub mod split;
pub mod stats;

pub use block::RowBlock;
pub use split::{Split, ThreeWaySplit};

/// Feature type. Categorical features carry their cardinality so binning can
/// one-hot/bin them correctly (paper §3: Booleans get 2 bins, categoricals
/// get per-value bins).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ColType {
    Numeric,
    Boolean,
    Categorical { cardinality: usize },
}

impl ColType {
    pub fn is_numeric(&self) -> bool {
        matches!(self, ColType::Numeric)
    }
}

/// Dataset schema: feature names + types. The label is binary {0,1} and kept
/// separately from features.
#[derive(Clone, Debug)]
pub struct Schema {
    pub names: Vec<String>,
    pub types: Vec<ColType>,
}

impl Schema {
    pub fn numeric(n: usize) -> Schema {
        Schema {
            names: (0..n).map(|i| format!("f{i}")).collect(),
            types: vec![ColType::Numeric; n],
        }
    }

    pub fn n_features(&self) -> usize {
        self.names.len()
    }
}

/// Column-major tabular dataset with binary labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub schema: Schema,
    /// `cols[f][r]` = value of feature `f` in row `r`.
    pub cols: Vec<Vec<f32>>,
    /// Binary labels in {0.0, 1.0}.
    pub labels: Vec<f32>,
}

impl Dataset {
    pub fn new(schema: Schema) -> Dataset {
        let n = schema.n_features();
        Dataset {
            schema,
            cols: vec![Vec::new(); n],
            labels: Vec::new(),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.labels.len()
    }

    pub fn n_features(&self) -> usize {
        self.cols.len()
    }

    /// Append one row (feature values in schema order).
    pub fn push_row(&mut self, features: &[f32], label: f32) {
        debug_assert_eq!(features.len(), self.n_features());
        debug_assert!(label == 0.0 || label == 1.0, "labels must be binary");
        for (c, &v) in self.cols.iter_mut().zip(features) {
            c.push(v);
        }
        self.labels.push(label);
    }

    /// Materialize row `r` into `buf` (cleared first).
    pub fn row_into(&self, r: usize, buf: &mut Vec<f32>) {
        buf.clear();
        buf.extend(self.cols.iter().map(|c| c[r]));
    }

    pub fn row(&self, r: usize) -> Vec<f32> {
        let mut buf = Vec::with_capacity(self.n_features());
        self.row_into(r, &mut buf);
        buf
    }

    /// Positive-label rate.
    pub fn positive_rate(&self) -> f64 {
        if self.labels.is_empty() {
            return 0.0;
        }
        self.labels.iter().map(|&y| y as f64).sum::<f64>() / self.labels.len() as f64
    }

    /// Select a subset of rows (by index) into a new dataset.
    pub fn take_rows(&self, idx: &[usize]) -> Dataset {
        let mut cols = Vec::with_capacity(self.n_features());
        for c in &self.cols {
            cols.push(idx.iter().map(|&i| c[i]).collect());
        }
        Dataset {
            schema: self.schema.clone(),
            cols,
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
        }
    }

    /// Select a subset of feature columns (by index) into a new dataset.
    pub fn take_features(&self, feats: &[usize]) -> Dataset {
        Dataset {
            schema: Schema {
                names: feats.iter().map(|&f| self.schema.names[f].clone()).collect(),
                types: feats.iter().map(|&f| self.schema.types[f].clone()).collect(),
            },
            cols: feats.iter().map(|&f| self.cols[f].clone()).collect(),
            labels: self.labels.clone(),
        }
    }

    /// First `n` rows (cheap prefix view used by the scaling study, Fig. 6).
    pub fn head(&self, n: usize) -> Dataset {
        let n = n.min(self.n_rows());
        Dataset {
            schema: self.schema.clone(),
            cols: self.cols.iter().map(|c| c[..n].to_vec()).collect(),
            labels: self.labels[..n].to_vec(),
        }
    }

    /// Sanity-check invariants (used by tests and after CSV load).
    pub fn validate(&self) -> Result<(), String> {
        if self.cols.len() != self.schema.n_features() {
            return Err("column count != schema".into());
        }
        for (f, c) in self.cols.iter().enumerate() {
            if c.len() != self.labels.len() {
                return Err(format!("column {f} length {} != rows {}", c.len(), self.labels.len()));
            }
            match self.schema.types[f] {
                ColType::Boolean => {
                    if c.iter().any(|&v| v != 0.0 && v != 1.0) {
                        return Err(format!("boolean column {f} has non-binary values"));
                    }
                }
                ColType::Categorical { cardinality } => {
                    if c.iter().any(|&v| v < 0.0 || v >= cardinality as f32 || v.fract() != 0.0) {
                        return Err(format!("categorical column {f} out of range"));
                    }
                }
                ColType::Numeric => {
                    if c.iter().any(|&v| !v.is_finite()) {
                        return Err(format!("numeric column {f} has non-finite values"));
                    }
                }
            }
        }
        if self.labels.iter().any(|&y| y != 0.0 && y != 1.0) {
            return Err("labels must be in {0,1}".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let mut d = Dataset::new(Schema {
            names: vec!["a".into(), "b".into(), "cat".into()],
            types: vec![
                ColType::Numeric,
                ColType::Boolean,
                ColType::Categorical { cardinality: 3 },
            ],
        });
        d.push_row(&[0.5, 1.0, 2.0], 1.0);
        d.push_row(&[-1.5, 0.0, 0.0], 0.0);
        d.push_row(&[2.5, 1.0, 1.0], 1.0);
        d
    }

    #[test]
    fn push_and_row_roundtrip() {
        let d = tiny();
        assert_eq!(d.n_rows(), 3);
        assert_eq!(d.row(1), vec![-1.5, 0.0, 0.0]);
        d.validate().unwrap();
    }

    #[test]
    fn take_rows_subsets() {
        let d = tiny();
        let s = d.take_rows(&[2, 0]);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.row(0), vec![2.5, 1.0, 1.0]);
        assert_eq!(s.labels, vec![1.0, 1.0]);
    }

    #[test]
    fn take_features_subsets() {
        let d = tiny();
        let s = d.take_features(&[2, 0]);
        assert_eq!(s.schema.names, vec!["cat", "a"]);
        assert_eq!(s.row(0), vec![2.0, 0.5]);
        assert_eq!(s.labels.len(), 3);
    }

    #[test]
    fn positive_rate() {
        let d = tiny();
        assert!((d.positive_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn validate_catches_bad_boolean() {
        let mut d = tiny();
        d.cols[1][0] = 0.5;
        assert!(d.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_categorical() {
        let mut d = tiny();
        d.cols[2][0] = 7.0;
        assert!(d.validate().is_err());
    }

    #[test]
    fn head_prefix() {
        let d = tiny();
        let h = d.head(2);
        assert_eq!(h.n_rows(), 2);
        assert_eq!(h.row(1), d.row(1));
    }
}
