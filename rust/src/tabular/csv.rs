//! CSV read/write for datasets.
//!
//! Format: header row with feature names, then one row per datum, label in
//! the last column named `label`. A sidecar `<name>.schema.json` carries the
//! column types so categorical cardinalities survive the round trip.

use super::{ColType, Dataset, Schema};
use crate::util::json::Json;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write dataset + schema sidecar.
pub fn write_csv(data: &Dataset, path: &Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let header: Vec<&str> = data
        .schema
        .names
        .iter()
        .map(|s| s.as_str())
        .chain(std::iter::once("label"))
        .collect();
    writeln!(w, "{}", header.join(","))?;
    let nf = data.n_features();
    let mut line = String::new();
    for r in 0..data.n_rows() {
        line.clear();
        for f in 0..nf {
            let v = data.cols[f][r];
            if v == v.trunc() && v.abs() < 1e7 {
                line.push_str(&format!("{}", v as i64));
            } else {
                line.push_str(&format!("{v}"));
            }
            line.push(',');
        }
        line.push_str(&format!("{}", data.labels[r] as i64));
        writeln!(w, "{line}")?;
    }
    w.flush()?;
    // Schema sidecar.
    let mut types = Vec::new();
    for t in &data.schema.types {
        types.push(match t {
            ColType::Numeric => Json::Str("numeric".into()),
            ColType::Boolean => Json::Str("boolean".into()),
            ColType::Categorical { cardinality } => {
                Json::Str(format!("categorical:{cardinality}"))
            }
        });
    }
    let mut obj = Json::obj();
    obj.set("types", Json::Arr(types));
    std::fs::write(schema_path(path), obj.pretty())?;
    Ok(())
}

fn schema_path(csv: &Path) -> std::path::PathBuf {
    let mut p = csv.as_os_str().to_owned();
    p.push(".schema.json");
    std::path::PathBuf::from(p)
}

/// Read dataset; uses the schema sidecar if present, otherwise infers
/// (integer 0/1 columns → Boolean, small-integer → Categorical, else
/// Numeric).
pub fn read_csv(path: &Path) -> std::io::Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "empty csv"))??;
    let mut names: Vec<String> = header.split(',').map(|s| s.trim().to_string()).collect();
    let label_col = names
        .iter()
        .position(|n| n == "label")
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "no label column"))?;
    names.remove(label_col);
    let nf = names.len();

    let mut cols: Vec<Vec<f32>> = vec![Vec::new(); nf];
    let mut labels = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut fi = 0;
        let mut label = None;
        for (ci, cell) in line.split(',').enumerate() {
            let v: f32 = cell.trim().parse().map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad number '{cell}' line {}", lineno + 2),
                )
            })?;
            if ci == label_col {
                label = Some(v);
            } else {
                if fi >= nf {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("line {} has too many columns", lineno + 2),
                    ));
                }
                cols[fi].push(v);
                fi += 1;
            }
        }
        if fi != nf {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {} has {} features, expected {nf}", lineno + 2, fi),
            ));
        }
        labels.push(label.unwrap());
    }

    // Types: sidecar, else inference.
    let types = match std::fs::read_to_string(schema_path(path)) {
        Ok(text) => parse_schema_types(&text, nf)?,
        Err(_) => infer_types(&cols),
    };

    let data = Dataset {
        schema: Schema { names, types },
        cols,
        labels,
    };
    data.validate()
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    Ok(data)
}

fn parse_schema_types(text: &str, nf: usize) -> std::io::Result<Vec<ColType>> {
    let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    let j = Json::parse(text).map_err(|e| err(&e.to_string()))?;
    let arr = j
        .get("types")
        .and_then(Json::as_arr)
        .ok_or_else(|| err("schema missing types"))?;
    if arr.len() != nf {
        return Err(err("schema/csv column count mismatch"));
    }
    arr.iter()
        .map(|t| {
            let s = t.as_str().ok_or_else(|| err("bad type entry"))?;
            Ok(match s {
                "numeric" => ColType::Numeric,
                "boolean" => ColType::Boolean,
                s if s.starts_with("categorical:") => ColType::Categorical {
                    cardinality: s["categorical:".len()..]
                        .parse()
                        .map_err(|_| err("bad cardinality"))?,
                },
                _ => return Err(err(&format!("unknown type '{s}'"))),
            })
        })
        .collect()
}

fn infer_types(cols: &[Vec<f32>]) -> Vec<ColType> {
    cols.iter()
        .map(|c| {
            let all_int = c.iter().all(|&v| v == v.trunc() && v >= 0.0);
            if !all_int {
                return ColType::Numeric;
            }
            let max = c.iter().cloned().fold(0.0f32, f32::max);
            if max <= 1.0 {
                ColType::Boolean
            } else if max < 32.0 {
                ColType::Categorical {
                    cardinality: max as usize + 1,
                }
            } else {
                ColType::Numeric
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lrwbins_csv_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> Dataset {
        let mut d = Dataset::new(Schema {
            names: vec!["x".into(), "flag".into(), "kind".into()],
            types: vec![
                ColType::Numeric,
                ColType::Boolean,
                ColType::Categorical { cardinality: 4 },
            ],
        });
        d.push_row(&[1.25, 1.0, 3.0], 1.0);
        d.push_row(&[-0.5, 0.0, 0.0], 0.0);
        d.push_row(&[1e7 as f32, 1.0, 2.0], 1.0);
        d
    }

    #[test]
    fn roundtrip_with_sidecar() {
        let p = tmpfile("roundtrip.csv");
        let d = sample();
        write_csv(&d, &p).unwrap();
        let d2 = read_csv(&p).unwrap();
        assert_eq!(d2.schema.names, d.schema.names);
        assert_eq!(d2.schema.types, d.schema.types);
        assert_eq!(d2.labels, d.labels);
        for f in 0..3 {
            assert_eq!(d2.cols[f], d.cols[f]);
        }
    }

    #[test]
    fn inference_without_sidecar() {
        let p = tmpfile("nosidecar.csv");
        std::fs::write(&p, "a,b,label\n0.5,1,1\n1.5,0,0\n2.5,1,1\n").unwrap();
        let d = read_csv(&p).unwrap();
        assert_eq!(d.schema.types[0], ColType::Numeric);
        assert_eq!(d.schema.types[1], ColType::Boolean);
        assert_eq!(d.n_rows(), 3);
    }

    #[test]
    fn missing_label_column_errors() {
        let p = tmpfile("nolabel.csv");
        std::fs::write(&p, "a,b\n1,2\n").unwrap();
        assert!(read_csv(&p).is_err());
    }

    #[test]
    fn ragged_row_errors() {
        let p = tmpfile("ragged.csv");
        std::fs::write(&p, "a,label\n1,0\n1,2,3\n").unwrap();
        assert!(read_csv(&p).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let p = tmpfile("badnum.csv");
        std::fs::write(&p, "a,label\nfoo,0\n").unwrap();
        assert!(read_csv(&p).is_err());
    }
}
