//! Bin allocation between stages — Algorithm 2 (`FilterCombinedBins`).
//!
//! On a validation set, evaluate both models per combined bin, sort bins by
//! how much the second stage beats the first, then scan cumulative prefixes:
//! each prefix is a candidate "stage-1 serves these bins" split. The chosen
//! split maximizes coverage subject to a metric-loss tolerance vs. pure
//! second-stage inference. The full scan *is* Figure 7's coverage curve; the
//! per-bin table is Figure 3's bar data.

use crate::metrics::{accuracy, roc_auc};
use crate::tabular::Dataset;
use std::collections::{HashMap, HashSet};

/// Metric used to rank bins and score hybrids (paper: "using the accuracy
/// to determine the combined bin separation gives the best results").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    RocAuc,
}

impl Metric {
    pub fn eval(&self, scores: &[f32], labels: &[f32]) -> f64 {
        match self {
            Metric::Accuracy => accuracy(scores, labels),
            Metric::RocAuc => roc_auc(scores, labels),
        }
    }
}

/// Per-bin evaluation row (Figure 3 bar).
#[derive(Clone, Debug)]
pub struct BinReport {
    pub bin: u32,
    pub rows: usize,
    pub stage1_metric: f64,
    pub stage2_metric: f64,
    /// stage2 − stage1 (sort key; small/negative ⇒ stage 1 competitive).
    pub gap: f64,
}

/// One point of the coverage sweep (Figure 7 sample).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Fraction of validation rows served by stage 1.
    pub coverage: f64,
    /// Hybrid metrics over the WHOLE validation set.
    pub auc: f64,
    pub accuracy: f64,
    /// Number of bins in the stage-1 prefix.
    pub bins: usize,
}

/// Output of Algorithm 2.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Bins assigned to stage 1 (`W_filtered` keys).
    pub stage1_bins: HashSet<u32>,
    /// Achieved validation coverage.
    pub coverage: f64,
    /// Hybrid metrics at the chosen split.
    pub auc: f64,
    pub accuracy: f64,
    /// Pure second-stage metrics (the baseline the tolerance is against).
    pub stage2_auc: f64,
    pub stage2_accuracy: f64,
    /// Per-bin report (Figure 3).
    pub bins: Vec<BinReport>,
    /// Full sweep (Figure 7).
    pub sweep: Vec<SweepPoint>,
}

/// Inputs: per-validation-row bin id and both models' scores.
pub struct ValScores<'a> {
    pub bin_ids: &'a [u32],
    pub stage1: &'a [f32],
    pub stage2: &'a [f32],
    pub labels: &'a [f32],
}

/// Run Algorithm 2 + the coverage sweep.
///
/// `tolerance` is the admissible drop of `metric` vs. pure stage-2 (paper
/// Table 2 uses per-dataset "small tolerance"); bins are admitted in gap
/// order while the hybrid stays within tolerance.
pub fn allocate(v: &ValScores, metric: Metric, tolerance: f64) -> Allocation {
    let n = v.labels.len();
    assert!(n > 0 && v.bin_ids.len() == n && v.stage1.len() == n && v.stage2.len() == n);

    // Group rows by bin.
    let mut groups: HashMap<u32, Vec<usize>> = HashMap::new();
    for (r, &b) in v.bin_ids.iter().enumerate() {
        groups.entry(b).or_default().push(r);
    }

    // Per-bin metrics.
    let mut bins: Vec<BinReport> = groups
        .iter()
        .map(|(&bin, rows)| {
            let s1: Vec<f32> = rows.iter().map(|&r| v.stage1[r]).collect();
            let s2: Vec<f32> = rows.iter().map(|&r| v.stage2[r]).collect();
            let y: Vec<f32> = rows.iter().map(|&r| v.labels[r]).collect();
            let m1 = metric.eval(&s1, &y);
            let m2 = metric.eval(&s2, &y);
            BinReport {
                bin,
                rows: rows.len(),
                stage1_metric: m1,
                stage2_metric: m2,
                gap: m2 - m1,
            }
        })
        .collect();
    // Sort by gap ascending (stage-1-competitive bins first); tie-break on
    // bin id for determinism.
    bins.sort_by(|a, b| {
        a.gap
            .partial_cmp(&b.gap)
            .unwrap()
            .then(a.bin.cmp(&b.bin))
    });

    // Cumulative sweep: hybrid scores start as pure stage-2 and flip bins
    // to stage-1 one prefix step at a time.
    let mut hybrid: Vec<f32> = v.stage2.to_vec();
    let stage2_auc = roc_auc(&hybrid, v.labels);
    let stage2_accuracy = accuracy(&hybrid, v.labels);

    let mut sweep = Vec::with_capacity(bins.len() + 1);
    sweep.push(SweepPoint {
        coverage: 0.0,
        auc: stage2_auc,
        accuracy: stage2_accuracy,
        bins: 0,
    });

    let mut covered = 0usize;
    for (k, br) in bins.iter().enumerate() {
        for &r in &groups[&br.bin] {
            hybrid[r] = v.stage1[r];
        }
        covered += br.rows;
        sweep.push(SweepPoint {
            coverage: covered as f64 / n as f64,
            auc: roc_auc(&hybrid, v.labels),
            accuracy: accuracy(&hybrid, v.labels),
            bins: k + 1,
        });
    }

    // Choose the largest prefix within tolerance of the pure stage-2 metric.
    let base = match metric {
        Metric::Accuracy => stage2_accuracy,
        Metric::RocAuc => stage2_auc,
    };
    let mut chosen = 0usize; // index into sweep (0 = no stage-1)
    for (i, pt) in sweep.iter().enumerate() {
        let m = match metric {
            Metric::Accuracy => pt.accuracy,
            Metric::RocAuc => pt.auc,
        };
        if base - m <= tolerance {
            chosen = i;
        }
        // Note: no break — the curve can dip then recover (paper observes
        // marginal *improvements* at small coverage on some datasets).
    }

    let stage1_bins: HashSet<u32> = bins[..chosen].iter().map(|b| b.bin).collect();
    let pt = &sweep[chosen];
    Allocation {
        stage1_bins,
        coverage: pt.coverage,
        auc: pt.auc,
        accuracy: pt.accuracy,
        stage2_auc,
        stage2_accuracy,
        bins,
        sweep,
    }
}

/// Convenience: run Algorithm 2 end-to-end for a trained LRwBins model and
/// a second-stage model on a validation dataset, and apply the route.
pub fn allocate_and_route(
    model: &mut crate::lrwbins::LrwBinsModel,
    second: &crate::gbdt::GbdtModel,
    val: &Dataset,
    metric: Metric,
    tolerance: f64,
) -> Allocation {
    let norm = model.normalizer.apply(val);
    let bin_ids = model.binner.bin_dataset(&norm);
    let stage1 = model.predict_proba(val);
    let stage2 = second.predict_proba(val);
    let alloc = allocate(
        &ValScores {
            bin_ids: &bin_ids,
            stage1: &stage1,
            stage2: &stage2,
            labels: &val.labels,
        },
        metric,
        tolerance,
    );
    model.set_route(alloc.stage1_bins.clone());
    alloc
}

/// Route at (nearest) target coverage, ignoring tolerance — used by the
/// latency benches to pin the paper's "50% of inferences" operating point.
pub fn route_at_coverage(
    model: &mut crate::lrwbins::LrwBinsModel,
    second: &crate::gbdt::GbdtModel,
    val: &Dataset,
    target: f64,
) -> Allocation {
    let norm = model.normalizer.apply(val);
    let bin_ids = model.binner.bin_dataset(&norm);
    let stage1 = model.predict_proba(val);
    let stage2 = second.predict_proba(val);
    let mut alloc = allocate(
        &ValScores {
            bin_ids: &bin_ids,
            stage1: &stage1,
            stage2: &stage2,
            labels: &val.labels,
        },
        Metric::Accuracy,
        f64::INFINITY,
    );
    // Pick the sweep prefix nearest the target coverage.
    let k = alloc
        .sweep
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            (a.coverage - target)
                .abs()
                .partial_cmp(&(b.coverage - target).abs())
                .unwrap()
        })
        .map(|(i, _)| i)
        .unwrap_or(0);
    alloc.stage1_bins = alloc.bins[..k].iter().map(|b| b.bin).collect();
    let pt = alloc.sweep[k].clone();
    alloc.coverage = pt.coverage;
    alloc.auc = pt.auc;
    alloc.accuracy = pt.accuracy;
    model.set_route(alloc.stage1_bins.clone());
    alloc
}

/// Correlation between global and bin-local feature importance (Figure 3's
/// bar colors). Pearson correlation of gain vectors.
pub fn importance_correlation(global: &[f64], local: &[f64]) -> f64 {
    assert_eq!(global.len(), local.len());
    let n = global.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mg = global.iter().sum::<f64>() / n;
    let ml = local.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vg = 0.0;
    let mut vl = 0.0;
    for (g, l) in global.iter().zip(local) {
        cov += (g - mg) * (l - ml);
        vg += (g - mg) * (g - mg);
        vl += (l - ml) * (l - ml);
    }
    if vg <= 0.0 || vl <= 0.0 {
        return 0.0;
    }
    cov / (vg.sqrt() * vl.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthetic validation world with 4 bins: in bins 0/1 both models are
    /// equally good; in bins 2/3 stage 2 is much better.
    fn make_scores(
        n_per_bin: usize,
        seed: u64,
    ) -> (Vec<u32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut bins = Vec::new();
        let mut s1 = Vec::new();
        let mut s2 = Vec::new();
        let mut y = Vec::new();
        for bin in 0..4u32 {
            for _ in 0..n_per_bin {
                let label = rng.bool(0.5) as u8 as f32;
                // stage 2: always strong.
                let p2 = if label > 0.5 {
                    0.6 + 0.39 * rng.f32()
                } else {
                    0.01 + 0.39 * rng.f32()
                };
                // stage 1: strong in bins 0/1, random in bins 2/3.
                let p1 = if bin < 2 {
                    p2.min(0.99) + 0.005 * rng.f32()
                } else {
                    rng.f32()
                };
                bins.push(bin);
                s1.push(p1);
                s2.push(p2);
                y.push(label);
            }
        }
        (bins, s1, s2, y)
    }

    #[test]
    fn picks_competitive_bins_first() {
        let (bins, s1, s2, y) = make_scores(400, 1);
        let alloc = allocate(
            &ValScores { bin_ids: &bins, stage1: &s1, stage2: &s2, labels: &y },
            Metric::Accuracy,
            0.005,
        );
        // Bins 0 and 1 should be chosen; 2 and 3 not.
        assert!(alloc.stage1_bins.contains(&0), "{:?}", alloc.stage1_bins);
        assert!(alloc.stage1_bins.contains(&1));
        assert!(!alloc.stage1_bins.contains(&2));
        assert!(!alloc.stage1_bins.contains(&3));
        assert!((alloc.coverage - 0.5).abs() < 1e-9);
        // Metric within tolerance.
        assert!(alloc.stage2_accuracy - alloc.accuracy <= 0.005 + 1e-12);
    }

    #[test]
    fn zero_tolerance_still_allows_harmless_bins() {
        let (bins, s1, s2, y) = make_scores(400, 2);
        let alloc = allocate(
            &ValScores { bin_ids: &bins, stage1: &s1, stage2: &s2, labels: &y },
            Metric::Accuracy,
            0.0,
        );
        // stage1 == stage2 in bins 0/1 ⇒ accuracy unchanged there.
        assert!(alloc.coverage >= 0.49, "coverage={}", alloc.coverage);
    }

    #[test]
    fn huge_tolerance_covers_everything() {
        let (bins, s1, s2, y) = make_scores(200, 3);
        let alloc = allocate(
            &ValScores { bin_ids: &bins, stage1: &s1, stage2: &s2, labels: &y },
            Metric::RocAuc,
            1.0,
        );
        assert!((alloc.coverage - 1.0).abs() < 1e-9);
        assert_eq!(alloc.stage1_bins.len(), 4);
    }

    #[test]
    fn sweep_monotone_coverage_and_conservation() {
        let (bins, s1, s2, y) = make_scores(150, 4);
        let alloc = allocate(
            &ValScores { bin_ids: &bins, stage1: &s1, stage2: &s2, labels: &y },
            Metric::Accuracy,
            0.01,
        );
        assert_eq!(alloc.sweep.len(), 5); // 0 + 4 bins
        for w in alloc.sweep.windows(2) {
            assert!(w[1].coverage > w[0].coverage);
        }
        assert!((alloc.sweep.last().unwrap().coverage - 1.0).abs() < 1e-9);
        // Bin rows sum to n.
        let total: usize = alloc.bins.iter().map(|b| b.rows).sum();
        assert_eq!(total, y.len());
    }

    #[test]
    fn gap_sorting_is_ascending() {
        let (bins, s1, s2, y) = make_scores(100, 5);
        let alloc = allocate(
            &ValScores { bin_ids: &bins, stage1: &s1, stage2: &s2, labels: &y },
            Metric::Accuracy,
            0.01,
        );
        for w in alloc.bins.windows(2) {
            assert!(w[0].gap <= w[1].gap);
        }
    }

    #[test]
    fn importance_correlation_bounds() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((importance_correlation(&a, &b) - 1.0).abs() < 1e-12);
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((importance_correlation(&a, &c) + 1.0).abs() < 1e-12);
        assert_eq!(importance_correlation(&[1.0], &[1.0]), 0.0);
        assert_eq!(importance_correlation(&a, &[1.0, 1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn property_coverage_increases_with_tolerance() {
        use crate::prop_assert;
        crate::util::proptest::check(25, |g| {
            let (bins, s1, s2, y) = make_scores(g.usize(50..150), g.usize(0..1000) as u64);
            let v = ValScores { bin_ids: &bins, stage1: &s1, stage2: &s2, labels: &y };
            let lo = allocate(&v, Metric::Accuracy, 0.001);
            let hi = allocate(&v, Metric::Accuracy, 0.05);
            prop_assert!(
                hi.coverage >= lo.coverage - 1e-12,
                "hi={} lo={}",
                hi.coverage,
                lo.coverage
            );
            Ok(())
        });
    }
}
