//! Engine worker: confines the (!Send) PJRT client to one dedicated thread.
//!
//! The `xla` crate's `PjRtClient` holds an `Rc` internally, so the engine
//! cannot be shared across the batcher workers directly. `EngineWorker`
//! owns the engine on its own thread and exposes a `Send + Sync` handle;
//! jobs (row batches) arrive over a channel with per-job reply channels.
//! Execution is serialized, which is what we want anyway — the CPU PJRT
//! executable is itself internally parallel.

use super::{Engine, ForestParams, Graph};
use crate::lrwbins::tables::KernelInputs;
use anyhow::Result;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Mutex;

enum Job {
    Second {
        rows: Vec<f32>,
        n: usize,
        /// Replies with `(probs, rows)` — the input buffer travels back to
        /// the caller so the request path can recycle it.
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>)>>,
    },
    First {
        rows: Vec<f32>,
        n: usize,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>)>>,
    },
    Shutdown,
}

/// Send+Sync handle to a dedicated engine thread.
pub struct EngineWorker {
    tx: Mutex<mpsc::Sender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub f_max: usize,
}

impl EngineWorker {
    /// Spawn the worker: loads artifacts and compiles on the worker thread.
    /// `forest` enables second-stage jobs; `kernel` enables first-stage.
    pub fn spawn(
        artifacts_dir: &Path,
        graphs: Vec<Graph>,
        forest: Option<ForestParams>,
        kernel: Option<KernelInputs>,
    ) -> Result<EngineWorker> {
        let dir = artifacts_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&dir, &graphs) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.shapes.f_max));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for job in rx {
                    match job {
                        Job::Second { rows, n, reply } => {
                            let forest = forest.as_ref().expect("no forest configured");
                            let out = engine.second_stage(&rows, n, forest);
                            let _ = reply.send(out.map(|probs| (probs, rows)));
                        }
                        Job::First { rows, n, reply } => {
                            let kernel = kernel.as_ref().expect("no kernel inputs configured");
                            let _ = reply.send(engine.first_stage(&rows, n, kernel));
                        }
                        Job::Shutdown => return,
                    }
                }
            })?;
        let f_max = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during load"))??;
        Ok(EngineWorker {
            tx: Mutex::new(tx),
            handle: Some(handle),
            f_max,
        })
    }

    /// Second-stage prediction over padded rows (`rows.len() == n * f_max`).
    pub fn second_stage(&self, rows: Vec<f32>, n: usize) -> Result<Vec<f32>> {
        self.second_stage_with_buf(rows, n).map(|(probs, _)| probs)
    }

    /// Like [`EngineWorker::second_stage`], but hands the row buffer back so
    /// the caller can recycle it (`PjrtBackend` keeps one staging buffer
    /// cycling through the engine thread instead of allocating per batch).
    pub fn second_stage_with_buf(&self, rows: Vec<f32>, n: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Second { rows, n, reply })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread gone"))?
    }

    /// First-stage (cross-check) prediction over padded rows.
    pub fn first_stage(&self, rows: Vec<f32>, n: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::First { rows, n, reply })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread gone"))?
    }
}

impl Drop for EngineWorker {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Job::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
