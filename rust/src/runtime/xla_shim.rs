//! Typed stub of the (unvendored) `xla` bindings' API surface.
//!
//! The PJRT engine (`runtime::engine`) compiles against the Rust XLA
//! bindings, which are not on crates.io — and a dependency with a dangling
//! `path = ...` would break `cargo metadata` for every build, so the real
//! crate cannot even be declared optionally. Before this shim existed the
//! whole `pjrt` feature was un-checkable in CI and bit-rotted silently.
//!
//! This module mirrors exactly the types and signatures `engine.rs` uses,
//! with constructors that fail fast at runtime (`PjRtClient::cpu()` returns
//! an error telling the operator to vendor the bindings), so:
//!
//! * `cargo check --features pjrt` type-checks the engine/worker/backend
//!   code on every CI run (the compile gate);
//! * a `--features pjrt` build without vendored bindings still *runs* —
//!   it just reports "xla bindings not vendored" the moment someone asks
//!   for the PJRT backend, instead of failing to build the whole crate.
//!
//! To deploy the real engine: vendor the bindings (see the `Cargo.toml`
//! header comment), add `xla = { path = "<vendored-xla-rs>" }` to
//! `[dependencies]`, and delete the `use super::xla_shim as xla;` line in
//! `engine.rs` — its `xla::` paths then resolve to the real crate.
//! Everything else is written against the real API and compiles unchanged.

use anyhow::{bail, Result};

fn not_vendored<T>() -> Result<T> {
    bail!(
        "xla bindings not vendored: this build's `pjrt` feature compiled \
         against the typed stub (runtime/xla_shim.rs); vendor the XLA \
         bindings per the Cargo.toml header to run the PJRT engine"
    )
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        not_vendored()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        not_vendored()
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// The type parameter mirrors the real API's argument-literal generic.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        not_vendored()
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        not_vendored()
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        not_vendored()
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        not_vendored()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        not_vendored()
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        not_vendored()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        not_vendored()
    }
}
