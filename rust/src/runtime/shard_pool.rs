//! Shard-per-core serving engine: a persistent worker pool with per-shard
//! [`FlatForest`] replicas, per-shard task rings, and **work-stealing**.
//!
//! # Why
//!
//! The paper's end-to-end win (1.3× latency, 30% CPU) depends on the ML
//! back-end saturating its cores without per-request thread churn. The old
//! `NativeBackend` spun up scoped threads per big batch and tore them down
//! again — fine for benches, but every batch paid thread spawn/join and the
//! OS scheduler had no warm affinity to exploit. This engine keeps one
//! long-lived worker per shard (core), in the spirit of provisioned
//! pipeline workers (InferLine) and database-style decision-forest serving
//! engines. Work-stealing attacks the **tail**: without it, a straggler
//! shard gates an entire block while its neighbors park idle.
//!
//! # Architecture
//!
//! * **Shards** — `n_shards` worker threads, spawned once. Each worker owns
//!   a private deep **replica** of every forest it has served, carrying the
//!   model **version stamp** it was built from. Replicas are pre-built off
//!   the hot path at [`ShardPool::register`]/[`ShardPool::swap`] time (one
//!   clone per shard, waiting in the registry) and installed by the worker
//!   on first touch of a version — the serve loop never pays a deep clone
//!   unless racing swaps exhausted the prepared set (counted as
//!   `replica_builds`). Replicas are the SoA [`FlatForest`] arenas, so each
//!   shard's lane-tiled walk streams only the node fields it touches; a
//!   private [`ForestScratch`] completes the no-shared-mutable-state hot
//!   loop. With [`ShardPoolConfig::pin_threads`] each worker
//!   additionally pins itself to core `shard % online` at startup
//!   (`sched_setaffinity` on Linux, no-op elsewhere), keeping replica cache
//!   residency and the OS scheduler out of each other's way;
//!   [`crate::telemetry::ShardStats`] records the CPU id each worker landed
//!   on (or the failure, in restricted cpusets).
//! * **Rings** — one bounded MPMC ring (Vyukov sequence-counter design) per
//!   shard: push and pop are single-CAS lock-free operations. MPMC matters:
//!   a steal is just a `try_pop` on a neighbor's ring, no separate deque
//!   protocol needed. Idle workers spin briefly then park on a shared
//!   condvar that the submit path only touches when sleepers exist.
//! * **Submission** — [`ShardPool::predict_spans`] splits a flat row batch
//!   into sub-range tasks and round-robins them across the shard rings.
//!   **Adaptive granularity**: when live [`ShardStats`] occupancy shows the
//!   pool idle (balance), the batch splits into at most one task per shard
//!   — minimal hand-off, steals rare; when shards are busy (skew), it
//!   splits up to [`STEAL_GRAIN`]× finer so a steal moves a small unit
//!   cheaply. The submitter blocks on a per-batch completion latch that
//!   counts **rows** (not tasks — so splitting a task in flight needs no
//!   latch surgery). Tasks borrow the caller's buffers via raw pointers —
//!   sound because the call cannot return before the latch opens.
//! * **Work-stealing** — a worker whose own ring is empty scans its
//!   neighbors' rings (nearest first) and steals a queued task. A stolen
//!   task spanning ≥ 2×`min_task_rows` is **split**: the thief keeps the
//!   back half and requeues the front half on the victim's ring — half the
//!   remaining span per steal, so recursive halving spreads a hot shard's
//!   backlog across every idle neighbor in O(log) steals while the victim
//!   keeps the rows nearest its cursor. The row-counting latch makes the
//!   split trivially sound; [`ShardStats`] counts steals per thief and
//!   splits globally.
//! * **Backpressure** — rings are bounded; a submitter that finds the home
//!   ring full tries every other ring once, then runs the task **inline**
//!   on its own thread (serving from the shared registry image) instead of
//!   blocking the request path behind a wedged queue.
//! * **Streaming** — [`ShardPool::predict_spans_streamed`] additionally
//!   delivers every completed sub-range to a caller sink *as it finishes*,
//!   from the worker that finished it. This is what the RPC server's
//!   streamed `CHUNK` responses hang off: a block's rows leave the process
//!   the moment their shard is done, not when the slowest shard is.
//! * **Poison tolerance** — a panicking shard (a model bug on a poison row)
//!   is contained to its task: the unwind is caught, the task's row span is
//!   reported as failed (to the sink too, mid-stream), the completion latch
//!   still opens, and the worker keeps serving. The engine never wedges and
//!   never loses a batch.
//! * **Deadline shedding** — [`ShardPool::predict_spans_deadline`] attaches
//!   a shed horizon to a batch's tasks: a sub-range still queued once it
//!   passes completes as a *failed span* (counted in
//!   [`ShardStats::deadline_shed`](crate::telemetry::ShardStats)) instead
//!   of executing for a caller that stopped waiting. Running tasks are
//!   never interrupted — rows are always fully computed or reported
//!   failed, never partial.
//! * **Multi-tenancy** — [`ShardPool::register`] adds models while the pool
//!   is live; several `Coordinator`s (tenants) can share one pool, each
//!   falling back to its own registered forest (the embedded multi-tenant
//!   mode — see the crate docs).
//! * **Live hot-swap** — [`ShardPool::swap`] replaces a registered model's
//!   forest under traffic: the registry `Arc` flips between batches and the
//!   model's version bumps. Every span is **stamped** with the version
//!   current at submit, so one batch is served entirely by one version —
//!   bit-stable even with a swap racing the batch. Workers re-materialize
//!   their replica on stamp mismatch (from the pre-built clones, off the
//!   hot path) and **evict** the drained old version. A **two-version
//!   window** keeps the previous forest resolvable while its in-flight
//!   spans drain — and exposes it for shadow scoring
//!   ([`ShardPool::shadow`]). A span whose version left the window (two
//!   swaps raced it) completes as a failed span (`stale_spans`), never
//!   wrong-version bits.
//! * **Guarded rollout hooks** — a candidate forest can be **staged**
//!   ([`ShardPool::stage`]) next to the incumbent: it gets its own version
//!   stamp (allocated from the same per-model clock as swaps, so a racing
//!   swap can never collide with it), pre-built per-shard replicas, and is
//!   resolvable/servable — canary batches stamp it explicitly via
//!   [`ShardPool::predict_spans_version`] — without ever being the default
//!   for new batches. [`ShardPool::promote`] atomically makes the staged
//!   version current (the incumbent slides into the two-version window);
//!   [`ShardPool::unstage`] discards it. [`ShardPool::pin_version`] takes a
//!   refcounted **lease** on any resolvable version so rollout comparisons
//!   survive racing swaps (without it, a second swap mid-comparison evicts
//!   the window and the comparison dies as `stale_spans`). **Shadow
//!   scoring** ([`ShardPool::submit_shadow`]) runs candidate re-scores on a
//!   bounded lowest-priority queue: workers take shadow jobs only when
//!   every task ring is empty, a full queue or an expired shadow deadline
//!   sheds the job immediately, and every outcome — scored, shed, or a
//!   contained candidate panic — is delivered to the job's callback, so
//!   rollout accounting reconciles exactly while live traffic never queues
//!   behind comparison work.
//!
//! Outputs are bit-identical to the scalar and block paths: replicas are
//! value-clones of the registered [`FlatForest`], and
//! [`FlatForest::predict_flat_rows`] over a sub-range computes exactly what
//! the single-threaded call would — however the spans end up split or
//! stolen.

use crate::gbdt::{FlatForest, ForestScratch};
use crate::telemetry::ShardStats;
use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::mem::MaybeUninit;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError, RwLock};
use std::time::{Duration, Instant};

/// Handle to a forest registered in a [`ShardPool`] (multi-tenant: each
/// tenant registers its own model and keeps its id).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ModelId(u32);

/// When live occupancy shows skew (busy shards at submit time), a batch is
/// split up to this many times finer than one-task-per-shard, so steals
/// move small units cheaply.
pub const STEAL_GRAIN: usize = 4;

/// Completion sink for streamed prediction: called once per finished
/// sub-range — from the worker thread that finished it — with the span
/// (absolute row indices within the batch), its probabilities (empty when
/// failed), and the failed flag. Spans are disjoint and tile the batch.
pub type SpanSink<'a> = &'a (dyn Fn(Range<usize>, &[f32], bool) + Sync);

/// What happened to a [`ShadowJob`] — delivered to its callback exactly
/// once, whichever way the job ends.
#[derive(Debug)]
pub enum ShadowOutcome {
    /// Candidate scores for every row of the job, in row order.
    Scored(Vec<f32>),
    /// Shed before execution: queue full at submit, deadline expired,
    /// version no longer resolvable, or pool shutdown. Counted in
    /// [`ShardStats::shadow_shed`](crate::telemetry::ShardStats).
    Shed,
    /// The candidate panicked while scoring (contained to the job). For a
    /// rollout this is maximal divergence — an immediate guard trip.
    Failed,
}

/// One shadow-scoring unit for a guarded rollout: an OWNED copy of the
/// sampled rows, the candidate version to score them on, and a callback
/// that receives the outcome. Owned payload (unlike [`Task`]'s borrowed
/// pointers) because nobody blocks on shadow work — the submitter returns
/// to serving immediately and the comparison completes whenever an idle
/// worker gets to it.
///
/// Delivery is guaranteed: if the job is dropped without executing (queue
/// teardown, shed on submit), `Drop` delivers [`ShadowOutcome::Shed`] to
/// the callback — rollout accounting never loses a sampled row.
pub struct ShadowJob {
    pub model: ModelId,
    /// Version to score on (the rollout's staged candidate, held
    /// resolvable by a [`VersionLease`]).
    pub version: u32,
    /// Flat row-major payload, `rows.len() / row_len` rows.
    pub rows: Vec<f32>,
    pub row_len: usize,
    /// Shed horizon: a job still queued past this instant is shed, not
    /// scored — a comparison nobody will read must not occupy a worker.
    pub deadline: Option<Instant>,
    done: Option<Box<dyn FnOnce(ShadowOutcome) + Send>>,
}

impl ShadowJob {
    pub fn new(
        model: ModelId,
        version: u32,
        rows: Vec<f32>,
        row_len: usize,
        deadline: Option<Instant>,
        done: impl FnOnce(ShadowOutcome) + Send + 'static,
    ) -> ShadowJob {
        ShadowJob {
            model,
            version,
            rows,
            row_len,
            deadline,
            done: Some(Box::new(done)),
        }
    }

    /// Rows carried by this job.
    pub fn n_rows(&self) -> usize {
        if self.row_len == 0 {
            0
        } else {
            self.rows.len() / self.row_len
        }
    }

    /// Deliver the outcome to the callback, containing a panicking callback
    /// like a panicking model (a rollout monitor bug must not kill a
    /// worker). Consumes the job; `Drop` then sees the callback gone.
    fn deliver(mut self, outcome: ShadowOutcome) -> bool {
        match self.done.take() {
            Some(f) => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(outcome))).is_ok(),
            None => true,
        }
    }
}

impl Drop for ShadowJob {
    fn drop(&mut self) {
        if let Some(f) = self.done.take() {
            // Last-resort delivery for jobs that never executed. Panic
            // containment as in `deliver`; the outcome is lost but the
            // thread survives.
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(ShadowOutcome::Shed)));
        }
    }
}

/// Pool construction knobs.
#[derive(Clone, Debug)]
pub struct ShardPoolConfig {
    /// Worker threads (shards). Default: one per core (capped like
    /// [`crate::util::threadpool::default_threads`]).
    pub n_shards: usize,
    /// Per-shard task-ring capacity (rounded up to a power of two). When
    /// every ring is full, submitters run tasks inline rather than block.
    pub queue_capacity: usize,
    /// Minimum rows per task: below this, splitting a batch across shards
    /// (or splitting a stolen task in half) costs more in hand-off than the
    /// parallel traversal wins.
    pub min_task_rows: usize,
    /// Work-stealing between shards (on by default; the off switch exists
    /// for A/B benchmarking — `steal_skew` in `hotpath_microbench`).
    pub steal: bool,
    /// Pin each shard's worker thread to a CPU core (`sched_setaffinity`
    /// on Linux; a no-op elsewhere). Off by default: pinning wins when the
    /// pool owns the machine (one shard per core, stable cache residency
    /// for the per-shard replicas) and hurts when it shares it — so it is
    /// an explicit deployment decision, not a default.
    /// [`ShardStats::pinned_cpu`](crate::telemetry::ShardStats::pinned_cpu)
    /// reports the CPU each worker landed on.
    pub pin_threads: bool,
    /// Bound on the lowest-priority shadow-scoring queue (guarded rollout,
    /// [`ShardPool::submit_shadow`]). A full queue sheds the submitted job
    /// immediately — shadow work must never build a standing backlog.
    pub shadow_queue_capacity: usize,
}

impl Default for ShardPoolConfig {
    fn default() -> Self {
        ShardPoolConfig {
            n_shards: crate::util::threadpool::default_threads(),
            queue_capacity: 1024,
            min_task_rows: 64,
            steal: true,
            pin_threads: false,
            shadow_queue_capacity: 256,
        }
    }
}

/// One unit of shard work: score `n` rows of a flat row-major buffer into a
/// disjoint output sub-slice, then hit the batch's completion latch.
///
/// Raw pointers, not borrows: tasks outlive the submitting stack frame only
/// until the latch opens, and the submitter blocks on the latch before
/// returning — see the safety argument on [`ShardPool::predict_spans`].
/// `Copy` so a thief can split a task into two window views of the same
/// buffers.
#[derive(Clone, Copy)]
struct Task {
    model: u32,
    /// Model version current when the batch was submitted: every task of a
    /// batch carries the same stamp, so the whole batch is served by ONE
    /// version regardless of swaps racing it.
    version: u32,
    rows: *const f32,
    rows_len: usize,
    row_len: usize,
    n: usize,
    out: *mut f32,
    /// Row offset of this task inside the parent batch (failure reporting
    /// and streamed-span addressing).
    span_start: usize,
    /// Shed horizon: a task still unstarted past this instant completes as
    /// a failed span instead of executing (nobody is waiting for the
    /// answer any more). `None` = run unconditionally.
    deadline: Option<Instant>,
    batch: *const BatchLatch,
}

// SAFETY: the pointers target buffers owned by a submitter that cannot
// return before this task completes (completion latch), and each task's
// output range is disjoint — splits partition a range, never duplicate it.
unsafe impl Send for Task {}

/// Per-batch completion latch: workers count down `rows_remaining` by the
/// row count of each finished sub-range; the decrement that reaches zero
/// opens the latch. Counting rows (not tasks) is what lets a thief split a
/// task in flight without telling the latch anything.
struct BatchLatch {
    rows_remaining: AtomicUsize,
    /// Failed row spans (a panicking shard reports its sub-range here).
    failed: Mutex<Vec<Range<usize>>>,
    done: Mutex<bool>,
    cv: Condvar,
    /// Streamed-completion sink (None on the plain path). Raw pointer with
    /// the same lifetime argument as the task pointers: the submitter's
    /// sink outlives the latch wait.
    sink: Option<*const (dyn Fn(Range<usize>, &[f32], bool) + Sync)>,
}

impl BatchLatch {
    fn new(rows: usize, sink: Option<SpanSink<'_>>) -> BatchLatch {
        BatchLatch {
            rows_remaining: AtomicUsize::new(rows),
            failed: Mutex::new(Vec::new()),
            done: Mutex::new(false),
            cv: Condvar::new(),
            sink: sink.map(|s| s as *const (dyn Fn(Range<usize>, &[f32], bool) + Sync)),
        }
    }

    /// Record a sub-range completion; the decrement reaching zero opens the
    /// latch. Nothing may touch the latch after the open (the submitter's
    /// stack frame is free to die), so the failure span goes in first.
    fn complete(&self, span: Range<usize>, failed: bool) {
        if failed {
            self.failed
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(span.clone());
        }
        let len = span.len();
        if self.rows_remaining.fetch_sub(len, Ordering::AcqRel) == len {
            let mut done = self.done.lock().unwrap_or_else(PoisonError::into_inner);
            *done = true;
            self.cv.notify_all();
        }
    }

    /// Block until every row completed; returns the failed spans (sorted).
    fn wait(&self) -> Vec<Range<usize>> {
        let mut done = self.done.lock().unwrap_or_else(PoisonError::into_inner);
        while !*done {
            done = self
                .cv
                .wait(done)
                .unwrap_or_else(PoisonError::into_inner);
        }
        drop(done);
        let mut failed = std::mem::take(
            &mut *self.failed.lock().unwrap_or_else(PoisonError::into_inner),
        );
        failed.sort_by_key(|r| r.start);
        failed
    }
}

/// One ring slot: `seq` is the Vyukov sequence counter that encodes whether
/// the slot is free for the producer (`seq == pos`) or holds a value for
/// the consumer (`seq == pos + 1`).
struct Slot {
    seq: AtomicUsize,
    task: UnsafeCell<MaybeUninit<Task>>,
}

/// Bounded lock-free MPMC task ring (Vyukov design). One per shard; "MPMC"
/// is load-bearing — any worker may pop any ring, which is exactly what a
/// steal is. Parking lives in [`Parker`], shared across rings.
struct TaskQueue {
    slots: Box<[Slot]>,
    mask: usize,
    /// Consumer cursor.
    head: AtomicUsize,
    /// Producer cursor.
    tail: AtomicUsize,
}

// SAFETY: slot payloads are published/claimed through the `seq` acquire/
// release protocol; a slot's UnsafeCell is only touched by the single
// producer or consumer that won the corresponding CAS.
unsafe impl Sync for TaskQueue {}
unsafe impl Send for TaskQueue {}

impl TaskQueue {
    fn new(capacity: usize) -> TaskQueue {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[Slot]> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                task: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        TaskQueue {
            slots,
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Lock-free bounded push. `Err(task)` hands the task back on a full
    /// ring (the caller tries another ring or runs it inline — back-
    /// pressure, not blocking).
    fn push(&self, task: Task) -> Result<(), Task> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS claimed slot `pos` exclusively for
                        // this producer; consumers wait for the seq store.
                        unsafe { (*slot.task.get()).write(task) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return Err(task); // full
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Lock-free pop; `None` when empty. Called by the ring's home worker
    /// and by thieves alike.
    fn try_pop(&self) -> Option<Task> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - (pos + 1) as isize;
            if dif == 0 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS claimed slot `pos` exclusively for
                        // this consumer; the producer's Release store made
                        // the payload visible.
                        let task = unsafe { (*slot.task.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(task);
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return None; // empty
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }

    /// Tasks currently queued (approximate — racy by nature, telemetry
    /// only).
    fn depth(&self) -> usize {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Relaxed);
        tail.saturating_sub(head)
    }
}

/// Shared idle-worker parking: one condvar for the whole pool. The data
/// path (ring push/pop) takes no lock; the park/wake path touches the
/// mutex only when a worker is actually asleep.
struct Parker {
    /// Workers currently parked (read/written around SeqCst fences — see
    /// `wake_for_push` for the handshake).
    sleepers: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

impl Parker {
    fn new() -> Parker {
        Parker {
            sleepers: AtomicUsize::new(0),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    fn wake_all(&self) {
        let _g = self.lock.lock().unwrap_or_else(PoisonError::into_inner);
        self.cv.notify_all();
    }
}

/// One registered model: the current forest (version-stamped), the
/// drained-but-still-resolvable previous version (the **two-version
/// window** — in-flight spans stamped with it keep serving, and it doubles
/// as the shadow-scoring hook, [`ShardPool::shadow`]), and the per-shard
/// pre-built replica clones workers install on first touch of a version.
struct ModelEntry {
    /// Version currently serving (the stamp new batches get). Starts at 1
    /// on register.
    version: u32,
    /// Highest version number ever allocated for this model. Swaps AND
    /// staged candidates both allocate from this clock, so a swap racing a
    /// stage can never hand two forests the same stamp.
    vclock: u32,
    cur: Arc<FlatForest>,
    prev: Option<(u32, Arc<FlatForest>)>,
    /// Rollout candidate staged next to the incumbent: resolvable and
    /// servable (canary batches stamp its version explicitly) but never
    /// the default for new batches until [`ShardPool::promote`].
    staged: Option<(u32, Arc<FlatForest>)>,
    /// Refcounted version leases (`(version, forest, count)`): a pinned
    /// version stays resolvable regardless of how many swaps race it —
    /// the fix for a second swap evicting the two-version window out from
    /// under an in-flight shadow comparison (`stale_spans`).
    pins: Vec<(u32, Arc<FlatForest>, usize)>,
    /// One slot per shard, `Some((version, replica))` until that shard
    /// takes it. Per-slot mutexes (not the registry write lock): workers
    /// take their slot under the registry READ lock, so an install never
    /// contends with submitters.
    prepared: Box<[Mutex<Option<(u32, FlatForest)>>]>,
    /// Pre-built replicas for the STAGED candidate (same protocol), so a
    /// canary batch's first touch of the candidate version doesn't deep-
    /// clone on a serving shard. Moves into `prepared` on promote.
    staged_prepared: Box<[Mutex<Option<(u32, FlatForest)>>]>,
}

impl ModelEntry {
    /// Resolve this model at exactly `version`: current, the two-version
    /// window, the staged candidate, or a pinned lease — in that order.
    fn resolve(&self, version: u32) -> Option<Arc<FlatForest>> {
        if self.version == version {
            return Some(self.cur.clone());
        }
        if let Some((v, f)) = &self.prev {
            if *v == version {
                return Some(f.clone());
            }
        }
        if let Some((v, f)) = &self.staged {
            if *v == version {
                return Some(f.clone());
            }
        }
        self.pins
            .iter()
            .find(|(v, _, _)| *v == version)
            .map(|(_, f, _)| f.clone())
    }
}

/// State shared between the pool handle and its workers.
struct PoolShared {
    /// One task ring per shard.
    rings: Box<[TaskQueue]>,
    parker: Parker,
    /// Registered models, indexed by [`ModelId`]. Workers read-lock once
    /// per (shard, model, version) to install their replica, never in the
    /// steady state.
    registry: RwLock<Vec<ModelEntry>>,
    shutdown: AtomicBool,
    stats: ShardStats,
    /// Task-granularity floor. Atomic so the SLO controller can retune
    /// steal granularity on a live pool (larger = fewer, coarser tasks).
    min_task_rows: AtomicUsize,
    /// Shards eligible for NEW task placement and for stealing. Workers
    /// past this index still drain their own rings (nothing strands on a
    /// shrink) but receive no new work and steal none — they park, and the
    /// pool's CPU footprint follows. Clamped to `1..=n_shards`.
    active_shards: AtomicUsize,
    steal: bool,
    pin_threads: bool,
    /// Round-robin base for home-shard assignment across batches.
    rr: AtomicUsize,
    /// Lowest-priority shadow-scoring queue (guarded rollout): bounded,
    /// popped by workers ONLY when every task ring is empty. A plain mutex
    /// is fine — this queue is off the hot path by construction.
    shadow: Mutex<VecDeque<ShadowJob>>,
    shadow_cap: usize,
}

impl PoolShared {
    /// Shards currently eligible for new-task placement and stealing.
    fn active(&self) -> usize {
        self.active_shards
            .load(Ordering::Relaxed)
            .clamp(1, self.rings.len())
    }

    /// Live task-granularity floor.
    fn min_rows(&self) -> usize {
        self.min_task_rows.load(Ordering::Relaxed).max(1)
    }

    /// Version currently serving `model` (the stamp new batches get).
    fn cur_version(&self, model: u32) -> u32 {
        self.registry.read().unwrap_or_else(PoisonError::into_inner)[model as usize].version
    }

    /// Resolve `model` at exactly `version` — the current forest, the
    /// two-version window, the staged rollout candidate, or a pinned
    /// lease. `None` means the version is gone (swapped out of the window,
    /// unpinned): the span fails rather than serve wrong-version bits.
    fn forest_version(&self, model: u32, version: u32) -> Option<Arc<FlatForest>> {
        let reg = self.registry.read().unwrap_or_else(PoisonError::into_inner);
        reg.get(model as usize)?.resolve(version)
    }

    /// Take the pre-built replica waiting for (`model`, `shard`) if its
    /// stamp matches `version` — the current set or the staged candidate's.
    /// Registry read lock + the slot's own mutex — never the write lock, so
    /// installs don't contend with submitters.
    fn take_prepared(&self, model: u32, shard: usize, version: u32) -> Option<FlatForest> {
        let reg = self.registry.read().unwrap_or_else(PoisonError::into_inner);
        let e = reg.get(model as usize)?;
        for set in [&e.prepared, &e.staged_prepared] {
            let Some(slot) = set.get(shard) else { continue };
            let mut slot = slot.lock().unwrap_or_else(PoisonError::into_inner);
            if matches!(&*slot, Some((v, _)) if *v == version) {
                return slot.take().map(|(_, f)| f);
            }
        }
        None
    }

    /// Pop the oldest queued shadow job (called by a worker whose rings
    /// are all empty — shadow work is strictly lower priority).
    fn pop_shadow(&self) -> Option<ShadowJob> {
        self.shadow
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .pop_front()
    }

    /// Shed every queued shadow job (shutdown drain). Jobs are collected
    /// under the lock but dropped OUTSIDE it — `Drop` delivers `Shed` to
    /// arbitrary rollout callbacks, which must not run under the queue
    /// mutex.
    fn drain_shadow(&self) {
        let jobs: Vec<ShadowJob> = self
            .shadow
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .drain(..)
            .collect();
        if !jobs.is_empty() {
            self.stats
                .shadow_shed
                .fetch_add(jobs.len() as u64, Ordering::Relaxed);
        }
        drop(jobs);
    }

    fn queue_depth_total(&self) -> usize {
        self.rings.iter().map(TaskQueue::depth).sum()
    }

    /// Wake after a ring push. Eventcount handshake (store-buffering/Dekker
    /// shape): the caller published the task (`seq` Release store), then
    /// fences SeqCst and loads `sleepers`; the sleeper increments
    /// `sleepers`, fences SeqCst, then re-checks the rings. The two SeqCst
    /// fences order the sides so that either this load observes the sleeper
    /// (and we notify under the park lock), or the sleeper's re-check
    /// observes the published task. The long timed wait in `acquire` is a
    /// belt-and-braces backstop, not a correctness requirement.
    ///
    /// With stealing on, ANY woken worker can serve the task (its re-check
    /// scans every ring), so one wakeup suffices; with stealing off only
    /// the home shard can, and a misdirected single wakeup would leave the
    /// task to the timeout backstop — so wake everyone.
    fn wake_for_push(&self) {
        std::sync::atomic::fence(Ordering::SeqCst);
        if self.parker.sleepers.load(Ordering::Relaxed) > 0 {
            let _g = self
                .parker
                .lock
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            // One wakeup only suffices if ANY woken worker can serve the
            // task. A shrunk pool breaks that (a deactivated worker wakes,
            // finds nothing it may take, re-parks), so wake everyone then.
            if self.steal && self.active() == self.rings.len() {
                self.parker.cv.notify_one();
            } else {
                self.parker.cv.notify_all();
            }
        }
    }
}

/// The persistent shard-per-core serving engine. See the module docs.
pub struct ShardPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_shards: usize,
}

impl ShardPool {
    /// Spawn the pool (empty registry) with default configuration.
    pub fn new(n_shards: usize) -> ShardPool {
        ShardPool::with_config(ShardPoolConfig {
            n_shards,
            ..Default::default()
        })
    }

    pub fn with_config(cfg: ShardPoolConfig) -> ShardPool {
        let n_shards = cfg.n_shards.max(1);
        let shared = Arc::new(PoolShared {
            rings: (0..n_shards)
                .map(|_| TaskQueue::new(cfg.queue_capacity))
                .collect(),
            parker: Parker::new(),
            registry: RwLock::new(Vec::new()),
            shutdown: AtomicBool::new(false),
            stats: ShardStats::new(n_shards),
            min_task_rows: AtomicUsize::new(cfg.min_task_rows.max(1)),
            active_shards: AtomicUsize::new(n_shards),
            steal: cfg.steal,
            pin_threads: cfg.pin_threads,
            rr: AtomicUsize::new(0),
            shadow: Mutex::new(VecDeque::new()),
            shadow_cap: cfg.shadow_queue_capacity.max(1),
        });
        let workers = (0..n_shards)
            .map(|shard| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("shard-{shard}"))
                    .spawn(move || worker_loop(shard, shared))
                    .expect("spawn shard worker")
            })
            .collect();
        ShardPool {
            shared,
            workers,
            n_shards,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The live task-granularity floor (sub-batch splits and steal-splits
    /// never go below it).
    pub fn min_task_rows(&self) -> usize {
        self.shared.min_rows()
    }

    /// Retune the task-granularity floor on a live pool (SLO-controller
    /// knob): coarser tasks cut scheduling overhead when the pool is
    /// keeping up, finer tasks spread a backlog faster. Clamped to ≥ 1;
    /// takes effect for the next batch and the next steal-split.
    pub fn set_min_task_rows(&self, rows: usize) {
        self.shared
            .min_task_rows
            .store(rows.max(1), Ordering::Relaxed);
    }

    /// Shards currently eligible for new work (≤ [`ShardPool::n_shards`]).
    pub fn active_shards(&self) -> usize {
        self.shared.active()
    }

    /// Shrink or re-grow the pool's working set without tearing down
    /// threads (SLO-controller knob): new batches place tasks on shards
    /// `0..n` only, and workers past `n` stop stealing and park. Queued
    /// work on deactivated rings still drains (the owner always serves its
    /// own ring), so a shrink never strands or reorders submitted spans.
    /// Clamped to `1..=n_shards`.
    pub fn set_active_shards(&self, n: usize) {
        let n = n.clamp(1, self.n_shards);
        self.shared.active_shards.store(n, Ordering::Relaxed);
    }

    /// Per-shard occupancy / steal / queue-depth telemetry.
    pub fn stats(&self) -> &ShardStats {
        &self.shared.stats
    }

    /// Tasks currently queued across all rings (telemetry gauge).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue_depth_total()
    }

    /// Deep-clone one replica per shard, stamped `version` — counted and
    /// timed in [`ShardStats`]. Called OUTSIDE any registry lock: building
    /// n_shards clones must never stall submitters or serving workers.
    fn prepare_replicas(
        &self,
        forest: &FlatForest,
        version: u32,
    ) -> Box<[Mutex<Option<(u32, FlatForest)>>]> {
        let stats = &self.shared.stats;
        (0..self.n_shards)
            .map(|_| {
                let t0 = Instant::now();
                let replica = forest.clone();
                stats.replica_builds.fetch_add(1, Ordering::Relaxed);
                stats.replica_build.record_duration(t0.elapsed());
                Mutex::new(Some((version, replica)))
            })
            .collect()
    }

    /// Register a forest; tenants keep the returned id. Safe while the pool
    /// is serving. Per-shard replicas are pre-built HERE, off the hot path,
    /// so the first task for the new model never pays a deep clone on a
    /// serving shard.
    pub fn register(&self, forest: FlatForest) -> ModelId {
        let version = 1u32;
        let prepared = self.prepare_replicas(&forest, version);
        let mut reg = self
            .shared
            .registry
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        let id = reg.len() as u32;
        reg.push(ModelEntry {
            version,
            vclock: version,
            cur: Arc::new(forest),
            prev: None,
            staged: None,
            pins: Vec::new(),
            prepared,
            staged_prepared: Box::default(),
        });
        ModelId(id)
    }

    /// Replace a registered model's forest under traffic. The registry
    /// `Arc` flips between batches: batches submitted before the flip keep
    /// serving the old version (their spans are stamped; the two-version
    /// window keeps it resolvable while they drain), batches after it serve
    /// the new one — no failed requests, no mixed-version batch. Returns
    /// the new version.
    ///
    /// Per-shard replicas for the new version are deep-cloned BEFORE taking
    /// the write lock, so a swap never stalls submitters behind `n_shards`
    /// clones, and workers install the new version from the prepared set
    /// instead of cloning on the serve path.
    pub fn swap(&self, model: ModelId, forest: FlatForest) -> Result<u32, String> {
        {
            let reg = self
                .shared
                .registry
                .read()
                .unwrap_or_else(PoisonError::into_inner);
            let e = reg
                .get(model.0 as usize)
                .ok_or_else(|| format!("swap: unknown model id {}", model.0))?;
            if forest.n_features != e.cur.n_features {
                return Err(format!(
                    "swap: model {} serves {} features, replacement has {}",
                    model.0, e.cur.n_features, forest.n_features
                ));
            }
        }
        let prepared = self.prepare_replicas(&forest, 0); // stamped under the lock below
        let mut reg = self
            .shared
            .registry
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        let e = &mut reg[model.0 as usize];
        // Version is assigned under the write lock (racing swaps serialize
        // here) from the per-model clock — shared with `stage`, so a swap
        // can never collide with a staged candidate's stamp. The prepared
        // clones built outside the lock are re-stamped to whatever version
        // this swap actually got.
        let new_version = e.vclock.wrapping_add(1);
        e.vclock = new_version;
        for slot in prepared.iter() {
            if let Some((v, _)) = slot
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .as_mut()
            {
                *v = new_version;
            }
        }
        e.prev = Some((e.version, std::mem::replace(&mut e.cur, Arc::new(forest))));
        e.version = new_version;
        e.prepared = prepared;
        self.shared.stats.model_swaps.fetch_add(1, Ordering::Relaxed);
        Ok(new_version)
    }

    /// The version currently serving `model` (bumped by every
    /// [`ShardPool::swap`]; 1 after register).
    pub fn version(&self, model: ModelId) -> u32 {
        self.shared.cur_version(model.0)
    }

    /// The previous version still inside the two-version window, if any —
    /// the shadow-scoring hook: score a sample of traffic against it and
    /// compare before retiring it for good (the next swap evicts it).
    /// Unprotected — take a [`ShardPool::pin_version`] lease to keep the
    /// comparison target alive across further swaps.
    pub fn shadow(&self, model: ModelId) -> Option<(u32, Arc<FlatForest>)> {
        self.shared
            .registry
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .get(model.0 as usize)?
            .prev
            .clone()
    }

    /// Stage a rollout candidate next to `model`'s incumbent: the forest
    /// gets a fresh version stamp (from the same per-model clock as swaps)
    /// and pre-built per-shard replicas, becomes resolvable — canary
    /// batches serve it via [`ShardPool::predict_spans_version`] — but is
    /// NOT the default for new batches until [`ShardPool::promote`].
    /// Re-staging replaces a previously staged candidate. Returns the
    /// candidate's version.
    pub fn stage(&self, model: ModelId, forest: FlatForest) -> Result<u32, String> {
        {
            let reg = self
                .shared
                .registry
                .read()
                .unwrap_or_else(PoisonError::into_inner);
            let e = reg
                .get(model.0 as usize)
                .ok_or_else(|| format!("stage: unknown model id {}", model.0))?;
            if forest.n_features != e.cur.n_features {
                return Err(format!(
                    "stage: model {} serves {} features, candidate has {}",
                    model.0, e.cur.n_features, forest.n_features
                ));
            }
        }
        // Replicas deep-cloned OUTSIDE the locks (like `swap`), re-stamped
        // once the version is allocated under the write lock.
        let prepared = self.prepare_replicas(&forest, 0);
        let mut reg = self
            .shared
            .registry
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        let e = &mut reg[model.0 as usize];
        let version = e.vclock.wrapping_add(1);
        e.vclock = version;
        for slot in prepared.iter() {
            if let Some((v, _)) = slot
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .as_mut()
            {
                *v = version;
            }
        }
        e.staged = Some((version, Arc::new(forest)));
        e.staged_prepared = prepared;
        Ok(version)
    }

    /// Promote the staged candidate: it becomes the current version (new
    /// batches stamp it), the incumbent slides into the two-version window
    /// so its in-flight spans drain, and the candidate's pre-built
    /// replicas become the live prepared set. Counted as a `model_swaps`
    /// lifecycle event. Returns the promoted version.
    pub fn promote(&self, model: ModelId) -> Result<u32, String> {
        let mut reg = self
            .shared
            .registry
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        let e = reg
            .get_mut(model.0 as usize)
            .ok_or_else(|| format!("promote: unknown model id {}", model.0))?;
        let (version, forest) = e
            .staged
            .take()
            .ok_or_else(|| format!("promote: model {} has no staged candidate", model.0))?;
        e.prev = Some((e.version, std::mem::replace(&mut e.cur, forest)));
        e.version = version;
        e.prepared = std::mem::take(&mut e.staged_prepared);
        self.shared.stats.model_swaps.fetch_add(1, Ordering::Relaxed);
        Ok(version)
    }

    /// Discard the staged candidate (rollback). In-flight canary batches
    /// stamped with it keep resolving only while a [`VersionLease`] pins
    /// it — which is exactly what a rollout holds. Returns the discarded
    /// version, `None` when nothing was staged.
    pub fn unstage(&self, model: ModelId) -> Option<u32> {
        let mut reg = self
            .shared
            .registry
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        let e = reg.get_mut(model.0 as usize)?;
        e.staged_prepared = Box::default();
        e.staged.take().map(|(v, _)| v)
    }

    /// The staged rollout candidate, if any.
    pub fn staged(&self, model: ModelId) -> Option<(u32, Arc<FlatForest>)> {
        self.shared
            .registry
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .get(model.0 as usize)?
            .staged
            .clone()
    }

    /// Take a refcounted lease on `version` of `model`: the version stays
    /// resolvable — spans stamped with it keep serving, shadow jobs keep
    /// scoring — no matter how many swaps race it, until the lease drops.
    /// `None` when the version is not currently resolvable (already out of
    /// the window and not staged or pinned).
    pub fn pin_version(&self, model: ModelId, version: u32) -> Option<VersionLease> {
        let mut reg = self
            .shared
            .registry
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        let e = reg.get_mut(model.0 as usize)?;
        if let Some(pin) = e.pins.iter_mut().find(|(v, _, _)| *v == version) {
            pin.2 += 1;
        } else {
            let forest = e.resolve(version)?;
            e.pins.push((version, forest, 1));
        }
        Some(VersionLease {
            shared: self.shared.clone(),
            model: model.0,
            version,
        })
    }

    /// Enqueue a shadow-scoring job on the lowest-priority queue. Returns
    /// `false` — and the job's callback receives [`ShadowOutcome::Shed`]
    /// immediately — when the queue is full or the pool is shutting down:
    /// shadow work sheds first, it never queues behind itself or delays
    /// live traffic. On `true` the callback will be invoked exactly once,
    /// from a worker thread, with the job's eventual outcome.
    pub fn submit_shadow(&self, job: ShadowJob) -> bool {
        let shared = &*self.shared;
        if shared.shutdown.load(Ordering::Relaxed) {
            shared.stats.shadow_shed.fetch_add(1, Ordering::Relaxed);
            return false; // Drop delivers Shed.
        }
        {
            let mut q = shared
                .shadow
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            if q.len() >= shared.shadow_cap {
                drop(q);
                shared.stats.shadow_shed.fetch_add(1, Ordering::Relaxed);
                return false; // Drop delivers Shed.
            }
            q.push_back(job);
        }
        shared.stats.shadow_jobs.fetch_add(1, Ordering::Relaxed);
        // An idle (fully parked) pool must notice the job without waiting
        // out the 50ms park backstop; a busy pool ignores the wakeup and
        // gets to the queue when its rings drain.
        shared.wake_for_push();
        true
    }

    /// Queued shadow jobs (telemetry gauge).
    pub fn shadow_queue_depth(&self) -> usize {
        self.shared
            .shadow
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    /// Feature width of a registered model.
    pub fn n_features(&self, model: ModelId) -> usize {
        self.shared
            .registry
            .read()
            .unwrap_or_else(PoisonError::into_inner)[model.0 as usize]
            .cur
            .n_features
    }

    /// Score `out.len()` rows of flat row-major `rows` (width `row_len`)
    /// with `model`, sharded across the pool. Blocks until every shard
    /// completed. Returns the row spans whose shard **panicked** (their
    /// `out` values are untouched garbage); an empty vec means every row
    /// was served. Bit-identical to a single-threaded
    /// [`FlatForest::predict_flat_rows`] over the same buffer.
    pub fn predict_spans(
        &self,
        model: ModelId,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
    ) -> Vec<Range<usize>> {
        self.predict_inner(model, rows, row_len, out, None, None, None)
    }

    /// [`ShardPool::predict_spans_deadline`] against an explicit version —
    /// the canary serve path: a rollout routes a batch to its staged
    /// candidate by stamping every span with the candidate's version, so
    /// the batch is single-version by construction exactly like a live
    /// batch. Spans whose version can no longer be resolved (candidate
    /// unstaged mid-flight with no [`VersionLease`] held) come back failed,
    /// never served with other bits.
    pub fn predict_spans_version(
        &self,
        model: ModelId,
        version: u32,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
        deadline: Option<Instant>,
    ) -> Vec<Range<usize>> {
        self.predict_inner(model, rows, row_len, out, deadline, None, Some(version))
    }

    /// Deadline-aware [`ShardPool::predict_spans`]: sub-range tasks still
    /// queued (not yet started) once `deadline` passes come back as failed
    /// spans instead of executing — capacity goes to work someone is still
    /// waiting for. Tasks already running are never interrupted, so rows
    /// are always either fully computed (bit-identical) or reported failed
    /// — never partially written. Sheds are counted in
    /// [`ShardStats::deadline_shed`](crate::telemetry::ShardStats).
    pub fn predict_spans_deadline(
        &self,
        model: ModelId,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
        deadline: Option<Instant>,
    ) -> Vec<Range<usize>> {
        self.predict_inner(model, rows, row_len, out, deadline, None, None)
    }

    /// Like [`ShardPool::predict_spans`], additionally delivering every
    /// completed sub-range to `sink` the moment its shard finishes it —
    /// called from worker threads, concurrently, while later spans are
    /// still executing. When this returns, every span has been delivered
    /// exactly once (served or failed) and `out` is fully written. The
    /// streamed spans concatenate bit-identically to the blocking result.
    pub fn predict_spans_streamed(
        &self,
        model: ModelId,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
        sink: SpanSink<'_>,
    ) -> Vec<Range<usize>> {
        self.predict_inner(model, rows, row_len, out, None, Some(sink), None)
    }

    /// Deadline-aware [`ShardPool::predict_spans_streamed`] — shed spans
    /// reach the sink as failed chunks, exactly like a panicked shard's.
    pub fn predict_spans_streamed_deadline(
        &self,
        model: ModelId,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
        deadline: Option<Instant>,
        sink: SpanSink<'_>,
    ) -> Vec<Range<usize>> {
        self.predict_inner(model, rows, row_len, out, deadline, Some(sink), None)
    }

    fn predict_inner(
        &self,
        model: ModelId,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
        deadline: Option<Instant>,
        sink: Option<SpanSink<'_>>,
        version_override: Option<u32>,
    ) -> Vec<Range<usize>> {
        let n = out.len();
        assert!(rows.len() >= n * row_len, "rows buffer shorter than n*row_len");
        if n == 0 {
            return Vec::new();
        }
        let shared = &*self.shared;
        // Adaptive granularity from live occupancy (see module docs): a
        // balanced (idle) pool gets at most one task per ACTIVE shard; an
        // occupied pool gets up to STEAL_GRAIN× finer tasks so steals are
        // cheap. Never fewer than min_task_rows rows per task. Both knobs
        // are read once per batch so a live retune can't tear a batch.
        let active = shared.active();
        let busy = shared.stats.busy_shards();
        let max_tasks = if busy == 0 {
            active
        } else {
            active * STEAL_GRAIN
        };
        let tasks = (n / shared.min_rows()).clamp(1, max_tasks);
        let chunk = n.div_ceil(tasks);
        let n_tasks = n.div_ceil(chunk);
        let latch = BatchLatch::new(n, sink);
        shared
            .stats
            .spans_submitted
            .fetch_add(n_tasks as u64, Ordering::Relaxed);

        // One version stamp per batch, read once: every span of this batch
        // is served by exactly this version (or fails), however a racing
        // swap lands relative to the submission loop below. A canary batch
        // overrides the stamp with its candidate's version — same
        // single-version-per-batch contract, different version.
        let version = version_override.unwrap_or_else(|| shared.cur_version(model.0));
        let rows_ptr = rows.as_ptr();
        let out_ptr = out.as_mut_ptr();
        let base = shared.rr.fetch_add(1, Ordering::Relaxed);
        let mut start = 0usize;
        let mut ti = 0usize;
        while start < n {
            let len = chunk.min(n - start);
            // SAFETY (task lifetime): `latch.wait()` below does not return
            // until every row completed, and workers never touch a task's
            // pointers after completing it — so `rows`, `out`, `latch` (and
            // the sink behind it) strictly outlive all uses. Output
            // sub-slices are disjoint by construction, and splits partition
            // a task's range without ever duplicating rows.
            let task = Task {
                model: model.0,
                version,
                rows: unsafe { rows_ptr.add(start * row_len) },
                rows_len: len * row_len,
                row_len,
                n: len,
                out: unsafe { out_ptr.add(start) },
                span_start: start,
                deadline,
                batch: &latch,
            };
            self.submit_task(task, (base + ti) % active);
            start += len;
            ti += 1;
        }
        shared.stats.note_queue_depth(shared.queue_depth_total());
        latch.wait()
    }

    /// Push one task: home ring first, then every other ACTIVE ring once,
    /// inline as the last resort (backpressure — the request path must not
    /// deadlock behind wedged rings).
    fn submit_task(&self, task: Task, home: usize) {
        let shared = &*self.shared;
        let active = shared.active();
        let mut task = task;
        for d in 0..active {
            match shared.rings[(home + d) % active].push(task) {
                Ok(()) => {
                    shared.wake_for_push();
                    return;
                }
                Err(t) => task = t,
            }
        }
        shared.stats.inline_runs.fetch_add(1, Ordering::Relaxed);
        let forest = shared.forest_version(task.model, task.version);
        run_task(task, forest.as_deref(), &mut ForestScratch::default(), shared);
    }

    /// Like [`ShardPool::predict_spans`], but collapses shard failures into
    /// one error (the whole-batch contract the RPC batcher had before
    /// per-shard granularity existed).
    pub fn predict(
        &self,
        model: ModelId,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
    ) -> Result<(), ShardPanic> {
        let failed = self.predict_spans(model, rows, row_len, out);
        if failed.is_empty() {
            Ok(())
        } else {
            Err(ShardPanic { spans: failed })
        }
    }
}

/// One or more shards panicked while serving a batch.
#[derive(Debug, Clone)]
pub struct ShardPanic {
    /// The failed row spans.
    pub spans: Vec<Range<usize>>,
}

impl std::fmt::Display for ShardPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard panic on row spans {:?}", self.spans)
    }
}

impl std::error::Error for ShardPanic {}

/// RAII lease from [`ShardPool::pin_version`]: while any lease on a
/// `(model, version)` pair is alive, that version stays resolvable for
/// span execution and shadow scoring regardless of how many `swap`s race
/// past it. Dropping the last lease releases the pinned forest.
pub struct VersionLease {
    shared: Arc<PoolShared>,
    model: u32,
    version: u32,
}

impl VersionLease {
    /// The pinned version.
    pub fn version(&self) -> u32 {
        self.version
    }
}

impl Drop for VersionLease {
    fn drop(&mut self) {
        let mut reg = self
            .shared
            .registry
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        let Some(e) = reg.get_mut(self.model as usize) else {
            return;
        };
        if let Some(i) = e.pins.iter().position(|(v, _, _)| *v == self.version) {
            e.pins[i].2 -= 1;
            if e.pins[i].2 == 0 {
                e.pins.swap_remove(i);
            }
        }
    }
}

impl std::fmt::Debug for VersionLease {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VersionLease")
            .field("model", &self.model)
            .field("version", &self.version)
            .finish()
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.parker.wake_all();
        for w in self.workers.drain(..) {
            // Workers drain every ring before exiting, so queued batches
            // complete rather than strand their submitters.
            self.shared.parker.wake_all();
            let _ = w.join();
        }
        // Workers drained the shadow queue on their way out; anything that
        // slipped in after the last worker exited is shed here, so every
        // accepted shadow job still gets exactly one outcome.
        self.shared.drain_shadow();
    }
}

/// Execute one task against `forest`, containing panics to the task's span
/// and delivering the completed span to the batch's sink (if streaming).
/// `forest: None` means the task's version stamp could no longer be
/// resolved (two swaps raced a queued span out of the two-version window):
/// the span completes as failed — wrong-version bits are never served.
fn run_task(task: Task, forest: Option<&FlatForest>, scratch: &mut ForestScratch, shared: &PoolShared) {
    // SAFETY: see the lifetime argument in `predict_inner` — the submitter
    // blocks on the latch, so these borrows are live, and no other task
    // writes this output range.
    let rows = unsafe { std::slice::from_raw_parts(task.rows, task.rows_len) };
    let out = unsafe { std::slice::from_raw_parts_mut(task.out, task.n) };
    // Deadline shed: a task whose horizon already passed completes as a
    // failed span WITHOUT executing — its submitter stopped waiting, so
    // computing the rows would serve nobody. Rows are thus always either
    // fully computed or reported failed, never partially written.
    let failed = if task.deadline.is_some_and(|d| Instant::now() >= d) {
        shared.stats.deadline_shed.fetch_add(1, Ordering::Relaxed);
        true
    } else if let Some(forest) = forest {
        let t0 = Instant::now();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            forest.predict_flat_rows(rows, task.row_len, scratch, out);
        }));
        // Recorded BEFORE the latch countdown: a submitter returning from
        // `wait()` observes chunk timings that include its whole batch.
        shared.stats.chunk_exec.record_duration(t0.elapsed());
        if r.is_err() {
            shared.stats.shard_panics.fetch_add(1, Ordering::Relaxed);
        }
        r.is_err()
    } else {
        shared.stats.stale_spans.fetch_add(1, Ordering::Relaxed);
        true
    };
    let span = task.span_start..task.span_start + task.n;
    // SAFETY: the latch (and sink) outlive the submitter's wait; the sink
    // call plus `complete` are the LAST touches, `complete` strictly last
    // (nothing may follow the final countdown).
    unsafe {
        let latch = &*task.batch;
        if let Some(sink) = latch.sink {
            let probs: &[f32] = if failed { &[] } else { &*out };
            // A panicking SINK must be contained exactly like a panicking
            // model: skipping `complete` would strand the submitter on the
            // latch forever and kill this worker. The span's data is
            // already in `out`, so the batch result is unaffected — only
            // the sink's own delivery is lost.
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                (*sink)(span.clone(), probs, failed);
            }))
            .is_err()
            {
                shared.stats.shard_panics.fetch_add(1, Ordering::Relaxed);
            }
        }
        latch.complete(span, failed);
    }
}

/// Scan the other shards' rings for a queued task, nearest neighbor first.
/// Deactivated workers (id past the live `active_shards` mark) never
/// steal — they drain their own ring and park, shedding CPU; active
/// thieves still scan EVERY ring so a shrink's residual work migrates to
/// the active set instead of waiting on a parked owner's 50ms backstop.
fn steal(thief: usize, shared: &PoolShared) -> Option<Task> {
    // Shutdown overrides the gate: the drain guarantee wants every worker
    // scanning every ring regardless of how shrunk the pool was.
    if thief >= shared.active() && !shared.shutdown.load(Ordering::Relaxed) {
        return None;
    }
    let n = shared.rings.len();
    for d in 1..n {
        let victim = (thief + d) % n;
        if let Some(t) = shared.rings[victim].try_pop() {
            shared.stats.record_steal(thief);
            return Some(split_stolen(t, victim, shared));
        }
    }
    None
}

/// Chunked steal: keep the BACK half of a big stolen span and requeue the
/// front half on the victim's ring, where the victim (or another thief)
/// finds it — each steal takes half the remaining span, so recursive
/// halving drains a hot shard's backlog in O(log) steals. Small tasks move
/// whole; a refilled victim ring also moves the task whole.
fn split_stolen(t: Task, victim: usize, shared: &PoolShared) -> Task {
    if t.n < 2 * shared.min_rows() {
        return t;
    }
    let keep = t.n / 2;
    let leave = t.n - keep;
    let rest = Task {
        rows_len: leave * t.row_len,
        n: leave,
        ..t
    };
    // SAFETY: window views over the stolen task's (live, disjoint) range —
    // `rest` covers rows [0, leave), `stolen` rows [leave, n).
    let stolen = Task {
        rows: unsafe { t.rows.add(leave * t.row_len) },
        rows_len: keep * t.row_len,
        n: keep,
        out: unsafe { t.out.add(leave) },
        span_start: t.span_start + leave,
        ..t
    };
    match shared.rings[victim].push(rest) {
        Ok(()) => {
            shared.stats.steal_splits.fetch_add(1, Ordering::Relaxed);
            // The requeued remainder is a NEW span: keep the
            // submitted == completed + inline invariant intact.
            shared.stats.spans_submitted.fetch_add(1, Ordering::Relaxed);
            shared.wake_for_push();
            stolen
        }
        Err(_) => t,
    }
}

/// Pop from the worker's own ring, falling back to a steal when allowed.
fn pop_or_steal(shard: usize, shared: &PoolShared, allow_steal: bool) -> Option<Task> {
    if let Some(t) = shared.rings[shard].try_pop() {
        return Some(t);
    }
    if allow_steal {
        steal(shard, shared)
    } else {
        None
    }
}

/// One unit of worker work: a live span task, or — only when every ring is
/// empty — a queued shadow-scoring job.
enum Work {
    Task(Task),
    Shadow(ShadowJob),
}

/// Worker-side work acquisition: spin on the own ring (stealing
/// periodically), then — only once the rings are confirmed empty — take a
/// shadow job, then park. The ordering IS the shadow-priority contract:
/// live spans are found in the spin loop and the park-path ring re-check,
/// shadow jobs only after both came up empty, so shadow work never delays
/// a queued live span. Returns `None` only when `shutdown` is set AND every
/// ring has drained — queued work is always finished before a worker exits,
/// so no submitter is left waiting on a latch that nobody will hit.
fn acquire(shard: usize, shared: &PoolShared) -> Option<Work> {
    loop {
        for spin in 0..96u32 {
            if let Some(t) = shared.rings[shard].try_pop() {
                return Some(Work::Task(t));
            }
            if shared.steal && spin % 32 == 31 {
                if let Some(t) = steal(shard, shared) {
                    return Some(Work::Task(t));
                }
            }
            if spin % 16 == 15 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        let guard = shared
            .parker
            .lock
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        shared.parker.sleepers.fetch_add(1, Ordering::Relaxed);
        // Advertise the sleep, THEN re-check the rings — the SeqCst fence
        // pairs with the one in `wake_for_push` (see there), so a push
        // racing this park is seen by exactly one side.
        std::sync::atomic::fence(Ordering::SeqCst);
        let shutting_down = shared.shutdown.load(Ordering::SeqCst);
        // During shutdown every worker scans every ring (steal or not) so
        // the drain guarantee holds.
        if let Some(t) = pop_or_steal(shard, shared, shared.steal || shutting_down) {
            shared.parker.sleepers.fetch_sub(1, Ordering::Relaxed);
            return Some(Work::Task(t));
        }
        if shutting_down {
            shared.parker.sleepers.fetch_sub(1, Ordering::Relaxed);
            // Pending shadow jobs are shed, not scored: shutdown must not
            // wait on best-effort work, but every job still gets its
            // exactly-once outcome. The parker lock is released first —
            // shed callbacks run outside all pool locks.
            drop(guard);
            shared.drain_shadow();
            return None;
        }
        // Rings are empty and we are not shutting down: this idle slot is
        // what shadow scoring is allowed to consume.
        if let Some(job) = shared.pop_shadow() {
            shared.parker.sleepers.fetch_sub(1, Ordering::Relaxed);
            return Some(Work::Shadow(job));
        }
        // The fence handshake makes wakeups reliable; the long timeout
        // only bounds the damage of an OS-level anomaly. Idle workers
        // wake ~20×/s instead of spinning.
        let (guard, _) = shared
            .parker
            .cv
            .wait_timeout(guard, Duration::from_millis(50))
            .unwrap_or_else(PoisonError::into_inner);
        shared.parker.sleepers.fetch_sub(1, Ordering::Relaxed);
        drop(guard);
    }
}

/// Pin the calling thread to CPU `shard % online_cpus` via
/// `sched_setaffinity` (pid 0 = this thread). Returns the CPU id on
/// success; `None` when the syscall is unavailable, fails (restricted
/// cpusets, containers), or the CPU count cannot be read.
#[cfg(target_os = "linux")]
fn pin_current_thread(shard: usize) -> Option<u32> {
    // SAFETY: sysconf takes no pointers; sched_setaffinity reads a fully
    // initialized cpu_set_t of the size we pass.
    unsafe {
        let online = libc::sysconf(libc::_SC_NPROCESSORS_ONLN);
        if online <= 0 {
            return None;
        }
        let cpu = shard % online as usize;
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        libc::CPU_SET(cpu, &mut set);
        if libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set) != 0 {
            return None;
        }
        Some(cpu as u32)
    }
}

/// Thread affinity is Linux-only; elsewhere pinning is a no-op.
#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_shard: usize) -> Option<u32> {
    None
}

fn worker_loop(shard: usize, shared: Arc<PoolShared>) {
    if shared.pin_threads {
        match pin_current_thread(shard) {
            Some(cpu) => shared.stats.set_pinned(shard, cpu),
            None => {
                shared.stats.pin_failures.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    // Per-shard model replicas, TWO slots per model id (MRU first), each
    // stamped with the version it was built from. Installed from the
    // registry's pre-built clones on first touch of a version (the deep
    // clone happened at register/swap/stage time, off this serve path).
    // Two slots because a canary ramp interleaves incumbent- and
    // candidate-stamped batches on the same model for its whole duration —
    // a one-slot cache would evict and rebuild a full replica on every
    // alternation. A version absent from both slots evicts the LRU slot,
    // so the cache holds at most two replicas per model. The scratch is
    // shared across models — it is cleared per call.
    let mut replicas: Vec<[Option<(u32, FlatForest)>; 2]> = Vec::new();
    let mut scratch = ForestScratch::default();
    while let Some(work) = acquire(shard, &shared) {
        let task = match work {
            Work::Task(t) => t,
            Work::Shadow(job) => {
                // Shadow jobs score the registry's shared forest directly —
                // no replica install, no cache disturbance: best-effort work
                // must not evict what the live path relies on.
                shared.stats.set_busy(shard, true);
                run_shadow(job, &shared, &mut scratch);
                shared.stats.set_busy(shard, false);
                continue;
            }
        };
        shared.stats.set_busy(shard, true);
        let model = task.model as usize;
        if replicas.len() <= model {
            replicas.resize_with(model + 1, || [None, None]);
        }
        let pair = &mut replicas[model];
        if pair[0].as_ref().is_some_and(|&(v, _)| v == task.version) {
            // MRU hit: nothing to do.
        } else if pair[1].as_ref().is_some_and(|&(v, _)| v == task.version) {
            pair.swap(0, 1);
        } else {
            // Miss: demote the MRU slot, evict the LRU slot, install the
            // needed version in front.
            pair.swap(0, 1);
            if pair[0].take().is_some() {
                shared.stats.replicas_evicted.fetch_add(1, Ordering::Relaxed);
            }
            let installed = shared
                .take_prepared(task.model, shard, task.version)
                .or_else(|| {
                    // No prepared clone with this stamp (a racing swap
                    // re-targeted the set, or a stale-but-windowed span
                    // needs the previous version): build one here, counted
                    // — this is the latency cliff the prepared path
                    // normally avoids.
                    shared.forest_version(task.model, task.version).map(|f| {
                        let t0 = Instant::now();
                        let replica = (*f).clone();
                        shared.stats.replica_builds.fetch_add(1, Ordering::Relaxed);
                        shared.stats.replica_build.record_duration(t0.elapsed());
                        replica
                    })
                });
            pair[0] = installed.map(|f| (task.version, f));
        }
        // None ⇒ the stamp left the two-version window: run_task fails the
        // span (counted), keeping the rows-conservation invariant intact.
        let forest = replicas[model][0].as_ref().map(|(_, f)| f);
        // Count the task BEFORE running it: `run_task` hits the completion
        // latch, and a submitter returning from `wait()` must observe
        // stats that already include every task of its batch.
        shared.stats.record_task(shard);
        run_task(task, forest, &mut scratch, &shared);
        shared.stats.set_busy(shard, false);
    }
}

/// Execute one shadow-scoring job on this worker: resolve the pinned
/// version from the registry (the shared `Arc`, NOT a per-shard replica —
/// shadow work must not disturb the replica cache), score the owned rows,
/// and deliver the outcome exactly once. A job whose deadline already
/// passed, or whose version left the window, is shed — shadow results are
/// advisory, so late or unresolvable answers are worthless. Candidate
/// panics are contained here and reported as [`ShadowOutcome::Failed`]:
/// a poisoned candidate must never take a serving worker down.
fn run_shadow(job: ShadowJob, shared: &PoolShared, scratch: &mut ForestScratch) {
    if job.deadline.is_some_and(|d| Instant::now() >= d) {
        shared.stats.shadow_shed.fetch_add(1, Ordering::Relaxed);
        job.deliver(ShadowOutcome::Shed);
        return;
    }
    let Some(forest) = shared.forest_version(job.model.0, job.version) else {
        shared.stats.shadow_shed.fetch_add(1, Ordering::Relaxed);
        job.deliver(ShadowOutcome::Shed);
        return;
    };
    let n = job.n_rows();
    let mut out = vec![0f32; n];
    let t0 = Instant::now();
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        forest.predict_flat_rows(&job.rows, job.row_len, scratch, &mut out);
    }));
    shared.stats.chunk_exec.record_duration(t0.elapsed());
    match r {
        Ok(()) => {
            job.deliver(ShadowOutcome::Scored(out));
        }
        Err(_) => {
            shared.stats.shadow_panics.fetch_add(1, Ordering::Relaxed);
            // A panic mid-predict can leave the scratch mid-traversal;
            // start the next call clean.
            *scratch = ForestScratch::default();
            job.deliver(ShadowOutcome::Failed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::flat::FlatNode;
    use crate::gbdt::{train, GbdtParams, LEAF};
    use crate::tabular::{Dataset, RowBlock, Schema};
    use crate::util::rng::Rng;

    fn trained() -> (crate::gbdt::GbdtModel, Dataset) {
        let mut rng = Rng::new(41);
        let mut d = Dataset::new(Schema::numeric(5));
        for _ in 0..2500 {
            let x: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
            let y = (x[0] * x[1] - x[3] > 0.1) as u8 as f32;
            d.push_row(&x, y);
        }
        let m = train(&d, &GbdtParams { n_trees: 15, max_depth: 5, ..Default::default() });
        (m, d)
    }

    /// A forest that panics (out-of-bounds feature read) on any row with
    /// `x[0] == f32::INFINITY` and returns sigmoid(base + 0.2) otherwise.
    fn poison_forest(n_features: usize) -> FlatForest {
        FlatForest::from_nodes(
            &[
                // root: x[0] <= 1e30 → left leaf; else → poison node.
                FlatNode { feat: 0, thresh: 1e30, lo: 1, value: 0.0 },
                FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: 0.2 },
                // Feature index far past any row width: the arena read of
                // rows[r*row_len + 9_999_999] panics (slice bounds check).
                FlatNode { feat: 9_999_999, thresh: 0.0, lo: 3, value: 0.0 },
                FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: 0.0 },
                FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: 0.0 },
            ],
            vec![0],
            0.0,
            n_features,
        )
    }

    /// A deliberately expensive forest: ONE shallow tree whose root is
    /// repeated `reps` times, so a single small batch grinds a shard for a
    /// long, tunable time (the "hot neighbor" in the steal tests).
    fn slow_forest(n_features: usize, reps: usize) -> FlatForest {
        FlatForest::from_nodes(
            &[
                FlatNode { feat: 0, thresh: 0.0, lo: 1, value: 0.0 },
                FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: 1e-7 },
                FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: -1e-7 },
            ],
            vec![0; reps],
            0.0,
            n_features,
        )
    }

    fn flat_rows(d: &Dataset, n: usize) -> (Vec<f32>, usize) {
        let row_len = d.n_features();
        let mut rows = vec![0f32; n * row_len];
        let mut row = Vec::new();
        for r in 0..n {
            d.row_into(r, &mut row);
            rows[r * row_len..(r + 1) * row_len].copy_from_slice(&row);
        }
        (rows, row_len)
    }

    /// Acceptance property: scalar, block, and pooled paths agree
    /// bit-for-bit — across shard counts, batch sizes, and NaN rows.
    #[test]
    fn pooled_matches_scalar_and_block_bitwise() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let (mut rows, row_len) = flat_rows(&d, 300);
        // NaN rows must route identically on every path.
        for f in 0..row_len {
            rows[17 * row_len + f] = f32::NAN;
        }
        rows[205 * row_len + 2] = f32::NAN;

        let mut scratch = ForestScratch::default();
        for &shards in &[1usize, 2, 4] {
            let pool = ShardPool::with_config(ShardPoolConfig {
                n_shards: shards,
                min_task_rows: 16, // engage sharding at these test sizes
                ..Default::default()
            });
            let id = pool.register(flat.clone());
            for &n in &[1usize, 15, 16, 64, 300] {
                let mut pooled = vec![0f32; n];
                let failed = pool.predict_spans(id, &rows[..n * row_len], row_len, &mut pooled);
                assert!(failed.is_empty(), "shards={shards} n={n}: {failed:?}");
                // Reference: single-threaded flat path (itself pinned
                // bit-identical to GbdtModel::predict_one by flat.rs tests).
                let mut reference = vec![0f32; n];
                flat.predict_flat_rows(&rows[..n * row_len], row_len, &mut scratch, &mut reference);
                for r in 0..n {
                    assert_eq!(
                        pooled[r].to_bits(),
                        reference[r].to_bits(),
                        "shards={shards} n={n} row={r}"
                    );
                }
                // And against the columnar block path.
                let mut block = RowBlock::new();
                block.fill_from_flat(&rows, n, row_len);
                let mut via_block = Vec::new();
                flat.predict_block(&block, &mut scratch, &mut via_block);
                for r in 0..n {
                    assert_eq!(pooled[r].to_bits(), via_block[r].to_bits(), "block n={n} row={r}");
                }
            }
        }
    }

    #[test]
    fn fault_injection_fails_only_the_poisoned_shard_span() {
        let row_len = 4;
        let n = 256;
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 4,
            min_task_rows: 64,
            ..Default::default()
        });
        let id = pool.register(poison_forest(row_len));
        let mut rows = vec![0.5f32; n * row_len];
        // Mark one row in the third task's sub-range (rows 128..192). The
        // 256-row batch splits into 4×64-row tasks (64 < 2×min_task_rows,
        // so steal-splits cannot refine the failure span further).
        rows[150 * row_len] = f32::INFINITY;
        let mut out = vec![-1f32; n];
        let failed = pool.predict_spans(id, &rows, row_len, &mut out);
        assert_eq!(failed, vec![128..192], "exactly the poisoned task's span");
        let expected = crate::util::sigmoid(0.2) as f32;
        for (r, &p) in out.iter().enumerate() {
            if (128..192).contains(&r) {
                continue; // failed span: contents unspecified
            }
            assert_eq!(p.to_bits(), expected.to_bits(), "row {r} outside the failed span");
        }
        assert_eq!(pool.stats().panics(), 1);

        // Subsequent submissions succeed on ALL shards — the panic did not
        // wedge the rings or kill a worker.
        for round in 0..3 {
            let clean = vec![0.5f32; n * row_len];
            let mut out = vec![0f32; n];
            let failed = pool.predict_spans(id, &clean, row_len, &mut out);
            assert!(failed.is_empty(), "round {round}");
            assert!(out.iter().all(|p| p.to_bits() == expected.to_bits()));
        }
        // Every sub-range task of every batch completed despite the panic
        // (no steal-splits possible at this task size — see above).
        assert_eq!(
            pool.stats().spans_completed() + pool.stats().inline_runs.load(Ordering::Relaxed),
            16
        );
    }

    #[test]
    fn live_min_task_rows_retunes_granularity_without_wrong_bits() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let (rows, row_len) = flat_rows(&d, 256);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 4,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(flat.clone());

        let mut scratch = ForestScratch::default();
        let mut reference = vec![0f32; 256];
        flat.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);

        // Coarsen far past the batch size: the next batch is exactly ONE
        // task, and no steal-split can refine it (256 < 2×floor).
        pool.set_min_task_rows(100_000);
        assert_eq!(pool.min_task_rows(), 100_000);
        let before = pool.stats().spans_submitted.load(Ordering::Relaxed);
        let mut coarse = vec![0f32; 256];
        assert!(pool.predict_spans(id, &rows, row_len, &mut coarse).is_empty());
        assert_eq!(
            pool.stats().spans_submitted.load(Ordering::Relaxed) - before,
            1,
            "a coarsened pool must submit one span per batch"
        );
        for r in 0..256 {
            assert_eq!(coarse[r].to_bits(), reference[r].to_bits(), "row {r}");
        }

        // Back to fine granularity: an idle 4-shard pool splits 256 rows
        // into one task per shard again.
        pool.set_min_task_rows(16);
        let before = pool.stats().spans_submitted.load(Ordering::Relaxed);
        let mut fine = vec![0f32; 256];
        assert!(pool.predict_spans(id, &rows, row_len, &mut fine).is_empty());
        assert!(
            pool.stats().spans_submitted.load(Ordering::Relaxed) - before >= 4,
            "a re-finened pool must fan a batch back out"
        );
        for r in 0..256 {
            assert_eq!(fine[r].to_bits(), reference[r].to_bits(), "row {r}");
        }

        // The floor clamps at 1 — a zero from a confused controller must
        // not produce zero-row tasks.
        pool.set_min_task_rows(0);
        assert_eq!(pool.min_task_rows(), 1);
    }

    #[test]
    fn shrunk_pool_places_all_work_on_the_active_set() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let (rows, row_len) = flat_rows(&d, 256);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 4,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(flat.clone());

        let mut scratch = ForestScratch::default();
        let mut reference = vec![0f32; 256];
        flat.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);

        // Clamping: 0 means 1, anything past n_shards means n_shards.
        pool.set_active_shards(0);
        assert_eq!(pool.active_shards(), 1);
        pool.set_active_shards(99);
        assert_eq!(pool.active_shards(), 4);

        pool.set_active_shards(1);
        for round in 0..4 {
            let mut out = vec![0f32; 256];
            let failed = pool.predict_spans(id, &rows, row_len, &mut out);
            assert!(failed.is_empty(), "round {round}");
            for r in 0..256 {
                assert_eq!(out[r].to_bits(), reference[r].to_bits(), "round {round} row {r}");
            }
        }
        // Every executed task landed on shard 0 (or ran inline under
        // backpressure) — the deactivated workers got nothing.
        for s in 1..4 {
            assert_eq!(pool.stats().tasks_on(s), 0, "deactivated shard {s} ran work");
        }

        // Re-grown, the pool serves correctly at full width again.
        pool.set_active_shards(4);
        let mut out = vec![0f32; 256];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        for r in 0..256 {
            assert_eq!(out[r].to_bits(), reference[r].to_bits(), "row {r}");
        }
    }

    #[test]
    fn multi_tenant_models_share_one_pool() {
        let (m1, d) = trained();
        let m2 = train(
            &d,
            &GbdtParams { n_trees: 9, max_depth: 3, seed: 99, ..Default::default() },
        );
        let f1 = FlatForest::from_model(&m1);
        let f2 = FlatForest::from_model(&m2);
        let pool = Arc::new(ShardPool::with_config(ShardPoolConfig {
            n_shards: 3,
            min_task_rows: 32,
            ..Default::default()
        }));
        let id1 = pool.register(f1);
        let id2 = pool.register(f2);
        assert_ne!(id1, id2);
        assert_eq!(pool.n_features(id1), d.n_features());

        let (rows, row_len) = flat_rows(&d, 200);
        // Both tenants submit concurrently; each must get ITS model's
        // predictions, bit-identical to the scalar path.
        std::thread::scope(|s| {
            for (id, model) in [(id1, &m1), (id2, &m2)] {
                let pool = pool.clone();
                let rows = &rows;
                s.spawn(move || {
                    let mut row = Vec::new();
                    for _ in 0..10 {
                        let mut out = vec![0f32; 200];
                        let failed = pool.predict_spans(id, rows, row_len, &mut out);
                        assert!(failed.is_empty());
                        for r in 0..200 {
                            row.clear();
                            row.extend_from_slice(&rows[r * row_len..(r + 1) * row_len]);
                            assert_eq!(
                                out[r].to_bits(),
                                model.predict_one(&row).to_bits(),
                                "tenant {id:?} row {r}"
                            );
                        }
                    }
                });
            }
        });
        // Telemetry saw the traffic.
        assert!(pool.stats().spans_submitted.load(Ordering::Relaxed) > 0);
        // The busy flag clears just AFTER the completion latch opens; give
        // the workers a moment to settle before asserting idleness.
        for _ in 0..200 {
            if pool.stats().busy_shards() == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.stats().busy_shards(), 0, "pool idle after the storm");
    }

    #[test]
    fn tiny_batches_stay_whole_and_empty_is_ok() {
        let (m, d) = trained();
        let pool = ShardPool::new(4);
        let id = pool.register(FlatForest::from_model(&m));
        let (rows, row_len) = flat_rows(&d, 8);
        let mut out = vec![0f32; 8];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        let mut row = Vec::new();
        for r in 0..8 {
            d.row_into(r, &mut row);
            assert_eq!(out[r].to_bits(), m.predict_one(&row).to_bits());
        }
        let mut empty: [f32; 0] = [];
        assert!(pool.predict_spans(id, &[], row_len, &mut empty).is_empty());
        assert!(pool.predict(id, &rows, row_len, &mut out).is_ok());
    }

    #[test]
    fn full_queue_degrades_to_inline_runs_not_deadlock() {
        let (m, d) = trained();
        // 2-slot rings with every batch split into several tasks and 6
        // concurrent submitters guarantee push failures.
        let pool = Arc::new(ShardPool::with_config(ShardPoolConfig {
            n_shards: 2,
            queue_capacity: 2,
            min_task_rows: 8,
            steal: true,
        }));
        let id = pool.register(FlatForest::from_model(&m));
        let (rows, row_len) = flat_rows(&d, 64);
        std::thread::scope(|s| {
            for _ in 0..6 {
                let pool = pool.clone();
                let rows = &rows;
                s.spawn(move || {
                    for _ in 0..20 {
                        let mut out = vec![0f32; 64];
                        assert!(pool.predict_spans(id, rows, row_len, &mut out).is_empty());
                    }
                });
            }
        });
        let st = pool.stats();
        // Split remainders count as newly submitted spans, so the
        // conservation law holds under stealing too.
        assert_eq!(
            st.spans_completed() + st.inline_runs.load(Ordering::Relaxed),
            st.spans_submitted.load(Ordering::Relaxed),
            "every span either ran on a shard or inline"
        );
    }

    /// The work-stealing acceptance scenario: one shard pinned hot by an
    /// expensive single-task tenant, a cheap probe batch split across the
    /// rings. Idle shards must steal the probe tasks parked behind the hog
    /// (splitting the big ones), the probe must complete while the hog is
    /// still grinding, and results stay bit-identical.
    #[test]
    fn idle_shards_steal_from_a_hot_neighbor() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let pool = Arc::new(ShardPool::with_config(ShardPoolConfig {
            n_shards: 2,
            min_task_rows: 16,
            ..Default::default()
        }));
        let fast = pool.register(flat.clone());
        // 31 rows < 2×min_task_rows ⇒ the hog batch is ONE task pinned to
        // one shard; ~2M repeated roots make it grind for a long time.
        let slow = pool.register(slow_forest(4, 2_000_000));
        let (rows, row_len) = flat_rows(&d, 300);
        let mut reference = vec![0f32; 300];
        {
            let mut scratch = ForestScratch::default();
            flat.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);
        }

        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let pool_hog = pool.clone();
            let stop = &stop;
            s.spawn(move || {
                let hog_rows = vec![0.5f32; 31 * 4];
                let mut out = vec![0f32; 31];
                while !stop.load(Ordering::Relaxed) {
                    assert!(pool_hog.predict_spans(slow, &hog_rows, 4, &mut out).is_empty());
                }
            });
            // Wait until the hog really occupies a shard.
            while pool.stats().busy_shards() == 0 {
                std::hint::spin_loop();
            }
            for round in 0..10 {
                let mut out = vec![0f32; 300];
                // busy ≥ 1 ⇒ adaptive granularity splits ~8 fine tasks
                // across both rings; the free shard must steal the ones
                // parked behind the hog for this to complete promptly.
                let failed = pool.predict_spans(fast, &rows, row_len, &mut out);
                assert!(failed.is_empty(), "round {round}");
                for r in 0..300 {
                    assert_eq!(
                        out[r].to_bits(),
                        reference[r].to_bits(),
                        "round {round} row {r}: stealing must not change results"
                    );
                }
            }
            stop.store(true, Ordering::Relaxed);
        });
        let st = pool.stats();
        assert!(st.steals() > 0, "no steals under a pinned-hot shard: {}", st.report());
        assert!(
            st.steal_splits.load(Ordering::Relaxed) > 0,
            "big stolen tasks must split: {}",
            st.report()
        );
    }

    /// The steal=false escape hatch (bench A/B) still serves correctly —
    /// covering the wake-all parking path.
    #[test]
    fn steal_disabled_still_correct() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 3,
            min_task_rows: 16,
            steal: false,
            ..Default::default()
        });
        let id = pool.register(flat.clone());
        let (rows, row_len) = flat_rows(&d, 200);
        let mut reference = vec![0f32; 200];
        let mut scratch = ForestScratch::default();
        flat.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);
        for _ in 0..5 {
            let mut out = vec![0f32; 200];
            assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
            for r in 0..200 {
                assert_eq!(out[r].to_bits(), reference[r].to_bits(), "row {r}");
            }
        }
        assert_eq!(pool.stats().steals(), 0, "stealing really was off");
    }

    /// Streamed prediction: every span arrives at the sink exactly once,
    /// spans tile the batch, streamed probabilities are bit-identical to
    /// the blocking output, and the out buffer matches too.
    #[test]
    fn streamed_sink_delivers_every_span_once_bit_identical() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 4,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(flat.clone());
        let (rows, row_len) = flat_rows(&d, 300);
        let mut reference = vec![0f32; 300];
        let mut scratch = ForestScratch::default();
        flat.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);

        let seen: Mutex<Vec<(Range<usize>, Vec<f32>, bool)>> = Mutex::new(Vec::new());
        let mut out = vec![0f32; 300];
        let failed = pool.predict_spans_streamed(id, &rows, row_len, &mut out, &|span, probs, failed| {
            seen.lock().unwrap().push((span, probs.to_vec(), failed));
        });
        assert!(failed.is_empty());
        let mut seen = seen.into_inner().unwrap();
        seen.sort_by_key(|(s, _, _)| s.start);
        // Disjoint tiling of 0..300.
        let mut at = 0usize;
        for (span, probs, failed) in &seen {
            assert_eq!(span.start, at, "gap or overlap at {at}");
            assert!(!failed);
            assert_eq!(probs.len(), span.len());
            for (k, p) in probs.iter().enumerate() {
                assert_eq!(p.to_bits(), reference[span.start + k].to_bits());
            }
            at = span.end;
        }
        assert_eq!(at, 300, "spans must tile the batch");
        for r in 0..300 {
            assert_eq!(out[r].to_bits(), reference[r].to_bits(), "row {r}");
        }
    }

    /// Streamed fault injection: the poisoned span arrives at the sink as
    /// failed (empty payload) while every other span streams its rows.
    #[test]
    fn streamed_sink_reports_failed_span_mid_stream() {
        let row_len = 4;
        let n = 256;
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 4,
            min_task_rows: 64,
            ..Default::default()
        });
        let id = pool.register(poison_forest(row_len));
        let mut rows = vec![0.5f32; n * row_len];
        rows[150 * row_len] = f32::INFINITY;
        let mut out = vec![0f32; n];
        let seen: Mutex<Vec<(Range<usize>, usize, bool)>> = Mutex::new(Vec::new());
        let failed = pool.predict_spans_streamed(id, &rows, row_len, &mut out, &|span, probs, failed| {
            seen.lock().unwrap().push((span, probs.len(), failed));
        });
        assert_eq!(failed, vec![128..192]);
        let mut seen = seen.into_inner().unwrap();
        seen.sort_by_key(|(s, _, _)| s.start);
        let mut rows_seen = 0;
        for (span, n_probs, failed) in &seen {
            if *failed {
                assert_eq!(span, &(128..192));
                assert_eq!(*n_probs, 0, "failed spans carry no payload");
            } else {
                assert_eq!(*n_probs, span.len());
            }
            rows_seen += span.len();
        }
        assert_eq!(rows_seen, n, "every row delivered exactly once, failed or not");
        assert_eq!(seen.iter().filter(|(_, _, f)| *f).count(), 1);
    }

    /// Core-pinned workers serve bit-identically, and the pin outcome is
    /// observable: on Linux every worker either records its CPU id or
    /// bumps `pin_failures` (restricted cpusets in CI containers).
    #[test]
    fn pinned_workers_serve_identically_and_record_cpu() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 2,
            min_task_rows: 16,
            pin_threads: true,
            ..Default::default()
        });
        let id = pool.register(flat.clone());
        let (rows, row_len) = flat_rows(&d, 200);
        let mut reference = vec![0f32; 200];
        let mut scratch = ForestScratch::default();
        flat.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);
        for round in 0..3 {
            let mut out = vec![0f32; 200];
            assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
            for r in 0..200 {
                assert_eq!(out[r].to_bits(), reference[r].to_bits(), "round {round} row {r}");
            }
        }
        #[cfg(target_os = "linux")]
        {
            // Workers pin (or record the failure) before their first
            // acquire; serving above guarantees they are up. Poll briefly
            // anyway: a worker may not have been needed yet.
            let deadline = std::time::Instant::now() + Duration::from_secs(2);
            loop {
                let resolved = (0..2)
                    .filter(|&s| pool.stats().pinned_cpu(s).is_some())
                    .count() as u64
                    + pool.stats().pin_failures.load(Ordering::Relaxed);
                if resolved >= 2 {
                    break;
                }
                assert!(
                    std::time::Instant::now() < deadline,
                    "pin outcome never recorded: {}",
                    pool.stats().report()
                );
                std::thread::sleep(Duration::from_millis(1));
            }
            let online = unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN) };
            for s in 0..2 {
                if let Some(cpu) = pool.stats().pinned_cpu(s) {
                    assert!((cpu as i64) < online, "shard {s} pinned to CPU {cpu}");
                }
            }
        }
        // An unpinned pool records nothing.
        let plain = ShardPool::new(2);
        assert!(plain.stats().pinned_cpu(0).is_none());
        assert_eq!(plain.stats().pin_failures.load(Ordering::Relaxed), 0);
    }

    /// Deadline shedding: an already-expired deadline fails every span
    /// without executing a row; a generous deadline changes nothing
    /// (bit-identical to the undeadlined path); sheds are counted.
    #[test]
    fn expired_deadline_sheds_spans_without_executing() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 2,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(flat.clone());
        let (rows, row_len) = flat_rows(&d, 200);

        // Expired before submission: every span must come back failed and
        // tile the batch; no row may be written.
        let past = Instant::now() - Duration::from_millis(1);
        let mut out = vec![-7.0f32; 200];
        let failed = pool.predict_spans_deadline(id, &rows, row_len, &mut out, Some(past));
        let covered: usize = failed.iter().map(Range::len).sum();
        assert_eq!(covered, 200, "expired deadline fails every row: {failed:?}");
        assert!(out.iter().all(|p| *p == -7.0), "shed spans never write output");
        let shed = pool.stats().deadline_shed.load(Ordering::Relaxed);
        assert!(shed > 0, "sheds must be counted");
        assert_eq!(pool.stats().panics(), 0, "a shed is not a panic");

        // Generous deadline: served fully, bit-identical to no deadline.
        let far = Instant::now() + Duration::from_secs(60);
        let mut with_deadline = vec![0f32; 200];
        let failed = pool.predict_spans_deadline(id, &rows, row_len, &mut with_deadline, Some(far));
        assert!(failed.is_empty());
        let mut reference = vec![0f32; 200];
        let mut scratch = ForestScratch::default();
        flat.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);
        for r in 0..200 {
            assert_eq!(with_deadline[r].to_bits(), reference[r].to_bits(), "row {r}");
        }
        assert_eq!(
            pool.stats().deadline_shed.load(Ordering::Relaxed),
            shed,
            "a live deadline sheds nothing"
        );

        // Streamed variant: shed spans reach the sink as failed chunks.
        let seen: Mutex<Vec<(Range<usize>, bool)>> = Mutex::new(Vec::new());
        let mut out = vec![0f32; 200];
        let failed = pool.predict_spans_streamed_deadline(
            id,
            &rows,
            row_len,
            &mut out,
            Some(Instant::now() - Duration::from_millis(1)),
            &|span, probs, failed| {
                assert!(probs.is_empty());
                seen.lock().unwrap().push((span, failed));
            },
        );
        let covered: usize = failed.iter().map(Range::len).sum();
        assert_eq!(covered, 200);
        let seen = seen.into_inner().unwrap();
        assert!(seen.iter().all(|(_, f)| *f));
        assert_eq!(seen.iter().map(|(s, _)| s.len()).sum::<usize>(), 200);
    }

    /// Hot-swap semantics: a swap flips which bits the pool serves, bumps
    /// the version, keeps the old version visible through the shadow hook,
    /// pre-builds (and counts) per-shard replicas off the hot path, and
    /// evicts drained worker replicas. Bad swaps (unknown id, mismatched
    /// feature width) are clean `Err`s.
    #[test]
    fn swap_serves_new_bits_and_shadow_keeps_old() {
        let (m1, d) = trained();
        let m2 = train(
            &d,
            &GbdtParams { n_trees: 9, max_depth: 3, seed: 77, ..Default::default() },
        );
        let f1 = FlatForest::from_model(&m1);
        let f2 = FlatForest::from_model(&m2);
        // ONE shard so the replica-lifecycle counters below are exact (the
        // storm test in tests/concurrency_stress.rs covers multi-shard).
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 1,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(f1.clone());
        assert_eq!(pool.version(id), 1);
        assert!(pool.shadow(id).is_none(), "no previous version yet");
        // Register pre-built one replica per shard, counted.
        assert_eq!(pool.stats().replica_builds.load(Ordering::Relaxed), 1);

        let (rows, row_len) = flat_rows(&d, 200);
        let mut scratch = ForestScratch::default();
        let mut ref1 = vec![0f32; 200];
        f1.predict_flat_rows(&rows, row_len, &mut scratch, &mut ref1);
        let mut ref2 = vec![0f32; 200];
        f2.predict_flat_rows(&rows, row_len, &mut scratch, &mut ref2);

        // Serve v1, swap, serve again: bits must flip to the new model.
        let mut out = vec![0f32; 200];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        for r in 0..200 {
            assert_eq!(out[r].to_bits(), ref1[r].to_bits(), "pre-swap row {r}");
        }
        let v2 = pool.swap(id, f2.clone()).expect("same-width swap succeeds");
        assert_eq!(v2, 2);
        assert_eq!(pool.version(id), 2);
        let (shadow_v, shadow_f) = pool.shadow(id).expect("old version in the window");
        assert_eq!(shadow_v, 1);
        // Shadow scoring: the windowed old forest still computes v1's bits.
        let mut shadow_out = vec![0f32; 200];
        shadow_f.predict_flat_rows(&rows, row_len, &mut scratch, &mut shadow_out);
        for r in 0..200 {
            assert_eq!(shadow_out[r].to_bits(), ref1[r].to_bits(), "shadow row {r}");
        }
        let mut out = vec![0f32; 200];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        for r in 0..200 {
            assert_eq!(out[r].to_bits(), ref2[r].to_bits(), "post-swap row {r}");
        }
        // EXACT replica lifecycle (one shard): one build at register, one
        // pre-build at swap — and zero hot-path clones, because the worker
        // installed the prepared replica on the stamp mismatch, evicting
        // its drained v1 copy.
        let st = pool.stats();
        assert_eq!(
            st.replica_builds.load(Ordering::Relaxed),
            2,
            "register + swap pre-builds only, no serve-loop clone: {}",
            st.report()
        );
        assert_eq!(
            st.replicas_evicted.load(Ordering::Relaxed),
            1,
            "the drained v1 replica was evicted: {}",
            st.report()
        );
        assert_eq!(st.model_swaps.load(Ordering::Relaxed), 1);
        assert_eq!(st.stale_spans.load(Ordering::Relaxed), 0);

        // A second swap retires v1 from the window entirely.
        let v3 = pool.swap(id, f1.clone()).expect("swap back");
        assert_eq!(v3, 3);
        assert_eq!(pool.shadow(id).map(|(v, _)| v), Some(2));

        // Bad swaps are Errs, not panics — and leave serving intact.
        assert!(pool.swap(ModelId(99), f1.clone()).is_err(), "unknown model id");
        let narrow = slow_forest(3, 1);
        let e = pool.swap(id, narrow).unwrap_err();
        assert!(e.contains("features"), "{e}");
        let mut out = vec![0f32; 200];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        for r in 0..200 {
            assert_eq!(out[r].to_bits(), ref1[r].to_bits(), "post-failed-swap row {r}");
        }
    }

    #[test]
    fn stage_serves_candidate_on_request_only_until_promote() {
        let (m1, d) = trained();
        let m2 = train(
            &d,
            &GbdtParams { n_trees: 9, max_depth: 3, seed: 77, ..Default::default() },
        );
        let f1 = FlatForest::from_model(&m1);
        let f2 = FlatForest::from_model(&m2);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 2,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(f1.clone());

        let (rows, row_len) = flat_rows(&d, 200);
        let mut scratch = ForestScratch::default();
        let mut ref1 = vec![0f32; 200];
        f1.predict_flat_rows(&rows, row_len, &mut scratch, &mut ref1);
        let mut ref2 = vec![0f32; 200];
        f2.predict_flat_rows(&rows, row_len, &mut scratch, &mut ref2);

        let cand_v = pool.stage(id, f2.clone()).expect("same-width stage");
        assert_eq!(cand_v, 2, "staged version comes off the same clock as swaps");
        assert_eq!(pool.version(id), 1, "staging does NOT change the serving version");
        assert_eq!(pool.staged(id).map(|(v, _)| v), Some(2));

        // Default batches still serve the incumbent, bit-identical.
        let mut out = vec![0f32; 200];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        for r in 0..200 {
            assert_eq!(out[r].to_bits(), ref1[r].to_bits(), "live row {r} during stage");
        }
        // Canary batches route to the candidate by explicit version stamp.
        let mut out = vec![0f32; 200];
        assert!(pool
            .predict_spans_version(id, cand_v, &rows, row_len, &mut out, None)
            .is_empty());
        for r in 0..200 {
            assert_eq!(out[r].to_bits(), ref2[r].to_bits(), "canary row {r}");
        }

        // Bad stages are Errs and leave both versions serving.
        assert!(pool.stage(ModelId(9), f2.clone()).is_err(), "unknown model");
        assert!(pool.stage(id, slow_forest(3, 1)).is_err(), "width mismatch");

        // Promote: candidate becomes the default, incumbent slides into the
        // shadow window, and the staged slot empties.
        let v = pool.promote(id).expect("staged candidate promotes");
        assert_eq!(v, cand_v);
        assert_eq!(pool.version(id), cand_v);
        assert!(pool.staged(id).is_none());
        assert_eq!(pool.shadow(id).map(|(v, _)| v), Some(1));
        assert!(pool.promote(id).is_err(), "nothing staged anymore");
        let mut out = vec![0f32; 200];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        for r in 0..200 {
            assert_eq!(out[r].to_bits(), ref2[r].to_bits(), "live row {r} after promote");
        }
        assert_eq!(pool.stats().stale_spans.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn unstage_discards_candidate_unless_a_lease_pins_it() {
        let (m1, d) = trained();
        let m2 = train(
            &d,
            &GbdtParams { n_trees: 9, max_depth: 3, seed: 77, ..Default::default() },
        );
        let f1 = FlatForest::from_model(&m1);
        let f2 = FlatForest::from_model(&m2);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 1,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(f1);
        let cand_v = pool.stage(id, f2.clone()).unwrap();
        let lease = pool.pin_version(id, cand_v).expect("staged version pinnable");
        assert_eq!(lease.version(), cand_v);
        assert_eq!(pool.unstage(id), Some(cand_v));
        assert!(pool.staged(id).is_none());

        let (rows, row_len) = flat_rows(&d, 64);
        let mut scratch = ForestScratch::default();
        let mut ref2 = vec![0f32; 64];
        f2.predict_flat_rows(&rows, row_len, &mut scratch, &mut ref2);

        // The lease keeps an unstaged (rolled-back) candidate resolvable so
        // its in-flight batches complete with the RIGHT bits.
        let mut out = vec![0f32; 64];
        assert!(pool
            .predict_spans_version(id, cand_v, &rows, row_len, &mut out, None)
            .is_empty());
        for r in 0..64 {
            assert_eq!(out[r].to_bits(), ref2[r].to_bits(), "pinned row {r}");
        }

        // Dropping the last lease releases it: the stamp now fails as
        // stale instead of serving — wrong-version bits are never served.
        drop(lease);
        let mut out = vec![0f32; 64];
        let failed = pool.predict_spans_version(id, cand_v, &rows, row_len, &mut out, None);
        let failed_rows: usize = failed.iter().map(|s| s.len()).sum();
        assert_eq!(failed_rows, 64, "unpinned candidate version is unresolvable");
        assert!(pool.stats().stale_spans.load(Ordering::Relaxed) > 0);
        assert_eq!(pool.unstage(id), None, "idempotent");
    }

    /// Satellite-1 regression: a rollout's comparison target must survive
    /// racing swaps. Pre-lease, the shadowed version lived only in the
    /// two-version window, so the SECOND racing swap evicted it
    /// mid-comparison and the comparison batches died as `stale_spans`.
    #[test]
    fn pinned_shadow_version_survives_three_racing_swaps() {
        let (m1, d) = trained();
        let m2 = train(
            &d,
            &GbdtParams { n_trees: 9, max_depth: 3, seed: 77, ..Default::default() },
        );
        let f1 = FlatForest::from_model(&m1);
        let f2 = FlatForest::from_model(&m2);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 2,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(f1.clone());
        pool.swap(id, f2.clone()).unwrap(); // v2 serves, v1 in the window

        let (rows, row_len) = flat_rows(&d, 128);
        let mut scratch = ForestScratch::default();
        let mut ref1 = vec![0f32; 128];
        f1.predict_flat_rows(&rows, row_len, &mut scratch, &mut ref1);

        // Pin the comparison target (v1) for the "rollout's" lifetime.
        let lease = pool.pin_version(id, 1).expect("windowed version pinnable");

        std::thread::scope(|s| {
            let swapper = s.spawn(|| {
                // 3 racing swaps: without the lease, the second one evicts
                // v1 from the window while comparisons are in flight.
                for f in [f1.clone(), f2.clone(), f1.clone()] {
                    pool.swap(id, f).unwrap();
                    std::thread::sleep(Duration::from_millis(2));
                }
            });
            // In-flight shadow comparison: keep scoring on the pinned
            // version throughout the swap storm. Every batch must complete
            // with v1's exact bits — zero stale spans.
            for i in 0..30 {
                let mut out = vec![0f32; 128];
                let failed =
                    pool.predict_spans_version(id, 1, &rows, row_len, &mut out, None);
                assert!(failed.is_empty(), "iteration {i}: stale spans {failed:?}");
                for r in 0..128 {
                    assert_eq!(out[r].to_bits(), ref1[r].to_bits(), "iter {i} row {r}");
                }
            }
            swapper.join().unwrap();
        });
        assert_eq!(
            pool.stats().stale_spans.load(Ordering::Relaxed),
            0,
            "pinned version never evicted mid-comparison: {}",
            pool.stats().report()
        );
        assert_eq!(pool.version(id), 5, "register + 4 swaps");

        // Re-pinning the same version refcounts; release order is free.
        let lease2 = pool.pin_version(id, 1).expect("refcounted re-pin");
        drop(lease);
        let mut out = vec![0f32; 128];
        assert!(pool
            .predict_spans_version(id, 1, &rows, row_len, &mut out, None)
            .is_empty());
        drop(lease2);
        let mut out = vec![0f32; 128];
        let failed = pool.predict_spans_version(id, 1, &rows, row_len, &mut out, None);
        assert!(!failed.is_empty(), "last lease dropped ⇒ v1 unresolvable");
    }

    #[test]
    fn shadow_jobs_score_when_idle_and_shed_on_pressure() {
        let (m1, d) = trained();
        let f1 = FlatForest::from_model(&m1);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 2,
            min_task_rows: 16,
            shadow_queue_capacity: 4,
            ..Default::default()
        });
        let id = pool.register(f1.clone());
        let cand_v = pool.stage(id, f1.clone()).unwrap();
        let _lease = pool.pin_version(id, cand_v).unwrap();

        let (rows, row_len) = flat_rows(&d, 32);
        let mut scratch = ForestScratch::default();
        let mut reference = vec![0f32; 32];
        f1.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);

        // A submitted job is scored by an idle worker and delivers the
        // candidate's exact bits to the callback.
        let (tx, rx) = std::sync::mpsc::channel();
        let job = ShadowJob::new(id, cand_v, rows.clone(), row_len, None, move |o| {
            tx.send(o).unwrap();
        });
        assert_eq!(job.n_rows(), 32);
        assert!(pool.submit_shadow(job));
        match rx.recv_timeout(Duration::from_secs(10)).expect("outcome delivered") {
            ShadowOutcome::Scored(got) => {
                for r in 0..32 {
                    assert_eq!(got[r].to_bits(), reference[r].to_bits(), "shadow row {r}");
                }
            }
            other => panic!("expected Scored, got {other:?}"),
        }

        // An expired deadline sheds without scoring.
        let (tx, rx) = std::sync::mpsc::channel();
        let expired = Some(Instant::now() - Duration::from_millis(1));
        assert!(pool.submit_shadow(ShadowJob::new(
            id,
            cand_v,
            rows.clone(),
            row_len,
            expired,
            move |o| {
                tx.send(o).unwrap();
            },
        )));
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(10)).unwrap(),
            ShadowOutcome::Shed
        ));

        // An unresolvable version sheds too (no lease, version never existed).
        let (tx, rx) = std::sync::mpsc::channel();
        assert!(pool.submit_shadow(ShadowJob::new(
            id,
            999,
            rows.clone(),
            row_len,
            None,
            move |o| {
                tx.send(o).unwrap();
            },
        )));
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(10)).unwrap(),
            ShadowOutcome::Shed
        ));

        let st = pool.stats();
        assert_eq!(st.shadow_jobs.load(Ordering::Relaxed), 3);
        assert_eq!(st.shadow_shed.load(Ordering::Relaxed), 2);
        assert_eq!(st.shadow_panics.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shadow_queue_full_sheds_at_submit_with_outcome_delivered() {
        // No-worker trick is impossible (workers always spawn), so wedge
        // the queue instead: capacity 2, submit while workers are pinned
        // down by live work — live work always wins, so the queue fills.
        let (m1, d) = trained();
        let f1 = FlatForest::from_model(&m1);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 1,
            min_task_rows: 8,
            shadow_queue_capacity: 2,
            ..Default::default()
        });
        let id = pool.register(f1.clone());
        let slow = pool.register(slow_forest(d.n_features(), 2_000_000));
        let cand_v = pool.stage(id, f1.clone()).unwrap();
        let _lease = pool.pin_version(id, cand_v).unwrap();
        let (rows, row_len) = flat_rows(&d, 8);

        std::thread::scope(|s| {
            // Grind the single worker with a slow live batch so queued
            // shadow jobs cannot drain while we overfill the queue.
            let grinder = s.spawn(|| {
                let mut out = vec![0f32; 8];
                let _ = pool.predict_spans(slow, &rows, row_len, &mut out);
            });
            // Wait until the worker is actually busy.
            while pool.stats().busy_shards() == 0 {
                std::hint::spin_loop();
            }
            let (tx, rx) = std::sync::mpsc::channel();
            let submit = |accepted_tx: std::sync::mpsc::Sender<ShadowOutcome>| {
                ShadowJob::new(id, cand_v, rows.clone(), row_len, None, move |o| {
                    let _ = accepted_tx.send(o);
                })
            };
            let a = pool.submit_shadow(submit(tx.clone()));
            let b = pool.submit_shadow(submit(tx.clone()));
            let c = pool.submit_shadow(submit(tx.clone()));
            drop(tx);
            assert!(a && b, "capacity-2 queue accepts two jobs");
            assert!(!c, "third job sheds at submit");
            // The shed job's callback got its Shed outcome synchronously
            // (Drop delivery) — exactly-once accounting holds.
            let first = rx.recv_timeout(Duration::from_secs(1)).unwrap();
            assert!(matches!(first, ShadowOutcome::Shed), "shed outcome delivered");
            grinder.join().unwrap();
            // The two accepted jobs eventually resolve (scored once the
            // grinder finishes, or shed at pool drop) — drain them so the
            // channel proves exactly-once for all three.
            let mut outcomes = 2;
            while outcomes > 0 {
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(_) => outcomes -= 1,
                    Err(e) => panic!("missing shadow outcome: {e}"),
                }
            }
        });
        assert!(pool.stats().shadow_shed.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn shadow_candidate_panic_contained_as_failed() {
        let (m1, d) = trained();
        let f1 = FlatForest::from_model(&m1);
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 1,
            min_task_rows: 16,
            ..Default::default()
        });
        let id = pool.register(f1.clone());
        // Poisoned candidate: panics on rows with x[0] == +inf.
        let cand_v = pool.stage(id, poison_forest(d.n_features())).unwrap();
        let _lease = pool.pin_version(id, cand_v).unwrap();

        let (mut rows, row_len) = flat_rows(&d, 16);
        rows[0] = f32::INFINITY; // first row trips the poison node

        let (tx, rx) = std::sync::mpsc::channel();
        assert!(pool.submit_shadow(ShadowJob::new(
            id,
            cand_v,
            rows.clone(),
            row_len,
            None,
            move |o| {
                tx.send(o).unwrap();
            },
        )));
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(10)).unwrap(),
            ShadowOutcome::Failed
        ));
        assert_eq!(pool.stats().shadow_panics.load(Ordering::Relaxed), 1);

        // The worker survived: live traffic still serves exact bits.
        let mut scratch = ForestScratch::default();
        let mut reference = vec![0f32; 16];
        f1.predict_flat_rows(&rows, row_len, &mut scratch, &mut reference);
        let mut out = vec![0f32; 16];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        for r in 0..16 {
            assert_eq!(out[r].to_bits(), reference[r].to_bits(), "post-panic row {r}");
        }
    }

    #[test]
    fn queue_ring_push_pop_fifo_and_bounds() {
        // Direct ring test (no workers): FIFO within a single producer and
        // exact capacity behavior.
        let q = TaskQueue::new(4);
        let latch = BatchLatch::new(usize::MAX, None); // never opens; tasks are dummies
        let mk = |i: usize| Task {
            model: 0,
            version: 0,
            rows: std::ptr::null(),
            rows_len: 0,
            row_len: 0,
            n: 0,
            out: std::ptr::null_mut(),
            span_start: i,
            deadline: None,
            batch: &latch,
        };
        for i in 0..4 {
            assert!(q.push(mk(i)).is_ok(), "slot {i}");
        }
        assert!(q.push(mk(99)).is_err(), "ring full at capacity");
        assert_eq!(q.depth(), 4);
        for i in 0..4 {
            assert_eq!(q.try_pop().expect("queued").span_start, i);
        }
        assert!(q.try_pop().is_none());
        assert_eq!(q.depth(), 0);
        // Wrap-around keeps working.
        for lap in 0..3 {
            assert!(q.push(mk(lap)).is_ok());
            assert_eq!(q.try_pop().unwrap().span_start, lap);
        }
    }
}
