//! Shard-per-core serving engine: a persistent worker pool with per-shard
//! [`FlatForest`] replicas and a bounded lock-free MPMC task queue.
//!
//! # Why
//!
//! The paper's end-to-end win (1.3× latency, 30% CPU) depends on the ML
//! back-end saturating its cores without per-request thread churn. The old
//! `NativeBackend` spun up scoped threads per big batch and tore them down
//! again — fine for benches, but every batch paid thread spawn/join and the
//! OS scheduler had no warm affinity to exploit. This engine keeps one
//! long-lived worker per shard (core), parked on a shared queue, in the
//! spirit of provisioned pipeline workers (InferLine) and database-style
//! decision-forest serving engines.
//!
//! # Architecture
//!
//! * **Shards** — `n_shards` worker threads, spawned once. Each worker owns
//!   a private deep **replica** of every forest it has served (materialized
//!   lazily on first use, allocated by the worker thread itself — the right
//!   memory locality story) plus a private [`ForestScratch`], so the hot
//!   loop touches no shared mutable state.
//! * **Queue** — a bounded MPMC ring (Vyukov sequence-counter design): push
//!   and pop are single-CAS lock-free operations; workers spin briefly then
//!   park on a condvar that the submit path only touches when sleepers
//!   exist.
//! * **Submission** — [`ShardPool::predict_spans`] splits a flat row batch
//!   into per-shard sub-ranges (at least [`ShardPoolConfig::min_task_rows`]
//!   rows each), submits one task per sub-range, and blocks on a per-batch
//!   completion latch (`remaining` count + condvar) until every task is
//!   done. Tasks borrow the caller's buffers via raw pointers — sound
//!   because the call cannot return before the latch opens.
//! * **Backpressure** — the queue is bounded; a submitter that finds it full
//!   runs the task **inline** on its own thread (serving from the shared
//!   registry image) instead of blocking the request path behind a wedged
//!   queue.
//! * **Poison tolerance** — a panicking shard (a model bug on a poison row)
//!   is contained to its task: the unwind is caught, the task's row span is
//!   reported as failed, the completion latch still opens, and the worker
//!   keeps serving. The engine never wedges and never loses a batch.
//! * **Multi-tenancy** — [`ShardPool::register`] adds models while the pool
//!   is live; several `Coordinator`s (tenants) can share one pool, each
//!   falling back to its own registered forest (the embedded multi-tenant
//!   mode — see the crate docs).
//!
//! Outputs are bit-identical to the scalar and block paths: replicas are
//! value-clones of the registered [`FlatForest`], and
//! [`FlatForest::predict_flat_rows`] over a sub-range computes exactly what
//! the single-threaded call would.

use crate::gbdt::{FlatForest, ForestScratch};
use crate::telemetry::ShardStats;
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError, RwLock};
use std::time::Duration;

/// Handle to a forest registered in a [`ShardPool`] (multi-tenant: each
/// tenant registers its own model and keeps its id).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ModelId(u32);

/// Pool construction knobs.
#[derive(Clone, Debug)]
pub struct ShardPoolConfig {
    /// Worker threads (shards). Default: one per core (capped like
    /// [`crate::util::threadpool::default_threads`]).
    pub n_shards: usize,
    /// Task-queue capacity (rounded up to a power of two). A full queue
    /// makes submitters run tasks inline rather than block.
    pub queue_capacity: usize,
    /// Minimum rows per task: below this, splitting a batch across shards
    /// costs more in hand-off than the parallel traversal wins.
    pub min_task_rows: usize,
}

impl Default for ShardPoolConfig {
    fn default() -> Self {
        ShardPoolConfig {
            n_shards: crate::util::threadpool::default_threads(),
            queue_capacity: 1024,
            min_task_rows: 64,
        }
    }
}

/// One unit of shard work: score `n` rows of a flat row-major buffer into a
/// disjoint output sub-slice, then hit the batch's completion latch.
///
/// Raw pointers, not borrows: tasks outlive the submitting stack frame only
/// until the latch opens, and the submitter blocks on the latch before
/// returning — see the safety argument on [`ShardPool::predict_spans`].
struct Task {
    model: u32,
    rows: *const f32,
    rows_len: usize,
    row_len: usize,
    n: usize,
    out: *mut f32,
    /// Row offset of this task inside the parent batch (failure reporting).
    span_start: usize,
    batch: *const BatchLatch,
}

// SAFETY: the pointers target buffers owned by a submitter that cannot
// return before this task completes (completion latch), and each task's
// output range is disjoint.
unsafe impl Send for Task {}

/// Per-batch completion latch: workers count down `remaining`; the
/// submitter sleeps on `cv` until the last decrement flips `done`.
struct BatchLatch {
    remaining: AtomicUsize,
    /// Failed row spans (a panicking shard reports its sub-range here).
    failed: Mutex<Vec<Range<usize>>>,
    done: Mutex<bool>,
    cv: Condvar,
}

impl BatchLatch {
    fn new(tasks: usize) -> BatchLatch {
        BatchLatch {
            remaining: AtomicUsize::new(tasks),
            failed: Mutex::new(Vec::new()),
            done: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    /// Record a task completion; the LAST completion opens the latch.
    /// Nothing may touch the latch after the open (the submitter's stack
    /// frame is free to die), so the failure span goes in first.
    fn complete(&self, failed_span: Option<Range<usize>>) {
        if let Some(span) = failed_span {
            self.failed
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(span);
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = self.done.lock().unwrap_or_else(PoisonError::into_inner);
            *done = true;
            self.cv.notify_all();
        }
    }

    /// Block until every task completed; returns the failed spans (sorted).
    fn wait(&self) -> Vec<Range<usize>> {
        let mut done = self.done.lock().unwrap_or_else(PoisonError::into_inner);
        while !*done {
            done = self
                .cv
                .wait(done)
                .unwrap_or_else(PoisonError::into_inner);
        }
        drop(done);
        let mut failed = std::mem::take(
            &mut *self.failed.lock().unwrap_or_else(PoisonError::into_inner),
        );
        failed.sort_by_key(|r| r.start);
        failed
    }
}

/// One ring slot: `seq` is the Vyukov sequence counter that encodes whether
/// the slot is free for the producer (`seq == pos`) or holds a value for
/// the consumer (`seq == pos + 1`).
struct Slot {
    seq: AtomicUsize,
    task: UnsafeCell<MaybeUninit<Task>>,
}

/// Bounded lock-free MPMC task queue (Vyukov ring) with condvar parking
/// for idle workers. The data path (push/try_pop) takes no lock; the
/// park/wake path touches a mutex only when a worker is actually asleep.
struct TaskQueue {
    slots: Box<[Slot]>,
    mask: usize,
    /// Consumer cursor.
    head: AtomicUsize,
    /// Producer cursor.
    tail: AtomicUsize,
    /// Workers currently parked (read/written around SeqCst fences — see
    /// `wake_one` for the handshake).
    sleepers: AtomicUsize,
    park: Mutex<()>,
    wake: Condvar,
}

// SAFETY: slot payloads are published/claimed through the `seq` acquire/
// release protocol; a slot's UnsafeCell is only touched by the single
// producer or consumer that won the corresponding CAS.
unsafe impl Sync for TaskQueue {}
unsafe impl Send for TaskQueue {}

impl TaskQueue {
    fn new(capacity: usize) -> TaskQueue {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[Slot]> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                task: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        TaskQueue {
            slots,
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            sleepers: AtomicUsize::new(0),
            park: Mutex::new(()),
            wake: Condvar::new(),
        }
    }

    /// Lock-free bounded push. `Err(task)` hands the task back on a full
    /// ring (the caller runs it inline — backpressure, not blocking).
    fn push(&self, task: Task) -> Result<(), Task> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS claimed slot `pos` exclusively for
                        // this producer; consumers wait for the seq store.
                        unsafe { (*slot.task.get()).write(task) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        self.wake_one();
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return Err(task); // full
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Lock-free pop; `None` when empty.
    fn try_pop(&self) -> Option<Task> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - (pos + 1) as isize;
            if dif == 0 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS claimed slot `pos` exclusively for
                        // this consumer; the producer's Release store made
                        // the payload visible.
                        let task = unsafe { (*slot.task.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(task);
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return None; // empty
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }

    /// Tasks currently queued (approximate — racy by nature, telemetry
    /// only).
    fn depth(&self) -> usize {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Relaxed);
        tail.saturating_sub(head)
    }

    fn wake_one(&self) {
        // Eventcount handshake (store-buffering/Dekker shape): the caller
        // published the task (`seq` Release store), then fences SeqCst and
        // loads `sleepers`; the sleeper increments `sleepers`, fences
        // SeqCst, then re-checks the queue. The two SeqCst fences order the
        // sides so that either this load observes the sleeper (and we
        // notify under the park lock), or the sleeper's re-check observes
        // the published task. The long timed wait in `pop_blocking` is a
        // belt-and-braces backstop, not a correctness requirement.
        std::sync::atomic::fence(Ordering::SeqCst);
        if self.sleepers.load(Ordering::Relaxed) > 0 {
            let _g = self.park.lock().unwrap_or_else(PoisonError::into_inner);
            self.wake.notify_one();
        }
    }

    fn wake_all(&self) {
        let _g = self.park.lock().unwrap_or_else(PoisonError::into_inner);
        self.wake.notify_all();
    }

    /// Worker-side pop: spin briefly, then park. Returns `None` only when
    /// `shutdown` is set AND the queue has drained — queued work is always
    /// finished before a worker exits, so no submitter is left waiting on a
    /// latch that nobody will hit.
    fn pop_blocking(&self, shutdown: &AtomicBool) -> Option<Task> {
        loop {
            for spin in 0..96u32 {
                if let Some(t) = self.try_pop() {
                    return Some(t);
                }
                if spin % 16 == 15 {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
            }
            let guard = self.park.lock().unwrap_or_else(PoisonError::into_inner);
            self.sleepers.fetch_add(1, Ordering::Relaxed);
            // Advertise the sleep, THEN re-check the queue — the SeqCst
            // fence pairs with the one in `wake_one` (see there), so a push
            // racing this park is seen by exactly one side.
            std::sync::atomic::fence(Ordering::SeqCst);
            if let Some(t) = self.try_pop() {
                self.sleepers.fetch_sub(1, Ordering::Relaxed);
                return Some(t);
            }
            if shutdown.load(Ordering::SeqCst) {
                self.sleepers.fetch_sub(1, Ordering::Relaxed);
                return None;
            }
            // The fence handshake makes wakeups reliable; the long timeout
            // only bounds the damage of an OS-level anomaly. Idle workers
            // wake ~20×/s instead of spinning.
            let (guard, _) = self
                .wake
                .wait_timeout(guard, Duration::from_millis(50))
                .unwrap_or_else(PoisonError::into_inner);
            self.sleepers.fetch_sub(1, Ordering::Relaxed);
            drop(guard);
        }
    }
}

/// State shared between the pool handle and its workers.
struct PoolShared {
    queue: TaskQueue,
    /// Registered forests, indexed by [`ModelId`]. Workers read-lock once
    /// per (shard, model) to materialize their replica, never in the steady
    /// state.
    registry: RwLock<Vec<Arc<FlatForest>>>,
    shutdown: AtomicBool,
    stats: ShardStats,
    min_task_rows: usize,
}

impl PoolShared {
    fn forest(&self, model: u32) -> Arc<FlatForest> {
        self.registry
            .read()
            .unwrap_or_else(PoisonError::into_inner)[model as usize]
            .clone()
    }
}

/// The persistent shard-per-core serving engine. See the module docs.
pub struct ShardPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_shards: usize,
}

impl ShardPool {
    /// Spawn the pool (empty registry) with default configuration.
    pub fn new(n_shards: usize) -> ShardPool {
        ShardPool::with_config(ShardPoolConfig {
            n_shards,
            ..Default::default()
        })
    }

    pub fn with_config(cfg: ShardPoolConfig) -> ShardPool {
        let n_shards = cfg.n_shards.max(1);
        let shared = Arc::new(PoolShared {
            queue: TaskQueue::new(cfg.queue_capacity),
            registry: RwLock::new(Vec::new()),
            shutdown: AtomicBool::new(false),
            stats: ShardStats::new(n_shards),
            min_task_rows: cfg.min_task_rows.max(1),
        });
        let workers = (0..n_shards)
            .map(|shard| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("shard-{shard}"))
                    .spawn(move || worker_loop(shard, shared))
                    .expect("spawn shard worker")
            })
            .collect();
        ShardPool {
            shared,
            workers,
            n_shards,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Per-shard occupancy / queue-depth telemetry.
    pub fn stats(&self) -> &ShardStats {
        &self.shared.stats
    }

    /// Tasks currently queued (telemetry gauge).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Register a forest; tenants keep the returned id. Safe while the pool
    /// is serving — workers materialize their replica of the new model
    /// lazily on first use.
    pub fn register(&self, forest: FlatForest) -> ModelId {
        let mut reg = self
            .shared
            .registry
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        let id = reg.len() as u32;
        reg.push(Arc::new(forest));
        ModelId(id)
    }

    /// Feature width of a registered model.
    pub fn n_features(&self, model: ModelId) -> usize {
        self.shared.forest(model.0).n_features
    }

    /// Score `out.len()` rows of flat row-major `rows` (width `row_len`)
    /// with `model`, sharded across the pool. Blocks until every shard
    /// completed. Returns the row spans whose shard **panicked** (their
    /// `out` values are untouched garbage); an empty vec means every row
    /// was served. Bit-identical to a single-threaded
    /// [`FlatForest::predict_flat_rows`] over the same buffer.
    pub fn predict_spans(
        &self,
        model: ModelId,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
    ) -> Vec<Range<usize>> {
        let n = out.len();
        assert!(rows.len() >= n * row_len, "rows buffer shorter than n*row_len");
        if n == 0 {
            return Vec::new();
        }
        let shared = &*self.shared;
        // Per-shard sub-ranges: never more tasks than shards, never fewer
        // than min_task_rows rows per task (a tiny batch stays whole).
        let tasks = (n / shared.min_task_rows).clamp(1, self.n_shards);
        let chunk = n.div_ceil(tasks);
        let n_tasks = n.div_ceil(chunk);
        let latch = BatchLatch::new(n_tasks);
        shared
            .stats
            .spans_submitted
            .fetch_add(n_tasks as u64, Ordering::Relaxed);

        let rows_ptr = rows.as_ptr();
        let out_ptr = out.as_mut_ptr();
        let mut start = 0usize;
        while start < n {
            let len = chunk.min(n - start);
            // SAFETY (task lifetime): `latch.wait()` below does not return
            // until every task called `complete`, and workers never touch a
            // task's pointers after completing it — so `rows`, `out`, and
            // `latch` strictly outlive all uses. Output sub-slices are
            // disjoint by construction.
            let task = Task {
                model: model.0,
                rows: unsafe { rows_ptr.add(start * row_len) },
                rows_len: len * row_len,
                row_len,
                n: len,
                out: unsafe { out_ptr.add(start) },
                span_start: start,
                batch: &latch,
            };
            if let Err(task) = shared.queue.push(task) {
                // Full queue: run inline on the submitter (backpressure —
                // the request path must not deadlock behind a wedged ring).
                shared.stats.inline_runs.fetch_add(1, Ordering::Relaxed);
                run_task(task, &shared.forest(model.0), &mut ForestScratch::default(), shared);
            }
            start += len;
        }
        shared.stats.note_queue_depth(shared.queue.depth());
        latch.wait()
    }

    /// Like [`ShardPool::predict_spans`], but collapses shard failures into
    /// one error (the whole-batch contract the RPC batcher had before
    /// per-shard granularity existed).
    pub fn predict(
        &self,
        model: ModelId,
        rows: &[f32],
        row_len: usize,
        out: &mut [f32],
    ) -> Result<(), ShardPanic> {
        let failed = self.predict_spans(model, rows, row_len, out);
        if failed.is_empty() {
            Ok(())
        } else {
            Err(ShardPanic { spans: failed })
        }
    }
}

/// One or more shards panicked while serving a batch.
#[derive(Debug, Clone)]
pub struct ShardPanic {
    /// The failed row spans.
    pub spans: Vec<Range<usize>>,
}

impl std::fmt::Display for ShardPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard panic on row spans {:?}", self.spans)
    }
}

impl std::error::Error for ShardPanic {}

impl Drop for ShardPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue.wake_all();
        for w in self.workers.drain(..) {
            // Workers drain the queue before exiting, so queued batches
            // complete rather than strand their submitters.
            self.shared.queue.wake_all();
            let _ = w.join();
        }
    }
}

/// Execute one task against `forest`, containing panics to the task's span.
fn run_task(task: Task, forest: &FlatForest, scratch: &mut ForestScratch, shared: &PoolShared) {
    // SAFETY: see the lifetime argument in `predict_spans` — the submitter
    // blocks on the latch, so these borrows are live, and no other task
    // writes this output range.
    let rows = unsafe { std::slice::from_raw_parts(task.rows, task.rows_len) };
    let out = unsafe { std::slice::from_raw_parts_mut(task.out, task.n) };
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        forest.predict_flat_rows(rows, task.row_len, scratch, out);
    }));
    let failed_span = match r {
        Ok(()) => None,
        Err(_) => {
            shared.stats.shard_panics.fetch_add(1, Ordering::Relaxed);
            Some(task.span_start..task.span_start + task.n)
        }
    };
    // SAFETY: the latch outlives the submitter's wait; `complete` is the
    // LAST touch (nothing may follow the final countdown).
    unsafe { (*task.batch).complete(failed_span) };
}

fn worker_loop(shard: usize, shared: Arc<PoolShared>) {
    // Per-shard model replicas, materialized on first use: a deep clone of
    // the registered forest, allocated by THIS thread (locality), indexed
    // by model id. The scratch is shared across models — it is cleared per
    // call.
    let mut replicas: Vec<Option<FlatForest>> = Vec::new();
    let mut scratch = ForestScratch::default();
    while let Some(task) = shared.queue.pop_blocking(&shared.shutdown) {
        shared.stats.set_busy(shard, true);
        let model = task.model as usize;
        if replicas.len() <= model {
            replicas.resize_with(model + 1, || None);
        }
        if replicas[model].is_none() {
            replicas[model] = Some((*shared.forest(task.model)).clone());
        }
        let forest = replicas[model].as_ref().expect("replica just materialized");
        // Count the task BEFORE running it: `run_task` hits the completion
        // latch, and a submitter returning from `wait()` must observe
        // stats that already include every task of its batch.
        shared.stats.record_task(shard);
        run_task(task, forest, &mut scratch, &shared);
        shared.stats.set_busy(shard, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::flat::FlatNode;
    use crate::gbdt::{train, GbdtParams, LEAF};
    use crate::tabular::{Dataset, RowBlock, Schema};
    use crate::util::rng::Rng;

    fn trained() -> (crate::gbdt::GbdtModel, Dataset) {
        let mut rng = Rng::new(41);
        let mut d = Dataset::new(Schema::numeric(5));
        for _ in 0..2500 {
            let x: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
            let y = (x[0] * x[1] - x[3] > 0.1) as u8 as f32;
            d.push_row(&x, y);
        }
        let m = train(&d, &GbdtParams { n_trees: 15, max_depth: 5, ..Default::default() });
        (m, d)
    }

    /// A forest that panics (out-of-bounds feature read) on any row with
    /// `x[0] == f32::INFINITY` and returns sigmoid(base + 0.2) otherwise.
    fn poison_forest(n_features: usize) -> FlatForest {
        FlatForest {
            nodes: vec![
                // root: x[0] <= 1e30 → left leaf; else → poison node.
                FlatNode { feat: 0, thresh: 1e30, lo: 1, value: 0.0 },
                FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: 0.2 },
                // Feature index far past any row width: the arena read of
                // rows[r*row_len + 9_999_999] panics (slice bounds check).
                FlatNode { feat: 9_999_999, thresh: 0.0, lo: 3, value: 0.0 },
                FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: 0.0 },
                FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: 0.0 },
            ],
            roots: vec![0],
            base_score: 0.0,
            n_features,
        }
    }

    fn flat_rows(d: &Dataset, n: usize) -> (Vec<f32>, usize) {
        let row_len = d.n_features();
        let mut rows = vec![0f32; n * row_len];
        let mut row = Vec::new();
        for r in 0..n {
            d.row_into(r, &mut row);
            rows[r * row_len..(r + 1) * row_len].copy_from_slice(&row);
        }
        (rows, row_len)
    }

    /// Acceptance property: scalar, block, and pooled paths agree
    /// bit-for-bit — across shard counts, batch sizes, and NaN rows.
    #[test]
    fn pooled_matches_scalar_and_block_bitwise() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let (mut rows, row_len) = flat_rows(&d, 300);
        // NaN rows must route identically on every path.
        for f in 0..row_len {
            rows[17 * row_len + f] = f32::NAN;
        }
        rows[205 * row_len + 2] = f32::NAN;

        let mut scratch = ForestScratch::default();
        for &shards in &[1usize, 2, 4] {
            let pool = ShardPool::with_config(ShardPoolConfig {
                n_shards: shards,
                min_task_rows: 16, // engage sharding at these test sizes
                ..Default::default()
            });
            let id = pool.register(flat.clone());
            for &n in &[1usize, 15, 16, 64, 300] {
                let mut pooled = vec![0f32; n];
                let failed = pool.predict_spans(id, &rows[..n * row_len], row_len, &mut pooled);
                assert!(failed.is_empty(), "shards={shards} n={n}: {failed:?}");
                // Reference: single-threaded flat path (itself pinned
                // bit-identical to GbdtModel::predict_one by flat.rs tests).
                let mut reference = vec![0f32; n];
                flat.predict_flat_rows(&rows[..n * row_len], row_len, &mut scratch, &mut reference);
                for r in 0..n {
                    assert_eq!(
                        pooled[r].to_bits(),
                        reference[r].to_bits(),
                        "shards={shards} n={n} row={r}"
                    );
                }
                // And against the columnar block path.
                let mut block = RowBlock::new();
                block.fill_from_flat(&rows, n, row_len);
                let mut via_block = Vec::new();
                flat.predict_block(&block, &mut scratch, &mut via_block);
                for r in 0..n {
                    assert_eq!(pooled[r].to_bits(), via_block[r].to_bits(), "block n={n} row={r}");
                }
            }
        }
    }

    #[test]
    fn fault_injection_fails_only_the_poisoned_shard_span() {
        let row_len = 4;
        let n = 256;
        let pool = ShardPool::with_config(ShardPoolConfig {
            n_shards: 4,
            min_task_rows: 64,
            ..Default::default()
        });
        let id = pool.register(poison_forest(row_len));
        let mut rows = vec![0.5f32; n * row_len];
        // Mark one row in the third shard's sub-range (rows 128..192).
        rows[150 * row_len] = f32::INFINITY;
        let mut out = vec![-1f32; n];
        let failed = pool.predict_spans(id, &rows, row_len, &mut out);
        assert_eq!(failed, vec![128..192], "exactly the poisoned shard's span");
        let expected = crate::util::sigmoid(0.2) as f32;
        for (r, &p) in out.iter().enumerate() {
            if (128..192).contains(&r) {
                continue; // failed span: contents unspecified
            }
            assert_eq!(p.to_bits(), expected.to_bits(), "row {r} outside the failed span");
        }
        assert_eq!(pool.stats().panics(), 1);

        // Subsequent submissions succeed on ALL shards — the panic did not
        // wedge the queue or kill a worker.
        for round in 0..3 {
            let clean = vec![0.5f32; n * row_len];
            let mut out = vec![0f32; n];
            let failed = pool.predict_spans(id, &clean, row_len, &mut out);
            assert!(failed.is_empty(), "round {round}");
            assert!(out.iter().all(|p| p.to_bits() == expected.to_bits()));
        }
        // Every sub-range task of every batch completed despite the panic.
        assert_eq!(pool.stats().spans_completed(), 16);
    }

    #[test]
    fn multi_tenant_models_share_one_pool() {
        let (m1, d) = trained();
        let m2 = train(
            &d,
            &GbdtParams { n_trees: 9, max_depth: 3, seed: 99, ..Default::default() },
        );
        let f1 = FlatForest::from_model(&m1);
        let f2 = FlatForest::from_model(&m2);
        let pool = Arc::new(ShardPool::with_config(ShardPoolConfig {
            n_shards: 3,
            min_task_rows: 32,
            ..Default::default()
        }));
        let id1 = pool.register(f1);
        let id2 = pool.register(f2);
        assert_ne!(id1, id2);
        assert_eq!(pool.n_features(id1), d.n_features());

        let (rows, row_len) = flat_rows(&d, 200);
        // Both tenants submit concurrently; each must get ITS model's
        // predictions, bit-identical to the scalar path.
        std::thread::scope(|s| {
            for (id, model) in [(id1, &m1), (id2, &m2)] {
                let pool = pool.clone();
                let rows = &rows;
                s.spawn(move || {
                    let mut row = Vec::new();
                    for _ in 0..10 {
                        let mut out = vec![0f32; 200];
                        let failed = pool.predict_spans(id, rows, row_len, &mut out);
                        assert!(failed.is_empty());
                        for r in 0..200 {
                            row.clear();
                            row.extend_from_slice(&rows[r * row_len..(r + 1) * row_len]);
                            assert_eq!(
                                out[r].to_bits(),
                                model.predict_one(&row).to_bits(),
                                "tenant {id:?} row {r}"
                            );
                        }
                    }
                });
            }
        });
        // Telemetry saw the traffic.
        assert!(pool.stats().spans_submitted.load(Ordering::Relaxed) > 0);
        // The busy flag clears just AFTER the completion latch opens; give
        // the workers a moment to settle before asserting idleness.
        for _ in 0..200 {
            if pool.stats().busy_shards() == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.stats().busy_shards(), 0, "pool idle after the storm");
    }

    #[test]
    fn tiny_batches_stay_whole_and_empty_is_ok() {
        let (m, d) = trained();
        let pool = ShardPool::new(4);
        let id = pool.register(FlatForest::from_model(&m));
        let (rows, row_len) = flat_rows(&d, 8);
        let mut out = vec![0f32; 8];
        assert!(pool.predict_spans(id, &rows, row_len, &mut out).is_empty());
        let mut row = Vec::new();
        for r in 0..8 {
            d.row_into(r, &mut row);
            assert_eq!(out[r].to_bits(), m.predict_one(&row).to_bits());
        }
        let mut empty: [f32; 0] = [];
        assert!(pool.predict_spans(id, &[], row_len, &mut empty).is_empty());
        assert!(pool.predict(id, &rows, row_len, &mut out).is_ok());
    }

    #[test]
    fn full_queue_degrades_to_inline_runs_not_deadlock() {
        let (m, d) = trained();
        // A 2-slot ring with every batch split into 2 tasks and 6
        // concurrent submitters guarantees push failures.
        let pool = Arc::new(ShardPool::with_config(ShardPoolConfig {
            n_shards: 2,
            queue_capacity: 2,
            min_task_rows: 8,
        }));
        let id = pool.register(FlatForest::from_model(&m));
        let (rows, row_len) = flat_rows(&d, 64);
        std::thread::scope(|s| {
            for _ in 0..6 {
                let pool = pool.clone();
                let rows = &rows;
                s.spawn(move || {
                    for _ in 0..20 {
                        let mut out = vec![0f32; 64];
                        assert!(pool.predict_spans(id, rows, row_len, &mut out).is_empty());
                    }
                });
            }
        });
        let st = pool.stats();
        assert_eq!(
            st.spans_completed() + st.inline_runs.load(Ordering::Relaxed),
            st.spans_submitted.load(Ordering::Relaxed),
            "every span either ran on a shard or inline"
        );
    }

    #[test]
    fn queue_ring_push_pop_fifo_and_bounds() {
        // Direct ring test (no workers): FIFO within a single producer and
        // exact capacity behavior.
        let q = TaskQueue::new(4);
        let latch = BatchLatch::new(usize::MAX); // never opens; tasks are dummies
        let mk = |i: usize| Task {
            model: 0,
            rows: std::ptr::null(),
            rows_len: 0,
            row_len: 0,
            n: 0,
            out: std::ptr::null_mut(),
            span_start: i,
            batch: &latch,
        };
        for i in 0..4 {
            assert!(q.push(mk(i)).is_ok(), "slot {i}");
        }
        assert!(q.push(mk(99)).is_err(), "ring full at capacity");
        assert_eq!(q.depth(), 4);
        for i in 0..4 {
            assert_eq!(q.try_pop().expect("queued").span_start, i);
        }
        assert!(q.try_pop().is_none());
        assert_eq!(q.depth(), 0);
        // Wrap-around keeps working.
        for lap in 0..3 {
            assert!(q.push(mk(lap)).is_ok());
            assert_eq!(q.try_pop().unwrap().span_start, lap);
        }
    }
}
