//! Execution runtime: the engines every prediction runs on.
//!
//! Two engines live here:
//!
//! * [`shard_pool`] — the dependency-free **shard-per-core serving engine**:
//!   one persistent worker thread per shard, each holding its own
//!   [`FlatForest`](crate::gbdt::FlatForest) replica and scratch, fed by
//!   per-shard bounded lock-free MPMC task rings with work-stealing and
//!   streamed sub-range completion. This is the default second-stage
//!   execution substrate (the native backend and the embedded multi-tenant
//!   mode both serve from it) and is always compiled.
//! * [`worker`] / [`engine`] — the PJRT engine executing the AOT-compiled
//!   XLA artifacts. Compiled only with `--features pjrt`. The `xla`
//!   bindings are not on crates.io, so un-vendored builds type-check
//!   against the typed stub in `xla_shim` (the `cargo check --features
//!   pjrt` CI gate) and fail fast at runtime; see `Cargo.toml`.

pub mod shard_pool;

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(feature = "pjrt")]
mod xla_shim;
#[cfg(feature = "pjrt")]
pub mod worker;

#[cfg(feature = "pjrt")]
pub use engine::{kernel_inputs_for, Engine, ForestParams, Graph, Shapes};
#[cfg(feature = "pjrt")]
pub use worker::EngineWorker;

pub use shard_pool::{
    ModelId, ShadowJob, ShadowOutcome, ShardPool, ShardPoolConfig, SpanSink, VersionLease,
    STEAL_GRAIN,
};
