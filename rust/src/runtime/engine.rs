//! PJRT engine — loads the AOT HLO-text artifacts and executes them.
//!
//! The compile path (`python/compile/aot.py`) runs once at build time; this
//! module is the only place the serving stack touches XLA: CPU PJRT client →
//! `HloModuleProto::from_text_file` → compile → execute. One compiled
//! executable per (graph, batch-variant); the runtime picks the smallest
//! variant ≥ the live batch and pads.

use crate::gbdt::ForestTensors;
use crate::lrwbins::tables::{KernelInputs, ServingTables};
use crate::util::json::Json;
// The XLA bindings are not on crates.io; builds without them type-check
// against the stub (and fail fast at runtime). To run the real engine,
// vendor the bindings, add the `xla` dependency, and DELETE this import —
// the `xla::` paths below then resolve to the real crate. See the
// `xla_shim` module docs and the Cargo.toml header.
use super::xla_shim as xla;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Padded artifact shapes (mirror of `python/compile/model.py::Shapes`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shapes {
    pub f_max: usize,
    pub nb_max: usize,
    pub q_max: usize,
    pub nf_max: usize,
    pub bins_max: usize,
    pub t_max: usize,
    pub depth: usize,
}

impl Shapes {
    pub fn ni(&self) -> usize {
        (1 << self.depth) - 1
    }
    pub fn nl(&self) -> usize {
        1 << self.depth
    }

    fn from_manifest(j: &Json) -> Result<Shapes> {
        let s = j.get("shapes").ok_or_else(|| anyhow!("manifest: no shapes"))?;
        let get = |k: &str| {
            s.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest: missing shapes.{k}"))
        };
        Ok(Shapes {
            f_max: get("f_max")?,
            nb_max: get("nb_max")?,
            q_max: get("q_max")?,
            nf_max: get("nf_max")?,
            bins_max: get("bins_max")?,
            t_max: get("t_max")?,
            depth: get("depth")?,
        })
    }
}

/// A compiled executable for one batch variant.
struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
}

/// Which graph to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Graph {
    FirstStage,
    SecondStage,
    Multistage,
}

impl Graph {
    fn key(&self) -> &'static str {
        match self {
            Graph::FirstStage => "first_stage",
            Graph::SecondStage => "second_stage",
            Graph::Multistage => "multistage",
        }
    }
}

/// The PJRT engine: client + compiled batch variants per graph.
pub struct Engine {
    client: xla::PjRtClient,
    pub shapes: Shapes,
    artifacts: BTreeMap<(Graph, usize), Artifact>,
    dir: PathBuf,
}

impl Engine {
    /// Load the manifest and compile the requested graphs (all batch
    /// variants listed in the manifest).
    pub fn load(artifacts_dir: &Path, graphs: &[Graph]) -> Result<Engine> {
        let manifest_path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let shapes = Shapes::from_manifest(&manifest)?;

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut engine = Engine {
            client,
            shapes,
            artifacts: BTreeMap::new(),
            dir: artifacts_dir.to_path_buf(),
        };
        let arts = manifest
            .get("artifacts")
            .ok_or_else(|| anyhow!("manifest: no artifacts"))?;
        for &g in graphs {
            let per_batch = arts
                .get(g.key())
                .ok_or_else(|| anyhow!("manifest: no {} artifacts", g.key()))?;
            if let Json::Obj(o) = per_batch {
                for (bstr, fname) in o.iter() {
                    let batch: usize = bstr.parse().map_err(|_| anyhow!("bad batch {bstr}"))?;
                    let fname = fname.as_str().ok_or_else(|| anyhow!("bad artifact name"))?;
                    engine.compile_artifact(g, batch, fname)?;
                }
            } else {
                bail!("manifest: artifacts.{} not an object", g.key());
            }
        }
        Ok(engine)
    }

    fn compile_artifact(&mut self, g: Graph, batch: usize, fname: &str) -> Result<()> {
        let path = self.dir.join(fname);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {fname}"))?;
        self.artifacts.insert((g, batch), Artifact { exe, batch });
        Ok(())
    }

    /// Batch variants available for a graph (ascending).
    pub fn variants(&self, g: Graph) -> Vec<usize> {
        self.artifacts
            .keys()
            .filter(|(gg, _)| *gg == g)
            .map(|(_, b)| *b)
            .collect()
    }

    /// Smallest compiled variant ≥ n, or the largest one (caller chunks).
    fn pick(&self, g: Graph, n: usize) -> Result<&Artifact> {
        let mut best: Option<&Artifact> = None;
        let mut largest: Option<&Artifact> = None;
        for ((gg, _), a) in self.artifacts.iter() {
            if *gg != g {
                continue;
            }
            if a.batch >= n && best.map_or(true, |b| a.batch < b.batch) {
                best = Some(a);
            }
            if largest.map_or(true, |l| a.batch > l.batch) {
                largest = Some(a);
            }
        }
        best.or(largest)
            .ok_or_else(|| anyhow!("no artifact for {:?}", g))
    }

    fn lit_f32(v: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(v).reshape(dims)?)
    }

    fn lit_i32(v: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(v).reshape(dims)?)
    }

    /// Execute the second-stage forest over a batch of padded feature rows
    /// (`rows.len() == n * f_max`). Returns `n` probabilities.
    pub fn second_stage(&self, rows: &[f32], n: usize, forest: &ForestParams) -> Result<Vec<f32>> {
        let s = &self.shapes;
        debug_assert_eq!(rows.len(), n * s.f_max);
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let art = self.pick(Graph::SecondStage, n - start)?;
            let take = art.batch.min(n - start);
            let mut x = vec![0f32; art.batch * s.f_max];
            x[..take * s.f_max].copy_from_slice(&rows[start * s.f_max..(start + take) * s.f_max]);
            let args = [
                Self::lit_f32(&x, &[art.batch as i64, s.f_max as i64])?,
                Self::lit_i32(&forest.feat, &[s.t_max as i64, s.ni() as i64])?,
                Self::lit_f32(&forest.thresh, &[s.t_max as i64, s.ni() as i64])?,
                Self::lit_f32(&forest.leaf, &[s.t_max as i64, s.nl() as i64])?,
                Self::lit_f32(&[forest.base_score], &[1])?,
            ];
            let result = art.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let probs = result.to_tuple1()?.to_vec::<f32>()?;
            out.extend_from_slice(&probs[..take]);
            start += take;
        }
        Ok(out)
    }

    /// Execute the first-stage artifact (cross-check path). Returns
    /// `(probs, accept)` for `n` rows.
    pub fn first_stage(
        &self,
        rows: &[f32],
        n: usize,
        k: &KernelInputs,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let s = &self.shapes;
        debug_assert_eq!(rows.len(), n * s.f_max);
        assert_eq!(k.nb_max, s.nb_max);
        assert_eq!(k.q_max, s.q_max);
        assert_eq!(k.nf_max, s.nf_max);
        assert_eq!(k.bins_max, s.bins_max);
        let mut probs_out = Vec::with_capacity(n);
        let mut accept_out = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let art = self.pick(Graph::FirstStage, n - start)?;
            let take = art.batch.min(n - start);
            let mut x = vec![0f32; art.batch * s.f_max];
            x[..take * s.f_max].copy_from_slice(&rows[start * s.f_max..(start + take) * s.f_max]);
            let args = [
                Self::lit_f32(&x, &[art.batch as i64, s.f_max as i64])?,
                Self::lit_i32(&k.bin_features, &[s.nb_max as i64])?,
                Self::lit_f32(&k.quantiles, &[s.nb_max as i64, s.q_max as i64])?,
                Self::lit_i32(&k.strides, &[s.nb_max as i64])?,
                Self::lit_i32(&k.infer_features, &[s.nf_max as i64])?,
                Self::lit_f32(&k.weights, &[s.bins_max as i64, (s.nf_max + 1) as i64])?,
                Self::lit_f32(&k.route, &[s.bins_max as i64])?,
            ];
            let result = art.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let (p, a) = result.to_tuple2()?;
            let p = p.to_vec::<f32>()?;
            let a = a.to_vec::<f32>()?;
            probs_out.extend_from_slice(&p[..take]);
            accept_out.extend_from_slice(&a[..take]);
            start += take;
        }
        Ok((probs_out, accept_out))
    }

    /// Pad a raw feature row to `f_max` for the second-stage artifact
    /// (raw values — trees split raw space).
    pub fn pad_row(&self, row: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.shapes.f_max];
        out[..row.len()].copy_from_slice(row);
        out
    }
}

/// Forest tensors padded to the artifact shapes.
#[derive(Clone, Debug)]
pub struct ForestParams {
    pub feat: Vec<i32>,
    pub thresh: Vec<f32>,
    pub leaf: Vec<f32>,
    pub base_score: f32,
}

impl ForestParams {
    /// Pad a trained forest to the artifact shapes.
    pub fn from_tensors(ft: &ForestTensors, shapes: &Shapes) -> Result<ForestParams> {
        if ft.depth != shapes.depth {
            bail!("forest depth {} != artifact depth {}", ft.depth, shapes.depth);
        }
        if ft.n_trees > shapes.t_max {
            bail!("forest has {} trees > artifact t_max {}", ft.n_trees, shapes.t_max);
        }
        if ft.n_features > shapes.f_max {
            bail!("forest features {} > f_max {}", ft.n_features, shapes.f_max);
        }
        let padded = ft.padded(shapes.t_max, shapes.f_max);
        Ok(ForestParams {
            feat: padded.feat,
            thresh: padded.thresh,
            leaf: padded.leaf,
            base_score: padded.base_score,
        })
    }
}

/// Convenience: kernel inputs for the first-stage artifact from serving
/// tables, using the engine's shapes.
pub fn kernel_inputs_for(tables: &ServingTables, shapes: &Shapes) -> KernelInputs {
    tables.kernel_inputs(shapes.nb_max, shapes.q_max, shapes.nf_max, shapes.bins_max)
}
