//! Gradient-boosted decision trees — the second-stage model substrate.
//!
//! The paper uses XGBoost as the strong fallback model served behind RPC;
//! no ML crates exist offline, so this is a from-scratch histogram GBDT with
//! second-order logistic loss (`train`), fast native inference (`predict_*`),
//! a contiguous batched serving image ([`flat::FlatForest`] — the RPC
//! backend's hot path), gain-based feature importance, JSON
//! (de)serialization for the service config, and a dense tensor export
//! consumed by the Pallas forest kernel.

pub mod binner;
pub mod flat;
pub mod train;
pub mod tree;

pub use binner::FeatureBinner;
pub use flat::{FlatForest, FlatNode, ForestScratch, ForestView};
pub use train::train;
pub use tree::{DenseTree, Node, Tree, LEAF};

use crate::tabular::Dataset;
use crate::util::json::Json;
use crate::util::sigmoid;

/// Training hyper-parameters (XGBoost-style names).
#[derive(Clone, Debug)]
pub struct GbdtParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub learning_rate: f64,
    /// L2 on leaf values.
    pub lambda: f64,
    /// Minimum split gain.
    pub gamma: f64,
    /// Minimum hessian sum per child.
    pub min_child_weight: f64,
    /// Row subsample fraction per tree.
    pub subsample: f64,
    /// Column subsample fraction per tree.
    pub colsample: f64,
    /// Histogram bins per feature (≤ 256).
    pub max_bins: usize,
    pub seed: u64,
    /// Worker threads for histogram building.
    pub threads: usize,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            n_trees: 60,
            max_depth: 6,
            learning_rate: 0.15,
            lambda: 1.0,
            gamma: 0.0,
            min_child_weight: 1.0,
            subsample: 1.0,
            colsample: 1.0,
            max_bins: 64,
            seed: 7,
            threads: crate::util::threadpool::default_threads(),
        }
    }
}

impl GbdtParams {
    /// Smaller/faster preset for tests and quick benches.
    pub fn quick() -> GbdtParams {
        GbdtParams {
            n_trees: 25,
            max_depth: 4,
            learning_rate: 0.2,
            ..Default::default()
        }
    }
}

/// A trained GBDT: margin = base_score + Σ tree_i(x); p = sigmoid(margin).
#[derive(Clone, Debug)]
pub struct GbdtModel {
    pub trees: Vec<Tree>,
    pub base_score: f64,
    pub n_features: usize,
    /// Accumulated split gain per feature (importance ranking).
    pub feature_gain: Vec<f64>,
    /// Depth bound used at training time (dense export depth).
    pub max_depth: usize,
}

impl GbdtModel {
    /// Margin for one row.
    #[inline]
    pub fn predict_margin_one(&self, row: &[f32]) -> f64 {
        let mut m = self.base_score;
        for t in &self.trees {
            m += t.predict_one(row) as f64;
        }
        m
    }

    /// Probability for one row.
    #[inline]
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        sigmoid(self.predict_margin_one(row)) as f32
    }

    /// Flatten into the contiguous serving image (see [`flat::FlatForest`]).
    pub fn flatten(&self) -> FlatForest {
        FlatForest::from_model(self)
    }

    /// Probabilities for a whole dataset.
    pub fn predict_proba(&self, data: &Dataset) -> Vec<f32> {
        let n = data.n_rows();
        let mut out = Vec::with_capacity(n);
        let mut row = Vec::with_capacity(self.n_features);
        for r in 0..n {
            data.row_into(r, &mut row);
            out.push(self.predict_one(&row));
        }
        out
    }

    /// Features ranked by decreasing gain importance.
    pub fn importance_ranking(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.n_features).collect();
        idx.sort_by(|&a, &b| {
            self.feature_gain[b]
                .partial_cmp(&self.feature_gain[a])
                .unwrap()
        });
        idx
    }

    /// Export the whole forest as dense tensors for the PJRT/Pallas forest
    /// kernel: shapes `[n_trees, 2^D-1]` (feat/thresh) and `[n_trees, 2^D]`
    /// (leaf), flattened row-major. Features index into the *full* feature
    /// vector.
    pub fn to_forest_tensors(&self) -> ForestTensors {
        self.to_forest_tensors_at(self.max_depth)
    }

    /// Dense export at an explicit depth ≥ the trained depth (artifact
    /// shapes are fixed; shallower forests pad with always-left splits).
    pub fn to_forest_tensors_at(&self, depth: usize) -> ForestTensors {
        assert!(depth >= self.max_depth, "export depth too shallow");
        let ni = (1usize << depth) - 1;
        let nl = 1usize << depth;
        let nt = self.trees.len();
        let mut feat = Vec::with_capacity(nt * ni);
        let mut thresh = Vec::with_capacity(nt * ni);
        let mut leaf = Vec::with_capacity(nt * nl);
        for t in &self.trees {
            let d = t.to_dense(depth);
            feat.extend(d.feat.iter().map(|&f| f as i32));
            thresh.extend_from_slice(&d.thresh);
            leaf.extend_from_slice(&d.leaf);
        }
        ForestTensors {
            n_trees: nt,
            depth,
            n_features: self.n_features,
            base_score: self.base_score as f32,
            feat,
            thresh,
            leaf,
        }
    }

    // ------------------------------------------------------------------
    // JSON (de)serialization — the service loads models from disk.
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("base_score", Json::Num(self.base_score));
        j.set("n_features", Json::Num(self.n_features as f64));
        j.set("max_depth", Json::Num(self.max_depth as f64));
        j.set("feature_gain", Json::from_f64_slice(&self.feature_gain));
        let trees: Vec<Json> = self
            .trees
            .iter()
            .map(|t| {
                let mut tj = Json::obj();
                tj.set(
                    "feat",
                    Json::Arr(t.nodes.iter().map(|n| Json::Num(n.feat as f64)).collect()),
                );
                tj.set(
                    "thresh",
                    Json::from_f32_slice(&t.nodes.iter().map(|n| n.thresh).collect::<Vec<_>>()),
                );
                tj.set(
                    "left",
                    Json::Arr(t.nodes.iter().map(|n| Json::Num(n.left as f64)).collect()),
                );
                tj.set(
                    "right",
                    Json::Arr(t.nodes.iter().map(|n| Json::Num(n.right as f64)).collect()),
                );
                tj.set(
                    "value",
                    Json::from_f32_slice(&t.nodes.iter().map(|n| n.value).collect::<Vec<_>>()),
                );
                tj.set(
                    "gain",
                    Json::from_f32_slice(&t.nodes.iter().map(|n| n.gain).collect::<Vec<_>>()),
                );
                tj
            })
            .collect();
        j.set("trees", Json::Arr(trees));
        j
    }

    pub fn from_json(j: &Json) -> Result<GbdtModel, String> {
        let err = |m: &str| m.to_string();
        let base_score = j.get("base_score").and_then(Json::as_f64).ok_or_else(|| err("base_score"))?;
        let n_features = j.get("n_features").and_then(Json::as_usize).ok_or_else(|| err("n_features"))?;
        let max_depth = j.get("max_depth").and_then(Json::as_usize).ok_or_else(|| err("max_depth"))?;
        let feature_gain = j.get("feature_gain").and_then(|v| v.as_f64_vec()).ok_or_else(|| err("feature_gain"))?;
        let mut trees = Vec::new();
        for tj in j.get("trees").and_then(Json::as_arr).ok_or_else(|| err("trees"))? {
            let get_vec = |k: &str| tj.get(k).and_then(|v| v.as_f64_vec()).ok_or_else(|| err(k));
            let feat = get_vec("feat")?;
            let thresh = get_vec("thresh")?;
            let left = get_vec("left")?;
            let right = get_vec("right")?;
            let value = get_vec("value")?;
            let gain = get_vec("gain")?;
            let nn = feat.len();
            if [thresh.len(), left.len(), right.len(), value.len(), gain.len()]
                .iter()
                .any(|&l| l != nn)
            {
                return Err(err("tree array length mismatch"));
            }
            let nodes = (0..nn)
                .map(|i| tree::Node {
                    feat: feat[i] as u32,
                    thresh: thresh[i] as f32,
                    left: left[i] as u32,
                    right: right[i] as u32,
                    value: value[i] as f32,
                    gain: gain[i] as f32,
                })
                .collect();
            trees.push(Tree { nodes });
        }
        Ok(GbdtModel {
            trees,
            base_score,
            n_features,
            feature_gain,
            max_depth,
        })
    }
}

/// Dense forest tensors (see [`GbdtModel::to_forest_tensors`]).
#[derive(Clone, Debug)]
pub struct ForestTensors {
    pub n_trees: usize,
    pub depth: usize,
    pub n_features: usize,
    pub base_score: f32,
    /// `[n_trees × (2^D - 1)]` split features.
    pub feat: Vec<i32>,
    /// `[n_trees × (2^D - 1)]` split thresholds (`+inf` = always-left pad).
    pub thresh: Vec<f32>,
    /// `[n_trees × 2^D]` leaf values.
    pub leaf: Vec<f32>,
}

impl ForestTensors {
    /// Reference oblivious traversal over the tensors — must match both the
    /// compact trees and the Pallas kernel bit-for-bit.
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        let ni = (1usize << self.depth) - 1;
        let nl = 1usize << self.depth;
        let mut margin = self.base_score;
        for t in 0..self.n_trees {
            let mut k = 0usize;
            for _ in 0..self.depth {
                let f = self.feat[t * ni + k] as usize;
                let th = self.thresh[t * ni + k];
                k = 2 * k + 1 + ((row[f] > th) as usize);
            }
            margin += self.leaf[t * nl + (k - ni)];
        }
        crate::util::sigmoid_f32(margin)
    }

    /// Pad to fixed shapes (serving artifacts use fixed `[T_MAX, …]`).
    pub fn padded(&self, n_trees: usize, n_features: usize) -> ForestTensors {
        assert!(n_trees >= self.n_trees && n_features >= self.n_features);
        let ni = (1usize << self.depth) - 1;
        let nl = 1usize << self.depth;
        let mut out = self.clone();
        out.n_trees = n_trees;
        out.n_features = n_features;
        // Padding trees: always-left to leaf 0 with value 0.
        out.feat.resize(n_trees * ni, 0);
        out.thresh.resize(n_trees * ni, f32::INFINITY);
        out.leaf.resize(n_trees * nl, 0.0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tabular::{Dataset, Schema};
    use crate::util::rng::Rng;

    fn trained() -> (GbdtModel, Dataset) {
        let mut rng = Rng::new(11);
        let mut d = Dataset::new(Schema::numeric(3));
        for _ in 0..1500 {
            let a = rng.normal() as f32;
            let b = rng.normal() as f32;
            let c = rng.normal() as f32;
            let y = (a + b * b > 0.8) as u8 as f32;
            d.push_row(&[a, b, c], y);
        }
        let m = train(&d, &GbdtParams { n_trees: 12, max_depth: 4, ..Default::default() });
        (m, d)
    }

    #[test]
    fn forest_tensors_match_native() {
        let (m, d) = trained();
        let ft = m.to_forest_tensors();
        let mut row = Vec::new();
        for r in 0..200 {
            d.row_into(r, &mut row);
            let native = m.predict_one(&row);
            let dense = ft.predict_one(&row);
            assert!(
                (native - dense).abs() < 2e-6,
                "row {r}: native={native} dense={dense}"
            );
        }
    }

    #[test]
    fn padded_tensors_same_output() {
        let (m, d) = trained();
        let ft = m.to_forest_tensors();
        let padded = ft.padded(ft.n_trees + 5, ft.n_features + 3);
        let mut row = Vec::new();
        for r in 0..50 {
            d.row_into(r, &mut row);
            let mut wide = row.clone();
            wide.resize(ft.n_features + 3, 0.0);
            assert_eq!(ft.predict_one(&row), padded.predict_one(&wide));
        }
    }

    #[test]
    fn json_roundtrip_exact_predictions() {
        let (m, d) = trained();
        let j = m.to_json();
        let m2 = GbdtModel::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(m.predict_proba(&d), m2.predict_proba(&d));
    }

    #[test]
    fn importance_ranking_sorted() {
        let (m, _) = trained();
        let rank = m.importance_ranking();
        for w in rank.windows(2) {
            assert!(m.feature_gain[w[0]] >= m.feature_gain[w[1]]);
        }
        // Noise feature (index 2) should rank last.
        assert_eq!(*rank.last().unwrap(), 2);
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(GbdtModel::from_json(&Json::parse("{}").unwrap()).is_err());
        let j = Json::parse(r#"{"base_score":0,"n_features":1,"max_depth":2,"feature_gain":[0],"trees":[{"feat":[0],"thresh":[],"left":[],"right":[],"value":[],"gain":[]}]}"#).unwrap();
        assert!(GbdtModel::from_json(&j).is_err());
    }
}
