//! Histogram-based gradient-boosting trainer (XGBoost-style).
//!
//! Second-order logistic loss: per-row gradient `g = p - y`, hessian
//! `h = p(1-p)`. Trees grow level-wise to `max_depth`; splits maximize
//!
//! ```text
//! gain = ½ [ G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ) ] − γ
//! ```
//!
//! over quantile-binned features (see `binner.rs`). Row subsampling and
//! column subsampling per tree match the usual stochastic-boosting setup.
//! Histogram building is parallel across features; each (node, feature)
//! task returns only its best split candidate, so memory stays at
//! O(active_nodes × bins) per worker.

use super::binner::FeatureBinner;
use super::tree::{Node, Tree, LEAF};
use super::{GbdtModel, GbdtParams};
use crate::tabular::Dataset;
use crate::util::rng::Rng;
use crate::util::sigmoid;
use crate::util::threadpool::parallel_map;

/// Interleaved histogram cell: one cache line per update.
#[derive(Clone, Copy, Default)]
struct Cell {
    g: f64,
    h: f64,
    c: u32,
}

impl Cell {
    #[inline]
    fn sub(self, other: Cell) -> Cell {
        Cell {
            g: self.g - other.g,
            h: self.h - other.h,
            c: self.c.saturating_sub(other.c),
        }
    }
}

/// Split candidate for one (node, feature).
#[derive(Clone, Copy, Debug)]
struct Candidate {
    gain: f64,
    feat: u32,
    bin: u16,
    g_left: f64,
    h_left: f64,
    n_left: u32,
}

/// Per-active-node aggregate stats.
#[derive(Clone, Copy, Debug, Default)]
struct NodeStats {
    g: f64,
    h: f64,
    n: u32,
    /// Index of this node in the tree being built.
    tree_idx: u32,
}

pub fn train(data: &Dataset, params: &GbdtParams) -> GbdtModel {
    let n = data.n_rows();
    assert!(n > 0, "cannot train on empty data");
    let nf = data.n_features();
    let mut rng = Rng::new(params.seed);

    let binner = FeatureBinner::fit(data, params.max_bins);
    let bins = binner.bin_dataset(data);

    let pos_rate = data.positive_rate().clamp(1e-6, 1.0 - 1e-6);
    let base_score = (pos_rate / (1.0 - pos_rate)).ln();

    let mut margins = vec![base_score; n];
    let mut trees = Vec::with_capacity(params.n_trees);
    let mut feature_gain = vec![0.0f64; nf];
    let threads = params.threads.max(1);

    let mut g = vec![0.0f64; n];
    let mut h = vec![0.0f64; n];

    for _ in 0..params.n_trees {
        // Gradients under current margins.
        for r in 0..n {
            let p = sigmoid(margins[r]);
            g[r] = p - data.labels[r] as f64;
            h[r] = (p * (1.0 - p)).max(1e-16);
        }
        // Row subsample mask.
        let row_in: Vec<bool> = if params.subsample < 1.0 {
            (0..n).map(|_| rng.bool(params.subsample)).collect()
        } else {
            vec![true; n]
        };
        // Column subsample.
        let feats: Vec<usize> = if params.colsample < 1.0 {
            let k = ((nf as f64 * params.colsample).ceil() as usize).clamp(1, nf);
            let mut f = rng.sample_indices(nf, k);
            f.sort_unstable();
            f
        } else {
            (0..nf).collect()
        };

        let tree = build_tree(
            data, &binner, &bins, &g, &h, &row_in, &feats, params, threads, &mut feature_gain,
        );

        // Margin update for ALL rows (including out-of-sample), in parallel
        // over row chunks with a reused row buffer per chunk.
        {
            let margins_slice = &mut margins[..];
            let tree_ref = &tree;
            // Disjoint mutable chunks via chunks_mut, executed on scoped
            // threads; each worker reuses one row buffer.
            let chunk = n.div_ceil(threads.max(1)).max(1);
            std::thread::scope(|s| {
                for (ci, m_chunk) in margins_slice.chunks_mut(chunk).enumerate() {
                    let start = ci * chunk;
                    s.spawn(move || {
                        let mut row = Vec::with_capacity(data.n_features());
                        for (i, m) in m_chunk.iter_mut().enumerate() {
                            data.row_into(start + i, &mut row);
                            *m += tree_ref.predict_one(&row) as f64;
                        }
                    });
                }
            });
        }
        trees.push(tree);
    }

    GbdtModel {
        trees,
        base_score,
        n_features: nf,
        feature_gain,
        max_depth: params.max_depth,
    }
}

#[allow(clippy::too_many_arguments)]
fn build_tree(
    data: &Dataset,
    binner: &FeatureBinner,
    bins: &[Vec<u8>],
    g: &[f64],
    h: &[f64],
    row_in: &[bool],
    feats: &[usize],
    params: &GbdtParams,
    threads: usize,
    feature_gain: &mut [f64],
) -> Tree {
    let n = data.n_rows();
    let lambda = params.lambda;
    let lr = params.learning_rate;

    let mut tree = Tree::default();
    // Root.
    tree.nodes.push(Node {
        feat: LEAF,
        thresh: 0.0,
        left: 0,
        right: 0,
        value: 0.0,
        gain: 0.0,
    });

    // assign[r] = active-frontier index, or -1 if the row is settled/excluded.
    let mut assign: Vec<i32> = row_in.iter().map(|&in_| if in_ { 0 } else { -1 }).collect();

    let mut root = NodeStats { tree_idx: 0, ..Default::default() };
    for r in 0..n {
        if assign[r] == 0 {
            root.g += g[r];
            root.h += h[r];
            root.n += 1;
        }
    }
    let mut frontier = vec![root];
    // Histogram-subtraction bookkeeping: per active node, its parent's index
    // in the previous frontier and its sibling's index in the current one
    // (root has neither). The smaller child of each split accumulates its
    // histogram from rows; the larger derives it as parent − sibling —
    // halving the dominant histogram pass (LightGBM's classic trick).
    let mut parent_of: Vec<i32> = vec![-1];
    let mut sibling_of: Vec<i32> = vec![-1];
    let mut prev_hist: Vec<Vec<Cell>> = vec![Vec::new(); feats.len()];

    for _depth in 0..params.max_depth {
        if frontier.is_empty() {
            break;
        }
        let n_active = frontier.len();
        // Which active nodes accumulate from rows (vs derive from parent)?
        let compute: Vec<bool> = (0..n_active)
            .map(|a| {
                let sib = sibling_of[a];
                if sib < 0 || parent_of[a] < 0 {
                    return true;
                }
                let sib = sib as usize;
                let (na, ns) = (frontier[a].n, frontier[sib].n);
                na < ns || (na == ns && a < sib)
            })
            .collect();

        // --- best split per (feature) across all active nodes, in parallel.
        // Each task builds the histograms for ONE feature over all active
        // nodes, then scans for the best split per node.
        let per_feature: Vec<(Vec<Option<Candidate>>, Vec<Cell>)> = parallel_map(feats.len(), threads, |fi| {
            let f = feats[fi];
            let nb = binner.n_bins(f);
            if nb < 2 {
                return (vec![None; n_active], Vec::new());
            }
            let mut hist = vec![Cell::default(); n_active * nb];
            let col = &bins[f];
            for r in 0..n {
                let a = assign[r];
                if a < 0 || !compute[a as usize] {
                    continue;
                }
                let cell = &mut hist[a as usize * nb + col[r] as usize];
                cell.g += g[r];
                cell.h += h[r];
                cell.c += 1;
            }
            // Derive the larger siblings: parent − computed sibling.
            for a in 0..n_active {
                if compute[a] {
                    continue;
                }
                let parent = parent_of[a] as usize;
                let sib = sibling_of[a] as usize;
                for b in 0..nb {
                    hist[a * nb + b] =
                        prev_hist[fi][parent * nb + b].sub(hist[sib * nb + b]);
                }
            }
            // Scan each node left→right.
            let cands = (0..n_active)
                .map(|a| {
                    let st = &frontier[a];
                    let parent_score = st.g * st.g / (st.h + lambda);
                    let mut gl = 0.0;
                    let mut hl = 0.0;
                    let mut nl = 0u32;
                    let mut best: Option<Candidate> = None;
                    for b in 0..nb - 1 {
                        let cell = &hist[a * nb + b];
                        gl += cell.g;
                        hl += cell.h;
                        nl += cell.c;
                        let gr = st.g - gl;
                        let hr = st.h - hl;
                        let nr = st.n - nl;
                        if hl < params.min_child_weight
                            || hr < params.min_child_weight
                            || nl == 0
                            || nr == 0
                        {
                            continue;
                        }
                        let gain = 0.5
                            * (gl * gl / (hl + lambda) + gr * gr / (hr + lambda) - parent_score)
                            - params.gamma;
                        if gain > best.map_or(0.0, |c| c.gain) {
                            best = Some(Candidate {
                                gain,
                                feat: f as u32,
                                bin: b as u16,
                                g_left: gl,
                                h_left: hl,
                                n_left: nl,
                            });
                        }
                    }
                    best
                })
                .collect();
            (cands, hist)
        });

        // Reduce across features: best candidate per active node; then move
        // (not copy) this level's histograms into the subtraction store.
        let mut best: Vec<Option<Candidate>> = vec![None; n_active];
        for (fc, _) in &per_feature {
            for (a, cand) in fc.iter().enumerate() {
                if let Some(c) = cand {
                    if best[a].map_or(true, |b| c.gain > b.gain) {
                        best[a] = Some(*c);
                    }
                }
            }
        }
        for (fi, (_, hist)) in per_feature.into_iter().enumerate() {
            prev_hist[fi] = hist;
        }

        // Apply splits; build the next frontier.
        // active index → (new left active idx, new right active idx) or leaf.
        let mut next_frontier: Vec<NodeStats> = Vec::new();
        let mut next_parent: Vec<i32> = Vec::new();
        let mut next_sibling: Vec<i32> = Vec::new();
        let mut remap: Vec<[i32; 2]> = Vec::with_capacity(n_active); // per active: children active ids or [-1,-1]
        let mut split_info: Vec<Option<(u32, u8)>> = Vec::with_capacity(n_active); // (feat, bin)

        for a in 0..n_active {
            let st = frontier[a];
            match best[a] {
                Some(c) => {
                    let ti = st.tree_idx as usize;
                    let left_idx = tree.nodes.len() as u32;
                    let right_idx = left_idx + 1;
                    tree.nodes[ti] = Node {
                        feat: c.feat,
                        thresh: binner.edge_value(c.feat as usize, c.bin as usize),
                        left: left_idx,
                        right: right_idx,
                        value: 0.0,
                        gain: c.gain as f32,
                    };
                    feature_gain[c.feat as usize] += c.gain;
                    // children placeholders (leaves until split further)
                    let gl = c.g_left;
                    let hl = c.h_left;
                    let gr = st.g - gl;
                    let hr = st.h - hl;
                    tree.nodes.push(Node {
                        feat: LEAF,
                        thresh: 0.0,
                        left: 0,
                        right: 0,
                        value: (-lr * gl / (hl + lambda)) as f32,
                        gain: 0.0,
                    });
                    tree.nodes.push(Node {
                        feat: LEAF,
                        thresh: 0.0,
                        left: 0,
                        right: 0,
                        value: (-lr * gr / (hr + lambda)) as f32,
                        gain: 0.0,
                    });
                    let la = next_frontier.len() as i32;
                    next_parent.push(a as i32);
                    next_parent.push(a as i32);
                    next_sibling.push(la + 1);
                    next_sibling.push(la);
                    next_frontier.push(NodeStats { g: gl, h: hl, n: c.n_left, tree_idx: left_idx });
                    next_frontier.push(NodeStats {
                        g: gr,
                        h: hr,
                        n: st.n - c.n_left,
                        tree_idx: right_idx,
                    });
                    remap.push([la, la + 1]);
                    split_info.push(Some((c.feat, c.bin as u8)));
                }
                None => {
                    // Becomes a leaf.
                    let ti = st.tree_idx as usize;
                    tree.nodes[ti].feat = LEAF;
                    tree.nodes[ti].value = (-lr * st.g / (st.h + lambda)) as f32;
                    remap.push([-1, -1]);
                    split_info.push(None);
                }
            }
        }

        // Update row assignment.
        for r in 0..n {
            let a = assign[r];
            if a < 0 {
                continue;
            }
            let a = a as usize;
            match split_info[a] {
                Some((f, b)) => {
                    let go_left = bins[f as usize][r] <= b;
                    assign[r] = remap[a][if go_left { 0 } else { 1 }];
                }
                None => assign[r] = -1,
            }
        }
        frontier = next_frontier;
        parent_of = next_parent;
        sibling_of = next_sibling;
    }

    // Any still-active nodes at max depth become leaves.
    for st in &frontier {
        let ti = st.tree_idx as usize;
        tree.nodes[ti].feat = LEAF;
        tree.nodes[ti].value = (-lr * st.g / (st.h + lambda)) as f32;
    }

    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::roc_auc;
    use crate::tabular::{Dataset, Schema};

    fn xor_dataset(n: usize, seed: u64) -> Dataset {
        // XOR: linearly inseparable, trees must get it.
        let mut rng = Rng::new(seed);
        let mut d = Dataset::new(Schema::numeric(2));
        for _ in 0..n {
            let a = rng.normal() as f32;
            let b = rng.normal() as f32;
            let y = ((a > 0.0) != (b > 0.0)) as u8 as f32;
            d.push_row(&[a, b], y);
        }
        d
    }

    #[test]
    fn learns_xor() {
        let d = xor_dataset(4000, 1);
        let m = train(&d, &GbdtParams { n_trees: 20, max_depth: 3, ..Default::default() });
        let preds = m.predict_proba(&d);
        let auc = roc_auc(&preds, &d.labels);
        assert!(auc > 0.95, "auc={auc}");
    }

    #[test]
    fn single_tree_on_step_function() {
        // y = x > 0; one depth-1 tree should nail it.
        let mut d = Dataset::new(Schema::numeric(1));
        let mut rng = Rng::new(2);
        for _ in 0..2000 {
            let x = rng.normal() as f32;
            d.push_row(&[x], (x > 0.0) as u8 as f32);
        }
        let m = train(
            &d,
            &GbdtParams { n_trees: 1, max_depth: 1, learning_rate: 1.0, ..Default::default() },
        );
        assert_eq!(m.trees.len(), 1);
        let preds = m.predict_proba(&d);
        let auc = roc_auc(&preds, &d.labels);
        assert!(auc > 0.99, "auc={auc}");
        // The split threshold should be near 0.
        let root = &m.trees[0].nodes[0];
        assert!(root.thresh.abs() < 0.3, "thresh={}", root.thresh);
    }

    #[test]
    fn respects_max_depth() {
        let d = xor_dataset(1000, 3);
        let m = train(&d, &GbdtParams { n_trees: 5, max_depth: 2, ..Default::default() });
        for t in &m.trees {
            assert!(t.depth() <= 2);
        }
    }

    #[test]
    fn single_class_stays_at_prior() {
        let mut d = Dataset::new(Schema::numeric(1));
        for i in 0..100 {
            d.push_row(&[i as f32], 1.0);
        }
        let m = train(&d, &GbdtParams { n_trees: 3, ..Default::default() });
        let preds = m.predict_proba(&d);
        assert!(preds.iter().all(|&p| p > 0.99));
    }

    #[test]
    fn subsampling_still_learns() {
        let d = xor_dataset(4000, 4);
        let m = train(
            &d,
            &GbdtParams {
                n_trees: 30,
                max_depth: 3,
                subsample: 0.7,
                colsample: 0.8,
                ..Default::default()
            },
        );
        let auc = roc_auc(&m.predict_proba(&d), &d.labels);
        assert!(auc > 0.9, "auc={auc}");
    }

    #[test]
    fn feature_importance_identifies_signal() {
        // Feature 1 is pure noise; feature 0 carries the label.
        let mut rng = Rng::new(5);
        let mut d = Dataset::new(Schema::numeric(2));
        for _ in 0..2000 {
            let x = rng.normal() as f32;
            let noise = rng.normal() as f32;
            d.push_row(&[x, noise], (x > 0.3) as u8 as f32);
        }
        let m = train(&d, &GbdtParams { n_trees: 10, max_depth: 3, ..Default::default() });
        assert!(m.feature_gain[0] > 10.0 * m.feature_gain[1].max(1e-9));
    }

    #[test]
    fn deterministic_given_seed() {
        let d = xor_dataset(500, 6);
        let p = GbdtParams { n_trees: 5, subsample: 0.8, seed: 9, ..Default::default() };
        let m1 = train(&d, &p);
        let m2 = train(&d, &p);
        let p1 = m1.predict_proba(&d);
        let p2 = m2.predict_proba(&d);
        assert_eq!(p1, p2);
    }

    #[test]
    fn more_trees_reduce_train_logloss() {
        let d = xor_dataset(2000, 7);
        let few = train(&d, &GbdtParams { n_trees: 3, max_depth: 3, ..Default::default() });
        let many = train(&d, &GbdtParams { n_trees: 30, max_depth: 3, ..Default::default() });
        let ll_few = crate::metrics::log_loss(&few.predict_proba(&d), &d.labels);
        let ll_many = crate::metrics::log_loss(&many.predict_proba(&d), &d.labels);
        assert!(ll_many < ll_few, "{ll_many} vs {ll_few}");
    }
}
