//! Histogram pre-binning for GBDT training.
//!
//! Maps each feature to small integer bins via quantile cut points computed
//! once before boosting (the XGBoost "hist" / LightGBM approach). Bin edges
//! satisfy: `bin(x) = #{edges e : e < x}`, so the split condition
//! `bin(x) <= b` is exactly `x <= edges[b]` on raw values — which is what the
//! serving-side tree evaluator and the Pallas forest kernel test.

use crate::tabular::Dataset;

/// Per-feature bin edges.
#[derive(Clone, Debug)]
pub struct FeatureBinner {
    /// `edges[f]` sorted ascending; feature f has `edges[f].len() + 1` bins.
    pub edges: Vec<Vec<f32>>,
}

impl FeatureBinner {
    /// Compute edges from quantile cut points (up to `max_bins` bins per
    /// feature). Low-cardinality features get one bin per distinct value.
    pub fn fit(data: &Dataset, max_bins: usize) -> FeatureBinner {
        assert!(max_bins >= 2 && max_bins <= 256, "bins must fit u8");
        let edges = data
            .cols
            .iter()
            .map(|col| Self::edges_for(col, max_bins))
            .collect();
        FeatureBinner { edges }
    }

    fn edges_for(col: &[f32], max_bins: usize) -> Vec<f32> {
        // Sample for speed on huge columns.
        const MAX_SAMPLE: usize = 100_000;
        let mut v: Vec<f32> = if col.len() > MAX_SAMPLE {
            let stride = col.len() / MAX_SAMPLE;
            col.iter().step_by(stride).copied().collect()
        } else {
            col.to_vec()
        };
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup();
        if v.len() <= 1 {
            return Vec::new(); // constant feature → single bin
        }
        if v.len() <= max_bins {
            // One bin per distinct value; edges between consecutive values.
            return v.windows(2).map(|w| midpoint(w[0], w[1])).collect();
        }
        // Quantile cut points over the deduped values weighted by original
        // distribution: use the *original sorted* data for quantiles.
        let mut sorted: Vec<f32> = col.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut edges: Vec<f32> = (1..max_bins)
            .map(|k| {
                crate::tabular::stats::quantile_sorted(&sorted, k as f64 / max_bins as f64)
            })
            .collect();
        edges.dedup();
        edges
    }

    /// Bin a single value for feature `f`.
    #[inline]
    pub fn bin_value(&self, f: usize, x: f32) -> u8 {
        let edges = &self.edges[f];
        // partition_point: first index where edge >= x ⇒ count of edges < x.
        edges.partition_point(|&e| e < x) as u8
    }

    /// Number of bins for feature `f`.
    pub fn n_bins(&self, f: usize) -> usize {
        self.edges[f].len() + 1
    }

    /// Raw-value threshold equivalent to `bin <= b` (upper edge of bin b).
    #[inline]
    pub fn edge_value(&self, f: usize, b: usize) -> f32 {
        self.edges[f][b]
    }

    /// Bin the whole dataset, column-major u8.
    pub fn bin_dataset(&self, data: &Dataset) -> Vec<Vec<u8>> {
        data.cols
            .iter()
            .enumerate()
            .map(|(f, col)| col.iter().map(|&x| self.bin_value(f, x)).collect())
            .collect()
    }
}

fn midpoint(a: f32, b: f32) -> f32 {
    let m = 0.5 * (a + b);
    // Guard against rounding making the midpoint equal to b (then x=b would
    // land in the left bin via `e < x` == false... keep strictly between).
    if m <= a {
        b
    } else {
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tabular::{Dataset, Schema};

    fn ds(cols: Vec<Vec<f32>>) -> Dataset {
        let n = cols[0].len();
        let nf = cols.len();
        Dataset {
            schema: Schema::numeric(nf),
            cols,
            labels: vec![0.0; n],
        }
    }

    #[test]
    fn bin_condition_matches_raw_threshold() {
        // The fundamental invariant: bin(x) <= b  ⟺  x <= edges[b].
        let col: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 10.0).collect();
        let d = ds(vec![col.clone()]);
        let binner = FeatureBinner::fit(&d, 16);
        for &x in col.iter().take(300) {
            let bin = binner.bin_value(0, x) as usize;
            for b in 0..binner.edges[0].len() {
                assert_eq!(
                    bin <= b,
                    x <= binner.edge_value(0, b),
                    "x={x} bin={bin} b={b} edge={}",
                    binner.edge_value(0, b)
                );
            }
        }
    }

    #[test]
    fn low_cardinality_gets_exact_bins() {
        let col = vec![0.0f32, 1.0, 2.0, 1.0, 0.0, 2.0, 2.0];
        let d = ds(vec![col]);
        let binner = FeatureBinner::fit(&d, 64);
        assert_eq!(binner.n_bins(0), 3);
        assert_eq!(binner.bin_value(0, 0.0), 0);
        assert_eq!(binner.bin_value(0, 1.0), 1);
        assert_eq!(binner.bin_value(0, 2.0), 2);
    }

    #[test]
    fn constant_feature_single_bin() {
        let d = ds(vec![vec![5.0f32; 100]]);
        let binner = FeatureBinner::fit(&d, 16);
        assert_eq!(binner.n_bins(0), 1);
        assert_eq!(binner.bin_value(0, 5.0), 0);
    }

    #[test]
    fn bins_roughly_balanced() {
        let col: Vec<f32> = (0..10_000).map(|i| i as f32).collect();
        let d = ds(vec![col]);
        let binner = FeatureBinner::fit(&d, 8);
        let bins = binner.bin_dataset(&d);
        let mut counts = vec![0usize; binner.n_bins(0)];
        for &b in &bins[0] {
            counts[b as usize] += 1;
        }
        for &c in &counts {
            assert!(c > 800 && c < 1700, "counts={counts:?}");
        }
    }

    #[test]
    fn bin_count_bounded() {
        let col: Vec<f32> = (0..5000).map(|i| ((i * 31) % 997) as f32).collect();
        let d = ds(vec![col]);
        let binner = FeatureBinner::fit(&d, 32);
        assert!(binner.n_bins(0) <= 32);
        let bins = binner.bin_dataset(&d);
        assert!(bins[0].iter().all(|&b| (b as usize) < binner.n_bins(0)));
    }
}
