//! Decision-tree representation: compact nodes for fast native inference
//! plus a dense perfect-depth export for the Pallas forest kernel.

/// One tree node. Leaves have `feat == LEAF`.
#[derive(Clone, Debug)]
pub struct Node {
    /// Split feature, or `LEAF`.
    pub feat: u32,
    /// Raw-value threshold: go left iff `x[feat] <= thresh`.
    pub thresh: f32,
    /// Children indices (valid when not leaf).
    pub left: u32,
    pub right: u32,
    /// Leaf value (margin contribution, already scaled by learning rate).
    pub value: f32,
    /// Split gain (for feature importance).
    pub gain: f32,
}

pub const LEAF: u32 = u32::MAX;

/// A regression tree over raw feature values.
#[derive(Clone, Debug, Default)]
pub struct Tree {
    pub nodes: Vec<Node>,
}

impl Tree {
    pub fn leaf(value: f32) -> Tree {
        Tree {
            nodes: vec![Node {
                feat: LEAF,
                thresh: 0.0,
                left: 0,
                right: 0,
                value,
                gain: 0.0,
            }],
        }
    }

    /// Margin contribution for one row.
    #[inline]
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        let mut i = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.feat == LEAF {
                return n.value;
            }
            i = if row[n.feat as usize] <= n.thresh {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }

    /// Maximum depth (root = 0).
    pub fn depth(&self) -> usize {
        fn go(t: &Tree, i: usize) -> usize {
            let n = &t.nodes[i];
            if n.feat == LEAF {
                0
            } else {
                1 + go(t, n.left as usize).max(go(t, n.right as usize))
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            go(self, 0)
        }
    }

    /// Append this tree to a [`FlatForest`](crate::gbdt::FlatForest) arena
    /// in BFS order, so every split's children land adjacently (`lo`,
    /// `lo + 1`). An empty tree flattens to a single zero-valued leaf (the
    /// compact `predict_one` would panic on it; the flat path degrades to a
    /// no-op contribution instead).
    pub fn flatten_into(&self, out: &mut Vec<crate::gbdt::flat::FlatNode>) {
        use crate::gbdt::flat::FlatNode;
        let base = out.len();
        let placeholder = FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: 0.0 };
        if self.nodes.is_empty() {
            out.push(placeholder);
            return;
        }
        // BFS over compact indices; `order[i]` is the compact node placed at
        // arena slot `base + i`. Children are reserved in pairs as their
        // parent is visited, which is exactly what makes them adjacent.
        let mut order: Vec<u32> = vec![0];
        out.push(placeholder);
        let mut head = 0usize;
        while head < order.len() {
            let n = &self.nodes[order[head] as usize];
            let slot = base + head;
            if n.feat == LEAF {
                out[slot] = FlatNode { feat: LEAF, thresh: 0.0, lo: 0, value: n.value };
            } else {
                let lo = (base + order.len()) as u32;
                order.push(n.left);
                order.push(n.right);
                out.push(placeholder);
                out.push(placeholder);
                out[slot] = FlatNode { feat: n.feat, thresh: n.thresh, lo, value: 0.0 };
            }
            head += 1;
        }
    }

    /// Export to a dense perfect-depth layout for the tensorized (Pallas)
    /// forest kernel:
    ///
    /// * `feat[k]`, `thresh[k]` for interior slots `k ∈ [0, 2^depth - 1)`;
    /// * `leaf[j]` for depth-`depth` slots `j ∈ [0, 2^depth)`.
    ///
    /// Early leaves are padded with always-left splits (`feat=0,
    /// thresh=+inf`) and their value replicated across the reachable
    /// depth-D slots, so an unconditional D-step traversal
    /// (`k ← 2k+1 + (x > t)`) lands on the right value.
    pub fn to_dense(&self, depth: usize) -> DenseTree {
        let n_interior = (1usize << depth) - 1;
        let n_leaves = 1usize << depth;
        let mut feat = vec![0u32; n_interior];
        let mut thresh = vec![f32::INFINITY; n_interior];
        let mut leaf = vec![0f32; n_leaves];

        // Walk (node, slot) pairs; slot indexes the implicit perfect tree.
        fn fill(
            t: &Tree,
            node: usize,
            slot: usize,
            d: usize,
            depth: usize,
            feat: &mut [u32],
            thresh: &mut [f32],
            leaf: &mut [f32],
        ) {
            let n = &t.nodes[node];
            if d == depth {
                // At leaf level: node must be a leaf (tree depth ≤ depth).
                debug_assert_eq!(n.feat, LEAF, "tree deeper than export depth");
                leaf[slot - ((1 << depth) - 1)] = n.value;
                return;
            }
            if n.feat == LEAF {
                // Pad: always-left split, replicate value down-left; fill
                // the whole subtree's leaf range for safety.
                feat[slot] = 0;
                thresh[slot] = f32::INFINITY;
                let first = leaf_range_start(slot, d, depth);
                let count = 1usize << (depth - d);
                for j in 0..count {
                    leaf[first + j] = n.value;
                }
                // Descend only left to keep padding cheap? The range fill
                // above already covers all descendants.
                return;
            }
            feat[slot] = n.feat;
            thresh[slot] = n.thresh;
            fill(t, n.left as usize, 2 * slot + 1, d + 1, depth, feat, thresh, leaf);
            fill(t, n.right as usize, 2 * slot + 2, d + 1, depth, feat, thresh, leaf);
        }

        /// First depth-D leaf index reachable from `slot` at depth `d`.
        fn leaf_range_start(slot: usize, d: usize, depth: usize) -> usize {
            // Leftmost descendant after (depth-d) left steps:
            let mut s = slot;
            for _ in 0..(depth - d) {
                s = 2 * s + 1;
            }
            s - ((1 << depth) - 1)
        }

        if !self.nodes.is_empty() {
            fill(self, 0, 0, 0, depth, &mut feat, &mut thresh, &mut leaf);
        }
        DenseTree { depth, feat, thresh, leaf }
    }
}

/// Dense perfect-depth tree (see [`Tree::to_dense`]).
#[derive(Clone, Debug)]
pub struct DenseTree {
    pub depth: usize,
    pub feat: Vec<u32>,
    pub thresh: Vec<f32>,
    pub leaf: Vec<f32>,
}

impl DenseTree {
    /// Oblivious D-step traversal — the exact algorithm the Pallas forest
    /// kernel runs; used in tests to prove compact ≡ dense.
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        let mut k = 0usize;
        for _ in 0..self.depth {
            let go_right = row[self.feat[k] as usize] > self.thresh[k];
            k = 2 * k + 1 + (go_right as usize);
        }
        self.leaf[k - ((1 << self.depth) - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// x0 <= 0 ? (x1 <= 1 ? 10 : 20) : 30
    fn sample_tree() -> Tree {
        Tree {
            nodes: vec![
                Node { feat: 0, thresh: 0.0, left: 1, right: 2, value: 0.0, gain: 1.0 },
                Node { feat: 1, thresh: 1.0, left: 3, right: 4, value: 0.0, gain: 0.5 },
                Node { feat: LEAF, thresh: 0.0, left: 0, right: 0, value: 30.0, gain: 0.0 },
                Node { feat: LEAF, thresh: 0.0, left: 0, right: 0, value: 10.0, gain: 0.0 },
                Node { feat: LEAF, thresh: 0.0, left: 0, right: 0, value: 20.0, gain: 0.0 },
            ],
        }
    }

    #[test]
    fn predict_follows_splits() {
        let t = sample_tree();
        assert_eq!(t.predict_one(&[-1.0, 0.0]), 10.0);
        assert_eq!(t.predict_one(&[-1.0, 2.0]), 20.0);
        assert_eq!(t.predict_one(&[1.0, 0.0]), 30.0);
        // Boundary: x0 == thresh goes left.
        assert_eq!(t.predict_one(&[0.0, 5.0]), 20.0);
    }

    #[test]
    fn depth_computed() {
        assert_eq!(sample_tree().depth(), 2);
        assert_eq!(Tree::leaf(1.0).depth(), 0);
    }

    #[test]
    fn dense_matches_compact_exhaustive() {
        let t = sample_tree();
        let d = t.to_dense(3); // export deeper than the tree
        for x0 in [-2.0f32, 0.0, 0.5, 3.0] {
            for x1 in [-1.0f32, 1.0, 1.5] {
                let row = [x0, x1];
                assert_eq!(t.predict_one(&row), d.predict_one(&row), "row={row:?}");
            }
        }
    }

    #[test]
    fn dense_single_leaf() {
        let t = Tree::leaf(7.5);
        let d = t.to_dense(4);
        assert_eq!(d.predict_one(&[1.0, 2.0, 3.0]), 7.5);
    }

    #[test]
    fn dense_random_trees_match() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        for _ in 0..30 {
            // Build a random tree of depth ≤ 4 over 5 features.
            let depth = 4;
            let t = random_tree(&mut rng, 0, depth);
            let d = t.to_dense(depth);
            for _ in 0..50 {
                let row: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
                assert_eq!(t.predict_one(&row), d.predict_one(&row));
            }
        }
    }

    fn random_tree(rng: &mut crate::util::rng::Rng, d: usize, max_d: usize) -> Tree {
        use crate::util::rng::Rng;
        fn build(rng: &mut Rng, d: usize, max_d: usize, nodes: &mut Vec<Node>) -> u32 {
            let idx = nodes.len() as u32;
            if d == max_d || rng.bool(0.3) {
                nodes.push(Node {
                    feat: LEAF,
                    thresh: 0.0,
                    left: 0,
                    right: 0,
                    value: rng.normal() as f32,
                    gain: 0.0,
                });
                return idx;
            }
            nodes.push(Node {
                feat: rng.index(5) as u32,
                thresh: rng.normal() as f32,
                left: 0,
                right: 0,
                value: 0.0,
                gain: 0.0,
            });
            let l = build(rng, d + 1, max_d, nodes);
            let r = build(rng, d + 1, max_d, nodes);
            nodes[idx as usize].left = l;
            nodes[idx as usize].right = r;
            idx
        }
        let mut nodes = Vec::new();
        build(rng, d, max_d, &mut nodes);
        Tree { nodes }
    }
}
