//! `FlatForest` — the whole GBDT flattened into one contiguous **SoA** node
//! arena for the serving hot path.
//!
//! # Layout
//!
//! The training-side [`Tree`](super::Tree) stores heap-allocated per-tree
//! node vectors with explicit left/right child indices; following them is a
//! pointer chase with no locality across trees. `FlatForest` re-lays the
//! forest out for inference:
//!
//! * **one arena, structure-of-arrays**: every node of every tree lives at
//!   one index into four parallel arrays — `feat`, `thresh`, `lo`, `value`
//!   — so the forest is four allocations and a traversal step loads **only
//!   the field it needs**: `feat[i]` to classify the node, then either
//!   `thresh[i]`/`lo[i]` (interior) or `value[i]` (leaf). The old
//!   interleaved 16-byte node dragged the unused fields through the cache
//!   with every load; SoA quadruples the nodes per cache line on the
//!   `feat`-probe that every step performs.
//! * **adjacent children**: nodes are re-numbered in BFS order per tree so
//!   a split's children always sit at `lo` and `lo + 1` — the branch
//!   direction is the single bit `!(x <= thresh)` added to `lo`, with no
//!   `right` pointer to load.
//! * **tree-major, lane-tiled blocks**: `predict_block` walks all rows of a
//!   block through one tree before moving to the next, so each tree's top
//!   levels stay in L1 across the whole block, and it steps [`LANES`]
//!   independent row *walks* in lockstep with a **pending-lane mask**: each
//!   pass advances every still-walking lane with the branchless
//!   compare-advance `lo[i] + !(x <= thresh[i])`, lanes that reach a leaf
//!   drop out of the mask, and the unrelated arena loads of the surviving
//!   lanes overlap in the memory pipeline (the classic decision-forest
//!   row-blocking/interleaving optimization; SoA is what lets the widened
//!   lane count stay fed from L1).
//!
//! # Exactness
//!
//! Outputs are bit-identical to [`GbdtModel::predict_one`]: lanes vectorize
//! **across rows**, so each row still sees the same `x <= thresh → left`
//! comparison sequence (NaN therefore goes right, as in training), leaf
//! margins accumulated into an `f64` in tree order starting from
//! `base_score`, and the same `sigmoid(f64) as f32` at the end — regardless
//! of how many lanes travel together or where the remainder tail begins.
//! [`FlatForest::predict_block_scalar`] keeps the plain per-row walk as the
//! A/B baseline (`forest_soa` bench section) and the property-test anchor.

use super::tree::LEAF;
use super::GbdtModel;
use crate::tabular::RowBlock;
use crate::util::sigmoid;

/// One build-time node, as emitted by [`Tree::flatten_into`]
/// (`super::tree::Tree::flatten_into`); [`FlatForest::from_nodes`] shreds
/// these into the SoA arrays.
#[derive(Clone, Copy, Debug)]
pub struct FlatNode {
    /// Split feature, or [`LEAF`].
    pub feat: u32,
    /// Go left iff `x[feat] <= thresh` (NaN goes right).
    pub thresh: f32,
    /// Arena index of the left child; the right child is `lo + 1`.
    /// Unused for leaves.
    pub lo: u32,
    /// Leaf margin contribution (zero for interior nodes).
    pub value: f32,
}

/// Number of row lanes stepped in lockstep by the block kernel. Sixteen
/// in-flight walks cover an L2 hit's latency; the SoA arena keeps the
/// per-step state (a `u32` index per lane plus the shared field arrays)
/// small enough that the wider tile still lives in registers/L1.
const LANES: usize = 16;

/// A whole forest in one contiguous SoA arena (see module docs).
#[derive(Clone, Debug, Default)]
pub struct FlatForest {
    /// Split feature per node, or [`LEAF`].
    pub feat: Vec<u32>,
    /// Split threshold per node (unused for leaves).
    pub thresh: Vec<f32>,
    /// Left-child index per node; right child is `lo + 1` (unused for
    /// leaves).
    pub lo: Vec<u32>,
    /// Leaf margin contribution per node (zero for interior nodes).
    pub value: Vec<f32>,
    /// Arena index of each tree's root, in boosting order.
    pub roots: Vec<u32>,
    pub base_score: f64,
    pub n_features: usize,
}

/// Reusable scratch for [`FlatForest::predict_block`] /
/// [`FlatForest::predict_flat_rows`] — holds the per-row f64 margin
/// accumulators so steady-state prediction allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct ForestScratch {
    margins: Vec<f64>,
}

impl FlatForest {
    /// Flatten a trained model. The model stays the source of truth for
    /// training-side concerns (importance, JSON, dense export); this is the
    /// serving image.
    pub fn from_model(m: &GbdtModel) -> FlatForest {
        let total: usize = m.trees.iter().map(|t| t.nodes.len().max(1)).sum();
        let mut nodes = Vec::with_capacity(total);
        let mut roots = Vec::with_capacity(m.trees.len());
        for t in &m.trees {
            roots.push(nodes.len() as u32);
            t.flatten_into(&mut nodes);
        }
        FlatForest::from_nodes(&nodes, roots, m.base_score, m.n_features)
    }

    /// Shred a build-time AoS node list (BFS-ordered, adjacent children)
    /// into the SoA arena.
    pub fn from_nodes(
        nodes: &[FlatNode],
        roots: Vec<u32>,
        base_score: f64,
        n_features: usize,
    ) -> FlatForest {
        FlatForest {
            feat: nodes.iter().map(|n| n.feat).collect(),
            thresh: nodes.iter().map(|n| n.thresh).collect(),
            lo: nodes.iter().map(|n| n.lo).collect(),
            value: nodes.iter().map(|n| n.value).collect(),
            roots,
            base_score,
            n_features,
        }
    }

    /// Nodes in the arena.
    pub fn n_nodes(&self) -> usize {
        self.feat.len()
    }

    /// Margin for one row — bit-identical to
    /// [`GbdtModel::predict_margin_one`].
    #[inline]
    pub fn predict_margin_one(&self, row: &[f32]) -> f64 {
        let mut m = self.base_score;
        for &root in &self.roots {
            let mut i = root as usize;
            loop {
                let f = self.feat[i];
                if f == LEAF {
                    m += self.value[i] as f64;
                    break;
                }
                let x = row[f as usize];
                i = (self.lo[i] + u32::from(!(x <= self.thresh[i]))) as usize;
            }
        }
        m
    }

    /// Probability for one row — bit-identical to
    /// [`GbdtModel::predict_one`].
    #[inline]
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        sigmoid(self.predict_margin_one(row)) as f32
    }

    /// Probabilities for a columnar block; `out` is cleared and refilled
    /// with one probability per row. Bit-identical to per-row
    /// [`GbdtModel::predict_one`].
    pub fn predict_block(&self, block: &RowBlock, scratch: &mut ForestScratch, out: &mut Vec<f32>) {
        let n = block.n_rows();
        out.clear();
        out.resize(n, 0.0);
        self.predict_with(n, |r, f| block.get(r, f as usize), scratch, out, true);
    }

    /// Per-row reference walk over a block — the A/B baseline for the
    /// lane-tiled kernel (the `forest_soa` bench section) and the anchor
    /// the property tests compare it against. Bit-identical to
    /// [`FlatForest::predict_block`].
    pub fn predict_block_scalar(
        &self,
        block: &RowBlock,
        scratch: &mut ForestScratch,
        out: &mut Vec<f32>,
    ) {
        let n = block.n_rows();
        out.clear();
        out.resize(n, 0.0);
        self.predict_with(n, |r, f| block.get(r, f as usize), scratch, out, false);
    }

    /// Probabilities for row-major flat rows (the RPC wire layout), written
    /// into `out` (`rows.len() >= out.len() * row_len`; `row_len` must cover
    /// `n_features`). Taking a sub-slice of `out` shards the batch.
    pub fn predict_flat_rows(
        &self,
        rows: &[f32],
        row_len: usize,
        scratch: &mut ForestScratch,
        out: &mut [f32],
    ) {
        let n = out.len();
        debug_assert!(rows.len() >= n * row_len);
        debug_assert!(row_len >= self.n_features);
        self.predict_with(n, |r, f| rows[r * row_len + f as usize], scratch, out, true);
    }

    /// Shared block kernel over an arbitrary `(row, feat) -> x` accessor.
    /// `lanes = false` forces the plain per-row walk.
    fn predict_with<G: Fn(usize, u32) -> f32>(
        &self,
        n: usize,
        get: G,
        scratch: &mut ForestScratch,
        out: &mut [f32],
        lanes: bool,
    ) {
        debug_assert_eq!(out.len(), n);
        let margins = &mut scratch.margins;
        margins.clear();
        margins.resize(n, self.base_score);
        let (feat, thresh, lo, value) = (&self.feat, &self.thresh, &self.lo, &self.value);
        for &root in &self.roots {
            let mut r = 0usize;
            if lanes {
                // Lane-tiled walk: LANES independent row walks advance in
                // lockstep under a pending mask. Each pass visits only the
                // still-walking lanes (bit iteration skips parked ones),
                // loads `feat` to classify, and either retires the lane
                // (leaf: one `value` load) or advances it with the
                // branchless compare `lo + !(x <= thresh)` — so the
                // unrelated SoA loads of different lanes overlap in the
                // memory pipeline.
                while r + LANES <= n {
                    let mut idx = [root; LANES];
                    let mut val = [0f32; LANES];
                    let mut pending: u32 = (1 << LANES) - 1;
                    while pending != 0 {
                        let mut p = pending;
                        while p != 0 {
                            let k = p.trailing_zeros() as usize;
                            p &= p - 1;
                            let i = idx[k] as usize;
                            let f = feat[i];
                            if f == LEAF {
                                val[k] = value[i];
                                pending &= !(1 << k);
                            } else {
                                let x = get(r + k, f);
                                idx[k] = lo[i] + u32::from(!(x <= thresh[i]));
                            }
                        }
                    }
                    for (k, &v) in val.iter().enumerate() {
                        margins[r + k] += v as f64;
                    }
                    r += LANES;
                }
            }
            // Remainder rows (or the whole block in scalar mode): plain
            // iterative walk — the same per-row comparisons in the same
            // order, so where the tile boundary falls cannot change bits.
            while r < n {
                let mut i = root as usize;
                loop {
                    let f = feat[i];
                    if f == LEAF {
                        margins[r] += value[i] as f64;
                        break;
                    }
                    let x = get(r, f);
                    i = (lo[i] + u32::from(!(x <= thresh[i]))) as usize;
                }
                r += 1;
            }
        }
        for (o, &m) in out.iter_mut().zip(margins.iter()) {
            *o = sigmoid(m) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::{train, GbdtParams};
    use crate::tabular::{Dataset, Schema};
    use crate::util::rng::Rng;

    fn trained() -> (GbdtModel, Dataset) {
        let mut rng = Rng::new(23);
        let mut d = Dataset::new(Schema::numeric(4));
        for _ in 0..2000 {
            let x: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            let y = (x[0] * x[1] + x[2] > 0.3) as u8 as f32;
            d.push_row(&x, y);
        }
        let m = train(&d, &GbdtParams { n_trees: 17, max_depth: 5, ..Default::default() });
        (m, d)
    }

    #[test]
    fn flat_matches_native_bitwise() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let mut row = Vec::new();
        for r in 0..300 {
            d.row_into(r, &mut row);
            assert_eq!(
                flat.predict_one(&row).to_bits(),
                m.predict_one(&row).to_bits(),
                "row {r}"
            );
        }
    }

    #[test]
    fn block_matches_scalar_bitwise_all_chunks() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let mut rows: Vec<Vec<f32>> = (0..100).map(|r| d.row(r)).collect();
        rows[5][1] = f32::NAN; // NaN must route right, identically.
        rows[31] = vec![f32::NAN; 4];
        let mut scratch = ForestScratch::default();
        let mut out = Vec::new();
        let mut out_scalar = Vec::new();
        // Chunk sizes straddle the lane tile: every remainder 1..LANES-1
        // plus exact and off-by-one tiles.
        for chunk in [1usize, 3, LANES - 1, LANES, LANES + 1, 64, 100] {
            for rows in rows.chunks(chunk) {
                let block = RowBlock::from_rows(rows);
                flat.predict_block(&block, &mut scratch, &mut out);
                flat.predict_block_scalar(&block, &mut scratch, &mut out_scalar);
                for (i, row) in rows.iter().enumerate() {
                    assert_eq!(
                        out[i].to_bits(),
                        m.predict_one(row).to_bits(),
                        "chunk {chunk} row {i}"
                    );
                    assert_eq!(
                        out[i].to_bits(),
                        out_scalar[i].to_bits(),
                        "lane walk vs scalar walk, chunk {chunk} row {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn flat_rows_match_block_with_padding() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let n = 50;
        let row_len = d.n_features() + 3; // padded wire rows
        let mut flat_rows = vec![0f32; n * row_len];
        let mut row = Vec::new();
        for r in 0..n {
            d.row_into(r, &mut row);
            flat_rows[r * row_len..r * row_len + row.len()].copy_from_slice(&row);
        }
        let mut scratch = ForestScratch::default();
        let mut out = vec![0f32; n];
        flat.predict_flat_rows(&flat_rows, row_len, &mut scratch, &mut out);
        for r in 0..n {
            d.row_into(r, &mut row);
            assert_eq!(out[r].to_bits(), m.predict_one(&row).to_bits(), "row {r}");
        }
    }

    #[test]
    fn arena_children_adjacent_soa() {
        let (m, _) = trained();
        let flat = FlatForest::from_model(&m);
        assert_eq!(flat.roots.len(), m.trees.len());
        let total: usize = m.trees.iter().map(|t| t.nodes.len()).sum();
        assert_eq!(flat.n_nodes(), total);
        // The SoA arrays stay parallel.
        assert_eq!(flat.thresh.len(), total);
        assert_eq!(flat.lo.len(), total);
        assert_eq!(flat.value.len(), total);
        for i in 0..flat.n_nodes() {
            if flat.feat[i] != LEAF {
                // Both children (lo, lo + 1) must be in-arena.
                assert!(flat.lo[i] as usize + 1 < flat.n_nodes());
            }
        }
    }

    #[test]
    fn empty_and_leaf_trees() {
        use crate::gbdt::Tree;
        let m = GbdtModel {
            trees: vec![Tree::leaf(0.25), Tree::default(), Tree::leaf(-0.5)],
            base_score: 0.1,
            n_features: 2,
            feature_gain: vec![0.0; 2],
            max_depth: 1,
        };
        let flat = FlatForest::from_model(&m);
        // Empty trees flatten to a zero-valued leaf; margin = 0.1 + 0.25 - 0.5.
        let p = flat.predict_one(&[1.0, 2.0]);
        assert!((p - crate::util::sigmoid(-0.15) as f32).abs() < 1e-7);
    }
}
