//! `FlatForest` — the whole GBDT flattened into one contiguous node arena
//! for the serving hot path.
//!
//! # Layout
//!
//! The training-side [`Tree`](super::Tree) stores heap-allocated per-tree
//! node vectors with explicit left/right child indices; following them is a
//! pointer chase with no locality across trees. `FlatForest` re-lays the
//! forest out for inference:
//!
//! * **one arena**: every node of every tree lives in a single
//!   `Vec<FlatNode>`; a tree is a root index into it, so the forest is one
//!   allocation and traversal touches one linear address range;
//! * **adjacent children**: nodes are re-numbered in BFS order per tree so
//!   a split's children always sit at `lo` and `lo + 1` — the node is 16
//!   bytes (4 per cache line) and the branch direction becomes the single
//!   bit `!(x <= thresh)` added to `lo`, with no `right` pointer to load;
//! * **tree-major, row-minor blocks**: `predict_block` walks all rows of a
//!   block through one tree before moving to the next, so each tree's top
//!   levels stay in L1 across the whole block, and it steps a small set of
//!   row *lanes* in lockstep so the independent node loads of different
//!   rows overlap in the memory pipeline (the classic decision-forest
//!   row-blocking/interleaving optimization).
//!
//! # Exactness
//!
//! Outputs are bit-identical to [`GbdtModel::predict_one`]: the same
//! `x <= thresh → left` comparison (NaN therefore goes right, as in
//! training), leaf margins accumulated into an `f64` in tree order starting
//! from `base_score`, and the same `sigmoid(f64) as f32` at the end.

use super::tree::LEAF;
use super::GbdtModel;
use crate::tabular::RowBlock;
use crate::util::sigmoid;

/// One arena node. 16 bytes; 4 per cache line.
#[derive(Clone, Copy, Debug)]
pub struct FlatNode {
    /// Split feature, or [`LEAF`].
    pub feat: u32,
    /// Go left iff `x[feat] <= thresh` (NaN goes right).
    pub thresh: f32,
    /// Arena index of the left child; the right child is `lo + 1`.
    /// Unused for leaves.
    pub lo: u32,
    /// Leaf margin contribution (zero for interior nodes).
    pub value: f32,
}

/// Number of row lanes stepped in lockstep by the block kernel. Eight
/// in-flight walks are enough to cover an L2 hit's latency without
/// spilling the lane state out of registers.
const LANES: usize = 8;

/// A whole forest in one contiguous arena (see module docs).
#[derive(Clone, Debug, Default)]
pub struct FlatForest {
    pub nodes: Vec<FlatNode>,
    /// Arena index of each tree's root, in boosting order.
    pub roots: Vec<u32>,
    pub base_score: f64,
    pub n_features: usize,
}

/// Reusable scratch for [`FlatForest::predict_block`] /
/// [`FlatForest::predict_flat_rows`] — holds the per-row f64 margin
/// accumulators so steady-state prediction allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct ForestScratch {
    margins: Vec<f64>,
}

impl FlatForest {
    /// Flatten a trained model. The model stays the source of truth for
    /// training-side concerns (importance, JSON, dense export); this is the
    /// serving image.
    pub fn from_model(m: &GbdtModel) -> FlatForest {
        let total: usize = m.trees.iter().map(|t| t.nodes.len().max(1)).sum();
        let mut nodes = Vec::with_capacity(total);
        let mut roots = Vec::with_capacity(m.trees.len());
        for t in &m.trees {
            roots.push(nodes.len() as u32);
            t.flatten_into(&mut nodes);
        }
        FlatForest {
            nodes,
            roots,
            base_score: m.base_score,
            n_features: m.n_features,
        }
    }

    /// Margin for one row — bit-identical to
    /// [`GbdtModel::predict_margin_one`].
    #[inline]
    pub fn predict_margin_one(&self, row: &[f32]) -> f64 {
        let nodes = &self.nodes;
        let mut m = self.base_score;
        for &root in &self.roots {
            let mut i = root as usize;
            loop {
                let nd = nodes[i];
                if nd.feat == LEAF {
                    m += nd.value as f64;
                    break;
                }
                let x = row[nd.feat as usize];
                i = (nd.lo + u32::from(!(x <= nd.thresh))) as usize;
            }
        }
        m
    }

    /// Probability for one row — bit-identical to
    /// [`GbdtModel::predict_one`].
    #[inline]
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        sigmoid(self.predict_margin_one(row)) as f32
    }

    /// Probabilities for a columnar block; `out` is cleared and refilled
    /// with one probability per row. Bit-identical to per-row
    /// [`GbdtModel::predict_one`].
    pub fn predict_block(&self, block: &RowBlock, scratch: &mut ForestScratch, out: &mut Vec<f32>) {
        let n = block.n_rows();
        out.clear();
        out.resize(n, 0.0);
        self.predict_with(n, |r, f| block.get(r, f as usize), scratch, out);
    }

    /// Probabilities for row-major flat rows (the RPC wire layout), written
    /// into `out` (`rows.len() >= out.len() * row_len`; `row_len` must cover
    /// `n_features`). Taking a sub-slice of `out` shards the batch.
    pub fn predict_flat_rows(
        &self,
        rows: &[f32],
        row_len: usize,
        scratch: &mut ForestScratch,
        out: &mut [f32],
    ) {
        let n = out.len();
        debug_assert!(rows.len() >= n * row_len);
        debug_assert!(row_len >= self.n_features);
        self.predict_with(n, |r, f| rows[r * row_len + f as usize], scratch, out);
    }

    /// Shared block kernel over an arbitrary `(row, feat) -> x` accessor.
    fn predict_with<G: Fn(usize, u32) -> f32>(
        &self,
        n: usize,
        get: G,
        scratch: &mut ForestScratch,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), n);
        let margins = &mut scratch.margins;
        margins.clear();
        margins.resize(n, self.base_score);
        let nodes = &self.nodes;
        for &root in &self.roots {
            let mut r = 0usize;
            // Interleaved lanes: LANES independent walks advance one node
            // per pass, so their (unrelated) arena loads overlap.
            while r + LANES <= n {
                let mut idx = [root as usize; LANES];
                let mut val = [0f32; LANES];
                let mut pending: u32 = (1 << LANES) - 1;
                while pending != 0 {
                    for (k, ik) in idx.iter_mut().enumerate() {
                        if pending & (1 << k) == 0 {
                            continue;
                        }
                        let nd = nodes[*ik];
                        if nd.feat == LEAF {
                            val[k] = nd.value;
                            pending &= !(1 << k);
                        } else {
                            let x = get(r + k, nd.feat);
                            *ik = (nd.lo + u32::from(!(x <= nd.thresh))) as usize;
                        }
                    }
                }
                for (k, &v) in val.iter().enumerate() {
                    margins[r + k] += v as f64;
                }
                r += LANES;
            }
            // Remainder rows: plain iterative walk.
            while r < n {
                let mut i = root as usize;
                loop {
                    let nd = nodes[i];
                    if nd.feat == LEAF {
                        margins[r] += nd.value as f64;
                        break;
                    }
                    let x = get(r, nd.feat);
                    i = (nd.lo + u32::from(!(x <= nd.thresh))) as usize;
                }
                r += 1;
            }
        }
        for (o, &m) in out.iter_mut().zip(margins.iter()) {
            *o = sigmoid(m) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::{train, GbdtParams};
    use crate::tabular::{Dataset, Schema};
    use crate::util::rng::Rng;

    fn trained() -> (GbdtModel, Dataset) {
        let mut rng = Rng::new(23);
        let mut d = Dataset::new(Schema::numeric(4));
        for _ in 0..2000 {
            let x: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            let y = (x[0] * x[1] + x[2] > 0.3) as u8 as f32;
            d.push_row(&x, y);
        }
        let m = train(&d, &GbdtParams { n_trees: 17, max_depth: 5, ..Default::default() });
        (m, d)
    }

    #[test]
    fn flat_matches_native_bitwise() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let mut row = Vec::new();
        for r in 0..300 {
            d.row_into(r, &mut row);
            assert_eq!(
                flat.predict_one(&row).to_bits(),
                m.predict_one(&row).to_bits(),
                "row {r}"
            );
        }
    }

    #[test]
    fn block_matches_scalar_bitwise_all_chunks() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let mut rows: Vec<Vec<f32>> = (0..100).map(|r| d.row(r)).collect();
        rows[5][1] = f32::NAN; // NaN must route right, identically.
        rows[31] = vec![f32::NAN; 4];
        let mut scratch = ForestScratch::default();
        let mut out = Vec::new();
        for chunk in [1usize, 3, LANES, LANES + 1, 64, 100] {
            for rows in rows.chunks(chunk) {
                let block = RowBlock::from_rows(rows);
                flat.predict_block(&block, &mut scratch, &mut out);
                for (i, row) in rows.iter().enumerate() {
                    assert_eq!(
                        out[i].to_bits(),
                        m.predict_one(row).to_bits(),
                        "chunk {chunk} row {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn flat_rows_match_block_with_padding() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let n = 50;
        let row_len = d.n_features() + 3; // padded wire rows
        let mut flat_rows = vec![0f32; n * row_len];
        let mut row = Vec::new();
        for r in 0..n {
            d.row_into(r, &mut row);
            flat_rows[r * row_len..r * row_len + row.len()].copy_from_slice(&row);
        }
        let mut scratch = ForestScratch::default();
        let mut out = vec![0f32; n];
        flat.predict_flat_rows(&flat_rows, row_len, &mut scratch, &mut out);
        for r in 0..n {
            d.row_into(r, &mut row);
            assert_eq!(out[r].to_bits(), m.predict_one(&row).to_bits(), "row {r}");
        }
    }

    #[test]
    fn arena_children_adjacent() {
        let (m, _) = trained();
        let flat = FlatForest::from_model(&m);
        assert_eq!(flat.roots.len(), m.trees.len());
        assert_eq!(
            flat.nodes.len(),
            m.trees.iter().map(|t| t.nodes.len()).sum::<usize>()
        );
        for nd in &flat.nodes {
            if nd.feat != LEAF {
                // Both children (lo, lo + 1) must be in-arena.
                assert!(nd.lo as usize + 1 < flat.nodes.len());
            }
        }
    }

    #[test]
    fn empty_and_leaf_trees() {
        use crate::gbdt::Tree;
        let m = GbdtModel {
            trees: vec![Tree::leaf(0.25), Tree::default(), Tree::leaf(-0.5)],
            base_score: 0.1,
            n_features: 2,
            feature_gain: vec![0.0; 2],
            max_depth: 1,
        };
        let flat = FlatForest::from_model(&m);
        // Empty trees flatten to a zero-valued leaf; margin = 0.1 + 0.25 - 0.5.
        let p = flat.predict_one(&[1.0, 2.0]);
        assert!((p - crate::util::sigmoid(-0.15) as f32).abs() < 1e-7);
    }
}
