//! `FlatForest` — the whole GBDT flattened into one contiguous **SoA** node
//! arena for the serving hot path.
//!
//! # Layout
//!
//! The training-side [`Tree`](super::Tree) stores heap-allocated per-tree
//! node vectors with explicit left/right child indices; following them is a
//! pointer chase with no locality across trees. `FlatForest` re-lays the
//! forest out for inference:
//!
//! * **one arena, structure-of-arrays**: every node of every tree lives at
//!   one index into four parallel arrays — `feat`, `thresh`, `lo`, `value`
//!   — so the forest is four allocations and a traversal step loads **only
//!   the field it needs**: `feat[i]` to classify the node, then either
//!   `thresh[i]`/`lo[i]` (interior) or `value[i]` (leaf). The old
//!   interleaved 16-byte node dragged the unused fields through the cache
//!   with every load; SoA quadruples the nodes per cache line on the
//!   `feat`-probe that every step performs.
//! * **adjacent children**: nodes are re-numbered in BFS order per tree so
//!   a split's children always sit at `lo` and `lo + 1` — the branch
//!   direction is the single bit `!(x <= thresh)` added to `lo`, with no
//!   `right` pointer to load.
//! * **tree-major, lane-tiled blocks**: `predict_block` walks all rows of a
//!   block through one tree before moving to the next, so each tree's top
//!   levels stay in L1 across the whole block, and it steps [`LANES`]
//!   independent row *walks* in lockstep with a **pending-lane mask**: each
//!   pass advances every still-walking lane with the branchless
//!   compare-advance `lo[i] + !(x <= thresh[i])`, lanes that reach a leaf
//!   drop out of the mask, and the unrelated arena loads of the surviving
//!   lanes overlap in the memory pipeline (the classic decision-forest
//!   row-blocking/interleaving optimization; SoA is what lets the widened
//!   lane count stay fed from L1).
//!
//! # Exactness
//!
//! Outputs are bit-identical to [`GbdtModel::predict_one`]: lanes vectorize
//! **across rows**, so each row still sees the same `x <= thresh → left`
//! comparison sequence (NaN therefore goes right, as in training), leaf
//! margins accumulated into an `f64` in tree order starting from
//! `base_score`, and the same `sigmoid(f64) as f32` at the end — regardless
//! of how many lanes travel together or where the remainder tail begins.
//! [`FlatForest::predict_block_scalar`] keeps the plain per-row walk as the
//! A/B baseline (`forest_soa` bench section) and the property-test anchor.

use super::tree::LEAF;
use super::GbdtModel;
use crate::tabular::RowBlock;
use crate::util::sigmoid;

/// One build-time node, as emitted by [`Tree::flatten_into`]
/// (`super::tree::Tree::flatten_into`); [`FlatForest::from_nodes`] shreds
/// these into the SoA arrays.
#[derive(Clone, Copy, Debug)]
pub struct FlatNode {
    /// Split feature, or [`LEAF`].
    pub feat: u32,
    /// Go left iff `x[feat] <= thresh` (NaN goes right).
    pub thresh: f32,
    /// Arena index of the left child; the right child is `lo + 1`.
    /// Unused for leaves.
    pub lo: u32,
    /// Leaf margin contribution (zero for interior nodes).
    pub value: f32,
}

/// Number of row lanes stepped in lockstep by the block kernel. Sixteen
/// in-flight walks cover an L2 hit's latency; the SoA arena keeps the
/// per-step state (a `u32` index per lane plus the shared field arrays)
/// small enough that the wider tile still lives in registers/L1.
const LANES: usize = 16;

/// A whole forest in one contiguous SoA arena (see module docs).
#[derive(Clone, Debug, Default)]
pub struct FlatForest {
    /// Split feature per node, or [`LEAF`].
    pub feat: Vec<u32>,
    /// Split threshold per node (unused for leaves).
    pub thresh: Vec<f32>,
    /// Left-child index per node; right child is `lo + 1` (unused for
    /// leaves).
    pub lo: Vec<u32>,
    /// Leaf margin contribution per node (zero for interior nodes).
    pub value: Vec<f32>,
    /// Arena index of each tree's root, in boosting order.
    pub roots: Vec<u32>,
    pub base_score: f64,
    pub n_features: usize,
}

/// Reusable scratch for [`FlatForest::predict_block`] /
/// [`FlatForest::predict_flat_rows`] — holds the per-row f64 margin
/// accumulators so steady-state prediction allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct ForestScratch {
    margins: Vec<f64>,
}

/// Borrowed SoA forest: the same five arrays as [`FlatForest`], as slices.
///
/// Every walk kernel lives here; [`FlatForest`] delegates through
/// [`FlatForest::view`]. The point of the split is the snapshot loader —
/// the arrays of a parsed snapshot are served straight out of its one
/// contiguous buffer (zero-copy) through this view, with byte-for-byte the
/// same kernels the owned arena runs.
#[derive(Clone, Copy, Debug)]
pub struct ForestView<'a> {
    pub feat: &'a [u32],
    pub thresh: &'a [f32],
    pub lo: &'a [u32],
    pub value: &'a [f32],
    pub roots: &'a [u32],
    pub base_score: f64,
    pub n_features: usize,
}

impl FlatForest {
    /// Flatten a trained model. The model stays the source of truth for
    /// training-side concerns (importance, JSON, dense export); this is the
    /// serving image.
    pub fn from_model(m: &GbdtModel) -> FlatForest {
        let total: usize = m.trees.iter().map(|t| t.nodes.len().max(1)).sum();
        let mut nodes = Vec::with_capacity(total);
        let mut roots = Vec::with_capacity(m.trees.len());
        for t in &m.trees {
            roots.push(nodes.len() as u32);
            t.flatten_into(&mut nodes);
        }
        FlatForest::from_nodes(&nodes, roots, m.base_score, m.n_features)
    }

    /// Shred a build-time AoS node list (BFS-ordered, adjacent children)
    /// into the SoA arena.
    ///
    /// Deliberately permissive: no structural validation, so tests can
    /// build pathological forests (poison nodes, shared roots). Untrusted
    /// inputs — snapshot bytes above all — must go through
    /// [`FlatForest::try_from_nodes`] or [`FlatForest::validate`] instead.
    pub fn from_nodes(
        nodes: &[FlatNode],
        roots: Vec<u32>,
        base_score: f64,
        n_features: usize,
    ) -> FlatForest {
        FlatForest {
            feat: nodes.iter().map(|n| n.feat).collect(),
            thresh: nodes.iter().map(|n| n.thresh).collect(),
            lo: nodes.iter().map(|n| n.lo).collect(),
            value: nodes.iter().map(|n| n.value).collect(),
            roots,
            base_score,
            n_features,
        }
    }

    /// [`FlatForest::from_nodes`] for untrusted input: builds the arena and
    /// then [`FlatForest::validate`]s it, so a corrupt forest is rejected at
    /// load instead of walking out of bounds in the lane-tiled kernel.
    pub fn try_from_nodes(
        nodes: &[FlatNode],
        roots: Vec<u32>,
        base_score: f64,
        n_features: usize,
    ) -> Result<FlatForest, String> {
        let forest = FlatForest::from_nodes(nodes, roots, base_score, n_features);
        forest.validate()?;
        Ok(forest)
    }

    /// Check every structural invariant the walk kernels index by — see
    /// [`ForestView::validate`].
    pub fn validate(&self) -> Result<(), String> {
        self.view().validate()
    }

    /// Borrow the arena as a [`ForestView`] — the type every walk kernel
    /// is written against.
    #[inline]
    pub fn view(&self) -> ForestView<'_> {
        ForestView {
            feat: &self.feat,
            thresh: &self.thresh,
            lo: &self.lo,
            value: &self.value,
            roots: &self.roots,
            base_score: self.base_score,
            n_features: self.n_features,
        }
    }

    /// Nodes in the arena.
    pub fn n_nodes(&self) -> usize {
        self.feat.len()
    }

    /// Margin for one row — bit-identical to
    /// [`GbdtModel::predict_margin_one`].
    #[inline]
    pub fn predict_margin_one(&self, row: &[f32]) -> f64 {
        self.view().predict_margin_one(row)
    }

    /// Probability for one row — bit-identical to
    /// [`GbdtModel::predict_one`].
    #[inline]
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        self.view().predict_one(row)
    }

    /// Probabilities for a columnar block; `out` is cleared and refilled
    /// with one probability per row. Bit-identical to per-row
    /// [`GbdtModel::predict_one`].
    pub fn predict_block(&self, block: &RowBlock, scratch: &mut ForestScratch, out: &mut Vec<f32>) {
        self.view().predict_block(block, scratch, out);
    }

    /// Per-row reference walk over a block — the A/B baseline for the
    /// lane-tiled kernel (the `forest_soa` bench section) and the anchor
    /// the property tests compare it against. Bit-identical to
    /// [`FlatForest::predict_block`].
    pub fn predict_block_scalar(
        &self,
        block: &RowBlock,
        scratch: &mut ForestScratch,
        out: &mut Vec<f32>,
    ) {
        self.view().predict_block_scalar(block, scratch, out);
    }

    /// Probabilities for row-major flat rows (the RPC wire layout), written
    /// into `out` (`rows.len() >= out.len() * row_len`; `row_len` must cover
    /// `n_features`). Taking a sub-slice of `out` shards the batch.
    pub fn predict_flat_rows(
        &self,
        rows: &[f32],
        row_len: usize,
        scratch: &mut ForestScratch,
        out: &mut [f32],
    ) {
        self.view().predict_flat_rows(rows, row_len, scratch, out);
    }
}

impl ForestView<'_> {
    /// Nodes in the arena.
    pub fn n_nodes(&self) -> usize {
        self.feat.len()
    }

    /// Materialize an owned [`FlatForest`] from the view — five straight
    /// `memcpy`s of the SoA arrays, no per-node rebuild.
    pub fn materialize(&self) -> FlatForest {
        FlatForest {
            feat: self.feat.to_vec(),
            thresh: self.thresh.to_vec(),
            lo: self.lo.to_vec(),
            value: self.value.to_vec(),
            roots: self.roots.to_vec(),
            base_score: self.base_score,
            n_features: self.n_features,
        }
    }

    /// Check every structural invariant the walk kernels index by:
    ///
    /// * the four SoA arrays are parallel (equal lengths);
    /// * every root is in-arena;
    /// * every interior node's children `lo`/`lo + 1` are in-arena, FOLLOW
    ///   their parent (`lo > i` — the BFS emission order), and its split
    ///   feature is `< n_features`.
    ///
    /// A forest that passes cannot read out of bounds in the walk kernels
    /// for any input row of width `>= n_features`, and every walk
    /// terminates (indices strictly increase) — the snapshot loader's
    /// panic-free guarantee.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.feat.len();
        if self.thresh.len() != n || self.lo.len() != n || self.value.len() != n {
            return Err(format!(
                "SoA arrays not parallel: feat={n} thresh={} lo={} value={}",
                self.thresh.len(),
                self.lo.len(),
                self.value.len()
            ));
        }
        for (t, &root) in self.roots.iter().enumerate() {
            if root as usize >= n {
                return Err(format!("tree {t}: root {root} out of arena (n_nodes={n})"));
            }
        }
        for i in 0..n {
            let f = self.feat[i];
            if f == LEAF {
                continue;
            }
            if f as usize >= self.n_features {
                return Err(format!(
                    "node {i}: split feature {f} >= n_features {}",
                    self.n_features
                ));
            }
            // Both children live at lo and lo + 1; BFS order places them
            // strictly after their parent, which is also what guarantees
            // every walk terminates on arbitrary (even adversarial) bytes.
            if self.lo[i] as usize <= i {
                return Err(format!(
                    "node {i}: child index {} does not follow its parent (BFS order)",
                    self.lo[i]
                ));
            }
            if self.lo[i] as usize + 1 >= n {
                return Err(format!(
                    "node {i}: children at {}..={} out of arena (n_nodes={n})",
                    self.lo[i],
                    self.lo[i] as u64 + 1
                ));
            }
        }
        Ok(())
    }

    /// Margin for one row — bit-identical to
    /// [`GbdtModel::predict_margin_one`].
    #[inline]
    pub fn predict_margin_one(&self, row: &[f32]) -> f64 {
        let mut m = self.base_score;
        for &root in self.roots {
            let mut i = root as usize;
            loop {
                let f = self.feat[i];
                if f == LEAF {
                    m += self.value[i] as f64;
                    break;
                }
                let x = row[f as usize];
                i = (self.lo[i] + u32::from(!(x <= self.thresh[i]))) as usize;
            }
        }
        m
    }

    /// Probability for one row — bit-identical to
    /// [`GbdtModel::predict_one`].
    #[inline]
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        sigmoid(self.predict_margin_one(row)) as f32
    }

    /// See [`FlatForest::predict_block`].
    pub fn predict_block(&self, block: &RowBlock, scratch: &mut ForestScratch, out: &mut Vec<f32>) {
        let n = block.n_rows();
        out.clear();
        out.resize(n, 0.0);
        self.predict_with(n, |r, f| block.get(r, f as usize), scratch, out, true);
    }

    /// See [`FlatForest::predict_block_scalar`].
    pub fn predict_block_scalar(
        &self,
        block: &RowBlock,
        scratch: &mut ForestScratch,
        out: &mut Vec<f32>,
    ) {
        let n = block.n_rows();
        out.clear();
        out.resize(n, 0.0);
        self.predict_with(n, |r, f| block.get(r, f as usize), scratch, out, false);
    }

    /// See [`FlatForest::predict_flat_rows`].
    pub fn predict_flat_rows(
        &self,
        rows: &[f32],
        row_len: usize,
        scratch: &mut ForestScratch,
        out: &mut [f32],
    ) {
        let n = out.len();
        debug_assert!(rows.len() >= n * row_len);
        debug_assert!(row_len >= self.n_features);
        self.predict_with(n, |r, f| rows[r * row_len + f as usize], scratch, out, true);
    }

    /// Shared block kernel over an arbitrary `(row, feat) -> x` accessor.
    /// `lanes = false` forces the plain per-row walk.
    fn predict_with<G: Fn(usize, u32) -> f32>(
        &self,
        n: usize,
        get: G,
        scratch: &mut ForestScratch,
        out: &mut [f32],
        lanes: bool,
    ) {
        debug_assert_eq!(out.len(), n);
        let margins = &mut scratch.margins;
        margins.clear();
        margins.resize(n, self.base_score);
        let (feat, thresh, lo, value) = (self.feat, self.thresh, self.lo, self.value);
        for &root in self.roots {
            let mut r = 0usize;
            if lanes {
                // Lane-tiled walk: LANES independent row walks advance in
                // lockstep under a pending mask. Each pass visits only the
                // still-walking lanes (bit iteration skips parked ones),
                // loads `feat` to classify, and either retires the lane
                // (leaf: one `value` load) or advances it with the
                // branchless compare `lo + !(x <= thresh)` — so the
                // unrelated SoA loads of different lanes overlap in the
                // memory pipeline.
                while r + LANES <= n {
                    let mut idx = [root; LANES];
                    let mut val = [0f32; LANES];
                    let mut pending: u32 = (1 << LANES) - 1;
                    while pending != 0 {
                        let mut p = pending;
                        while p != 0 {
                            let k = p.trailing_zeros() as usize;
                            p &= p - 1;
                            let i = idx[k] as usize;
                            let f = feat[i];
                            if f == LEAF {
                                val[k] = value[i];
                                pending &= !(1 << k);
                            } else {
                                let x = get(r + k, f);
                                idx[k] = lo[i] + u32::from(!(x <= thresh[i]));
                            }
                        }
                    }
                    for (k, &v) in val.iter().enumerate() {
                        margins[r + k] += v as f64;
                    }
                    r += LANES;
                }
            }
            // Remainder rows (or the whole block in scalar mode): plain
            // iterative walk — the same per-row comparisons in the same
            // order, so where the tile boundary falls cannot change bits.
            while r < n {
                let mut i = root as usize;
                loop {
                    let f = feat[i];
                    if f == LEAF {
                        margins[r] += value[i] as f64;
                        break;
                    }
                    let x = get(r, f);
                    i = (lo[i] + u32::from(!(x <= thresh[i]))) as usize;
                }
                r += 1;
            }
        }
        for (o, &m) in out.iter_mut().zip(margins.iter()) {
            *o = sigmoid(m) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::{train, GbdtParams};
    use crate::tabular::{Dataset, Schema};
    use crate::util::rng::Rng;

    fn trained() -> (GbdtModel, Dataset) {
        let mut rng = Rng::new(23);
        let mut d = Dataset::new(Schema::numeric(4));
        for _ in 0..2000 {
            let x: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            let y = (x[0] * x[1] + x[2] > 0.3) as u8 as f32;
            d.push_row(&x, y);
        }
        let m = train(&d, &GbdtParams { n_trees: 17, max_depth: 5, ..Default::default() });
        (m, d)
    }

    #[test]
    fn flat_matches_native_bitwise() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let mut row = Vec::new();
        for r in 0..300 {
            d.row_into(r, &mut row);
            assert_eq!(
                flat.predict_one(&row).to_bits(),
                m.predict_one(&row).to_bits(),
                "row {r}"
            );
        }
    }

    #[test]
    fn block_matches_scalar_bitwise_all_chunks() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let mut rows: Vec<Vec<f32>> = (0..100).map(|r| d.row(r)).collect();
        rows[5][1] = f32::NAN; // NaN must route right, identically.
        rows[31] = vec![f32::NAN; 4];
        let mut scratch = ForestScratch::default();
        let mut out = Vec::new();
        let mut out_scalar = Vec::new();
        // Chunk sizes straddle the lane tile: every remainder 1..LANES-1
        // plus exact and off-by-one tiles.
        for chunk in [1usize, 3, LANES - 1, LANES, LANES + 1, 64, 100] {
            for rows in rows.chunks(chunk) {
                let block = RowBlock::from_rows(rows);
                flat.predict_block(&block, &mut scratch, &mut out);
                flat.predict_block_scalar(&block, &mut scratch, &mut out_scalar);
                for (i, row) in rows.iter().enumerate() {
                    assert_eq!(
                        out[i].to_bits(),
                        m.predict_one(row).to_bits(),
                        "chunk {chunk} row {i}"
                    );
                    assert_eq!(
                        out[i].to_bits(),
                        out_scalar[i].to_bits(),
                        "lane walk vs scalar walk, chunk {chunk} row {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn flat_rows_match_block_with_padding() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let n = 50;
        let row_len = d.n_features() + 3; // padded wire rows
        let mut flat_rows = vec![0f32; n * row_len];
        let mut row = Vec::new();
        for r in 0..n {
            d.row_into(r, &mut row);
            flat_rows[r * row_len..r * row_len + row.len()].copy_from_slice(&row);
        }
        let mut scratch = ForestScratch::default();
        let mut out = vec![0f32; n];
        flat.predict_flat_rows(&flat_rows, row_len, &mut scratch, &mut out);
        for r in 0..n {
            d.row_into(r, &mut row);
            assert_eq!(out[r].to_bits(), m.predict_one(&row).to_bits(), "row {r}");
        }
    }

    #[test]
    fn arena_children_adjacent_soa() {
        let (m, _) = trained();
        let flat = FlatForest::from_model(&m);
        assert_eq!(flat.roots.len(), m.trees.len());
        let total: usize = m.trees.iter().map(|t| t.nodes.len()).sum();
        assert_eq!(flat.n_nodes(), total);
        // The SoA arrays stay parallel.
        assert_eq!(flat.thresh.len(), total);
        assert_eq!(flat.lo.len(), total);
        assert_eq!(flat.value.len(), total);
        for i in 0..flat.n_nodes() {
            if flat.feat[i] != LEAF {
                // Both children (lo, lo + 1) must be in-arena.
                assert!(flat.lo[i] as usize + 1 < flat.n_nodes());
            }
        }
    }

    #[test]
    fn validate_accepts_every_trained_forest() {
        let (m, _) = trained();
        let flat = FlatForest::from_model(&m);
        flat.validate().expect("trained forests are well-formed");
        // And the fallible constructor round-trips the same nodes.
        let mut nodes = Vec::new();
        let mut roots = Vec::new();
        for t in &m.trees {
            roots.push(nodes.len() as u32);
            t.flatten_into(&mut nodes);
        }
        let rebuilt =
            FlatForest::try_from_nodes(&nodes, roots, m.base_score, m.n_features).unwrap();
        assert_eq!(rebuilt.n_nodes(), flat.n_nodes());
    }

    #[test]
    fn validate_rejects_corrupt_arenas() {
        let (m, _) = trained();
        let good = FlatForest::from_model(&m);
        let interior = (0..good.n_nodes())
            .find(|&i| good.feat[i] != LEAF)
            .expect("trained forest has splits");

        // Out-of-arena root.
        let mut f = good.clone();
        f.roots[0] = f.n_nodes() as u32;
        assert!(f.validate().unwrap_err().contains("root"));

        // Split feature past the row width.
        let mut f = good.clone();
        f.feat[interior] = f.n_features as u32;
        assert!(f.validate().unwrap_err().contains("n_features"));

        // Children walking off the end of the arena.
        let mut f = good.clone();
        f.lo[interior] = f.n_nodes() as u32;
        assert!(f.validate().is_err());

        // A backward child edge (cycle) — must be rejected so walks on
        // untrusted bytes always terminate.
        let mut f = good.clone();
        f.lo[interior] = interior as u32;
        assert!(f.validate().unwrap_err().contains("BFS"));

        // Non-parallel SoA arrays.
        let mut f = good.clone();
        f.thresh.pop();
        assert!(f.validate().unwrap_err().contains("parallel"));

        // try_from_nodes surfaces the same failure.
        let nodes = [FlatNode { feat: 0, thresh: 0.0, lo: 7, value: 0.0 }];
        assert!(FlatForest::try_from_nodes(&nodes, vec![0], 0.0, 4).is_err());
    }

    #[test]
    fn view_serves_identically_and_materializes_round_trip() {
        let (m, d) = trained();
        let flat = FlatForest::from_model(&m);
        let view = flat.view();
        view.validate().expect("view validates like the owner");
        let rows: Vec<Vec<f32>> = (0..80).map(|r| d.row(r)).collect();
        let block = RowBlock::from_rows(&rows);
        let mut scratch = ForestScratch::default();
        let (mut owned, mut viewed) = (Vec::new(), Vec::new());
        flat.predict_block(&block, &mut scratch, &mut owned);
        view.predict_block(&block, &mut scratch, &mut viewed);
        for r in 0..rows.len() {
            assert_eq!(owned[r].to_bits(), viewed[r].to_bits(), "row {r}");
        }
        // Materialization is a bit-exact copy of the arena.
        let copy = view.materialize();
        assert_eq!(copy.feat, flat.feat);
        assert_eq!(copy.roots, flat.roots);
        assert_eq!(copy.base_score.to_bits(), flat.base_score.to_bits());
        let mut from_copy = Vec::new();
        copy.predict_block(&block, &mut scratch, &mut from_copy);
        for r in 0..rows.len() {
            assert_eq!(owned[r].to_bits(), from_copy[r].to_bits(), "row {r}");
        }
    }

    #[test]
    fn empty_and_leaf_trees() {
        use crate::gbdt::Tree;
        let m = GbdtModel {
            trees: vec![Tree::leaf(0.25), Tree::default(), Tree::leaf(-0.5)],
            base_score: 0.1,
            n_features: 2,
            feature_gain: vec![0.0; 2],
            max_depth: 1,
        };
        let flat = FlatForest::from_model(&m);
        // Empty trees flatten to a zero-valued leaf; margin = 0.1 + 0.25 - 0.5.
        let p = flat.predict_one(&[1.0, 2.0]);
        assert!((p - crate::util::sigmoid(-0.15) as f32).abs() < 1e-7);
    }
}
