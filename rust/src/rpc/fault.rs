//! Failure-model primitives for the serving stack: per-request deadlines,
//! bounded retry with exponential backoff + jitter and a shared retry
//! budget, and a circuit breaker (closed / open / half-open).
//!
//! These are the pieces the fault-tolerance layer is assembled from
//! (coordinator → [`RpcClient`](crate::rpc::RpcClient) → server batcher →
//! [`ShardPool`](crate::runtime::ShardPool)):
//!
//! * [`Deadline`] — an absolute per-request budget that travels with the
//!   request (the remaining budget is re-encoded at every hop:
//!   `deadline_us` in the request frame header). Every hop sheds work whose
//!   deadline already passed instead of computing an answer nobody is
//!   waiting for.
//! * [`RetryPolicy`] + [`RetryBudget`] — bounded transparent retries on
//!   transport failures with exponential backoff and jitter, gated by a
//!   token-bucket budget replenished by successes, so a hard-down backend
//!   costs a bounded number of extra dials instead of a retry storm.
//! * [`CircuitBreaker`] — trips open on consecutive transport failures (or
//!   a p99 latency breach), fails calls fast while open, and probes with a
//!   half-open trial call after a cooldown. The breaker is what lets the
//!   coordinator degrade to stage-1-only service *before* burning the
//!   request's latency budget on a backend that is known to be down.
//!
//! Failure classification helpers ([`is_deadline_exceeded`],
//! [`is_breaker_open`], [`is_overloaded`]) let callers tell "the budget ran
//! out", "we never tried", and "the server told us to back off" apart from
//! ordinary transport errors — the coordinator's degradation accounting and
//! the client's retry discipline depend on the distinction. In particular
//! an [`Overloaded`] rejection (explicit admission-control refusal carrying
//! a retry-after hint) must never count toward the breaker's consecutive
//! failures and must never be retried faster than the hint — otherwise
//! rejection turns into a retry storm aimed at a server that just said it
//! is drowning.

use crate::util::histogram::Histogram;
use crate::util::rng::Rng;
use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Deadline

/// Absolute per-request deadline. `Copy`, so it travels with requests and
/// tasks for free; the *remaining* budget is what gets encoded on the wire
/// (`deadline_us`), so each hop measures against its own clock and clock
/// skew never accumulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Deadline(Instant);

impl Deadline {
    /// Deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline(Instant::now() + budget)
    }

    /// Deadline at an absolute instant.
    pub fn at(t: Instant) -> Deadline {
        Deadline(t)
    }

    /// The absolute instant.
    pub fn instant(&self) -> Instant {
        self.0
    }

    /// Budget left; `Duration::ZERO` once expired.
    pub fn remaining(&self) -> Duration {
        self.0.saturating_duration_since(Instant::now())
    }

    /// True once the budget is exhausted.
    pub fn expired(&self) -> bool {
        self.remaining() == Duration::ZERO
    }

    /// Remaining budget in whole microseconds for the wire (`deadline_us`
    /// request-header field), clamped to `1..=u32::MAX` — 0 is the wire's
    /// "no deadline" sentinel, so an expired-but-sent deadline encodes as 1
    /// and the receiving hop sheds it on arrival.
    pub fn remaining_us(&self) -> u32 {
        (self.remaining().as_micros().min(u32::MAX as u128) as u32).max(1)
    }

    /// Decode a wire `deadline_us` (0 = none) against this hop's clock.
    pub fn from_wire_us(us: u32) -> Option<Deadline> {
        if us == 0 {
            None
        } else {
            Some(Deadline::after(Duration::from_micros(us as u64)))
        }
    }
}

/// Per-call options threaded through the serving entry points. `Default`
/// keeps the pre-deadline behavior (no budget, never shed, full priority).
#[derive(Clone, Copy, Debug, Default)]
pub struct PredictOptions {
    /// Absolute deadline for the whole request; work still pending at the
    /// deadline is shed at whichever hop notices first.
    pub deadline: Option<Deadline>,
    /// Low-priority traffic is the first to be browned out: under measured
    /// pressure the coordinator answers it from the stage-1 prior
    /// (`Served::Degraded`) instead of spending second-stage capacity.
    pub low_priority: bool,
    /// Stable request identity for canary routing during a guarded rollout:
    /// the coordinator hashes it deterministically to decide whether this
    /// request serves the candidate version — the same key always routes
    /// the same way at a given ramp step, so a canary run is replayable.
    /// `None` lets the coordinator assign an internal sequence key.
    /// Client-side only; never rides the wire.
    pub rollout_key: Option<u64>,
}

impl PredictOptions {
    /// Options with a deadline `budget` from now.
    pub fn with_budget(budget: Duration) -> PredictOptions {
        PredictOptions {
            deadline: Some(Deadline::after(budget)),
            ..PredictOptions::default()
        }
    }

    /// Mark this call sheddable-first under brownout.
    pub fn low_priority(mut self) -> PredictOptions {
        self.low_priority = true;
        self
    }

    /// Attach a stable canary-routing key (see
    /// [`PredictOptions::rollout_key`]).
    pub fn rollout_key(mut self, key: u64) -> PredictOptions {
        self.rollout_key = Some(key);
        self
    }
}

// ---------------------------------------------------------------------------
// Error classification

/// Marker payload for "the request's deadline expired" errors.
#[derive(Debug)]
pub struct DeadlineExceeded;

impl std::fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request deadline exceeded")
    }
}

impl std::error::Error for DeadlineExceeded {}

/// Marker payload for "the circuit breaker is open, call not attempted".
#[derive(Debug)]
pub struct BreakerOpen;

impl std::fmt::Display for BreakerOpen {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "circuit breaker open: second stage unavailable")
    }
}

impl std::error::Error for BreakerOpen {}

/// Marker payload for "the server explicitly rejected the request under
/// overload" — admission-control quota breach, global in-flight cap, or a
/// CoDel sojourn shed. Distinct from transport failures (the server is
/// healthy and answered) and from deadline expiry (the budget is intact):
/// the right reaction is to back off for `retry_after`, not to retry-storm
/// and not to burn breaker failure counts.
#[derive(Debug)]
pub struct Overloaded {
    /// Server-suggested pause before the next attempt.
    pub retry_after: Duration,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "server overloaded: retry after {}ms",
            self.retry_after.as_millis()
        )
    }
}

impl std::error::Error for Overloaded {}

/// An error carrying [`DeadlineExceeded`].
pub fn deadline_error() -> io::Error {
    io::Error::new(io::ErrorKind::TimedOut, DeadlineExceeded)
}

/// An error carrying [`BreakerOpen`].
pub fn breaker_error() -> io::Error {
    io::Error::new(io::ErrorKind::ConnectionRefused, BreakerOpen)
}

/// True if `e` is a deadline expiry (this hop's or a downstream one's).
pub fn is_deadline_exceeded(e: &io::Error) -> bool {
    e.get_ref().is_some_and(|inner| inner.is::<DeadlineExceeded>())
}

/// True if `e` is a breaker fast-fail (the call was never attempted).
pub fn is_breaker_open(e: &io::Error) -> bool {
    e.get_ref().is_some_and(|inner| inner.is::<BreakerOpen>())
}

/// An error carrying [`Overloaded`]. `WouldBlock` is the closest stdlib
/// kind: the server is alive but refuses to take the work right now.
pub fn overloaded_error(retry_after: Duration) -> io::Error {
    io::Error::new(io::ErrorKind::WouldBlock, Overloaded { retry_after })
}

/// True if `e` is an explicit server-side rejection (admission or shed).
pub fn is_overloaded(e: &io::Error) -> bool {
    e.get_ref().is_some_and(|inner| inner.is::<Overloaded>())
}

/// The server's retry-after hint, if `e` is an [`Overloaded`] rejection.
pub fn retry_after(e: &io::Error) -> Option<Duration> {
    e.get_ref()
        .and_then(|inner| inner.downcast_ref::<Overloaded>())
        .map(|o| o.retry_after)
}

// ---------------------------------------------------------------------------
// Retry policy + budget

/// Bounded-retry policy with exponential backoff and jitter. Governs how
/// the client reacts to *transport* failures (stale pooled connections,
/// reader death mid-response); application errors (the server answered
/// with an error frame) are never retried — the server already saw and
/// rejected the request.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Extra attempts after the first (0 disables retrying entirely).
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per subsequent retry.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Fraction of each backoff that is randomized (0 = deterministic,
    /// 1 = full jitter): `sleep = backoff · (1 - jitter·U[0,1))`.
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 2,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(200),
            jitter: 0.5,
        }
    }
}

impl RetryPolicy {
    /// Policy that never retries (the embedded path, and A/B baselines).
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            ..Default::default()
        }
    }

    /// Jittered backoff before retry number `retry` (1-based).
    pub fn backoff(&self, retry: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .base_backoff
            .saturating_mul(1u32 << (retry.saturating_sub(1)).min(16))
            .min(self.max_backoff);
        let scale = 1.0 - self.jitter.clamp(0.0, 1.0) * rng.f64();
        Duration::from_nanos((exp.as_nanos() as f64 * scale) as u64)
    }
}

/// Token-bucket retry budget shared by every request on a client: each
/// *success* deposits a fraction of a token, each retry withdraws a whole
/// one. Under a healthy backend the bucket stays full and retries are
/// free; under a hard-down backend the bucket drains and retries stop —
/// callers fail fast instead of amplifying the outage with a dial storm.
pub struct RetryBudget {
    /// Milli-tokens, so fractional deposits stay integral.
    millitokens: AtomicU64,
    cap: u64,
    deposit: u64,
}

impl RetryBudget {
    /// Budget holding up to `cap` retries, replenished `per_success`
    /// tokens per recorded success. Starts full.
    pub fn new(cap: f64, per_success: f64) -> RetryBudget {
        let cap_mt = (cap.max(0.0) * 1000.0) as u64;
        RetryBudget {
            millitokens: AtomicU64::new(cap_mt),
            cap: cap_mt,
            deposit: (per_success.max(0.0) * 1000.0) as u64,
        }
    }

    /// Record one successful call (replenishes the bucket).
    pub fn deposit(&self) {
        let cap = self.cap;
        let _ = self
            .millitokens
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |t| {
                Some((t + self.deposit).min(cap))
            });
    }

    /// Try to pay for one retry; `false` = budget exhausted, don't retry.
    pub fn try_withdraw(&self) -> bool {
        self.millitokens
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |t| {
                t.checked_sub(1000)
            })
            .is_ok()
    }

    /// Whole retries currently affordable (telemetry).
    pub fn available(&self) -> u64 {
        self.millitokens.load(Ordering::Relaxed) / 1000
    }
}

impl Default for RetryBudget {
    /// 10 retries capacity, +0.1 per success (≤ ~10% retry amplification
    /// in steady state — the classic Finagle-style budget shape).
    fn default() -> Self {
        RetryBudget::new(10.0, 0.1)
    }
}

// ---------------------------------------------------------------------------
// Circuit breaker

/// Breaker states. `Closed` = calls flow; `Open` = calls fail fast;
/// `HalfOpen` = a trial call probes the backend after the cooldown — its
/// success re-closes the breaker, its failure re-opens it for another
/// cooldown.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// Breaker tuning.
#[derive(Clone, Debug)]
pub struct BreakerConfig {
    /// Consecutive transport failures that trip the breaker open.
    pub failure_threshold: u32,
    /// How long an open breaker fails fast before probing half-open.
    pub cooldown: Duration,
    /// Optional latency rule: trip when observed success p99 exceeds this
    /// (the SLO-breach trigger; `None` disables it).
    pub p99_limit: Option<Duration>,
    /// Minimum successes observed before the p99 rule may fire.
    pub min_p99_samples: u64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            failure_threshold: 5,
            cooldown: Duration::from_millis(250),
            p99_limit: None,
            min_p99_samples: 64,
        }
    }
}

struct BreakerInner {
    state: BreakerState,
    consecutive_failures: u32,
    opened_at: Option<Instant>,
    /// Manually forced open (tests, drills, maintenance): stays open until
    /// [`CircuitBreaker::force_close`], ignoring the cooldown probe.
    forced: bool,
}

/// Closed / open / half-open circuit breaker over the second-stage RPC.
/// Thread-safe; the hot path cost is one short mutex hold per call.
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    inner: Mutex<BreakerInner>,
    /// Success-latency histogram feeding the p99 rule.
    latency: Histogram,
    /// Closed/half-open → open transitions (observable in reports).
    pub trips: AtomicU64,
    /// All state transitions.
    pub transitions: AtomicU64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                opened_at: None,
                forced: false,
            }),
            latency: Histogram::new(),
            trips: AtomicU64::new(0),
            transitions: AtomicU64::new(0),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BreakerInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn transition(&self, inner: &mut BreakerInner, to: BreakerState) {
        if inner.state == to {
            return;
        }
        if to == BreakerState::Open {
            inner.opened_at = Some(Instant::now());
            self.trips.fetch_add(1, Ordering::Relaxed);
        }
        inner.state = to;
        self.transitions.fetch_add(1, Ordering::Relaxed);
    }

    /// May a call proceed right now? Open → `false` (fail fast) until the
    /// cooldown elapses, then ONE caller is admitted as the half-open
    /// probe; half-open admits (the probe outcome decides what's next).
    pub fn admit(&self) -> bool {
        let mut inner = self.lock();
        match inner.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                if inner.forced {
                    return false;
                }
                let cooled = match inner.opened_at {
                    Some(t) => t.elapsed() >= self.cfg.cooldown,
                    None => true,
                };
                if cooled {
                    self.transition(&mut inner, BreakerState::HalfOpen);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a successful call (with its latency, feeding the p99 rule).
    /// A half-open probe's success re-closes the breaker.
    pub fn record_success(&self, latency: Duration) {
        self.latency.record_duration(latency);
        let mut inner = self.lock();
        inner.consecutive_failures = 0;
        if inner.state == BreakerState::HalfOpen && !inner.forced {
            self.transition(&mut inner, BreakerState::Closed);
        }
        // SLO-breach rule: sustained p99 above the limit trips the breaker
        // even though calls are "succeeding" — latency is the contract.
        if let Some(limit) = self.cfg.p99_limit {
            if inner.state == BreakerState::Closed
                && self.latency.count() >= self.cfg.min_p99_samples
                && self.latency.quantile_ns(0.99) > limit.as_nanos() as u64
            {
                self.transition(&mut inner, BreakerState::Open);
                drop(inner);
                self.latency.reset();
            }
        }
    }

    /// Record a failed call. Trips open on the threshold's consecutive
    /// failure (or immediately when the half-open probe fails).
    pub fn record_failure(&self) {
        let mut inner = self.lock();
        inner.consecutive_failures = inner.consecutive_failures.saturating_add(1);
        let trip = match inner.state {
            BreakerState::HalfOpen => true,
            BreakerState::Closed => {
                inner.consecutive_failures >= self.cfg.failure_threshold
            }
            BreakerState::Open => false,
        };
        if trip {
            self.transition(&mut inner, BreakerState::Open);
        }
    }

    pub fn state(&self) -> BreakerState {
        self.lock().state
    }

    /// Force the breaker open until [`CircuitBreaker::force_close`] —
    /// no half-open probes. For tests, chaos drills, and maintenance.
    pub fn force_open(&self) {
        let mut inner = self.lock();
        inner.forced = true;
        self.transition(&mut inner, BreakerState::Open);
    }

    /// Clear a forced-open (or any) state back to closed.
    pub fn force_close(&self) {
        let mut inner = self.lock();
        inner.forced = false;
        inner.consecutive_failures = 0;
        self.transition(&mut inner, BreakerState::Closed);
    }
}

impl Default for CircuitBreaker {
    fn default() -> Self {
        CircuitBreaker::new(BreakerConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_budget_and_wire_roundtrip() {
        let d = Deadline::after(Duration::from_millis(50));
        assert!(!d.expired());
        assert!(d.remaining() <= Duration::from_millis(50));
        let us = d.remaining_us();
        assert!(us > 0 && us <= 50_000);
        let decoded = Deadline::from_wire_us(us).unwrap();
        assert!(decoded.remaining() <= Duration::from_micros(us as u64));
        assert!(Deadline::from_wire_us(0).is_none());

        let past = Deadline::at(Instant::now() - Duration::from_millis(1));
        assert!(past.expired());
        assert_eq!(past.remaining(), Duration::ZERO);
        // Expired deadlines still encode as a (minimal) live wire value,
        // never as the "no deadline" sentinel.
        assert_eq!(past.remaining_us(), 1);
    }

    #[test]
    fn deadline_errors_classify() {
        let e = deadline_error();
        assert!(is_deadline_exceeded(&e));
        assert!(!is_breaker_open(&e));
        let b = breaker_error();
        assert!(is_breaker_open(&b));
        assert!(!is_deadline_exceeded(&b));
        let plain = io::Error::new(io::ErrorKind::TimedOut, "ordinary timeout");
        assert!(!is_deadline_exceeded(&plain));
        assert!(!is_breaker_open(&plain));
    }

    #[test]
    fn overloaded_errors_classify_and_carry_the_hint() {
        let o = overloaded_error(Duration::from_millis(40));
        assert!(is_overloaded(&o));
        assert_eq!(retry_after(&o), Some(Duration::from_millis(40)));
        assert!(!is_deadline_exceeded(&o));
        assert!(!is_breaker_open(&o));

        // Other marker errors and plain I/O errors carry no hint.
        assert!(!is_overloaded(&deadline_error()));
        assert_eq!(retry_after(&breaker_error()), None);
        let plain = io::Error::new(io::ErrorKind::WouldBlock, "plain wouldblock");
        assert!(!is_overloaded(&plain));
        assert_eq!(retry_after(&plain), None);
    }

    #[test]
    fn low_priority_options_compose() {
        let o = PredictOptions::with_budget(Duration::from_millis(5)).low_priority();
        assert!(o.low_priority);
        assert!(o.deadline.is_some());
        assert!(!PredictOptions::default().low_priority);
    }

    #[test]
    fn backoff_doubles_and_caps_with_jitter_bounds() {
        let p = RetryPolicy {
            max_retries: 5,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(50),
            jitter: 0.5,
        };
        let mut rng = Rng::new(7);
        for retry in 1..=5u32 {
            let nominal = Duration::from_millis(10)
                .saturating_mul(1 << (retry - 1))
                .min(Duration::from_millis(50));
            for _ in 0..100 {
                let b = p.backoff(retry, &mut rng);
                assert!(b <= nominal, "retry {retry}: {b:?} > {nominal:?}");
                // jitter 0.5 ⇒ at least half the nominal backoff remains.
                assert!(
                    b.as_secs_f64() >= nominal.as_secs_f64() * 0.5 - 1e-9,
                    "retry {retry}: {b:?} below jitter floor"
                );
            }
        }
        // Zero jitter is deterministic.
        let det = RetryPolicy { jitter: 0.0, ..p };
        assert_eq!(det.backoff(1, &mut rng), Duration::from_millis(10));
        assert_eq!(det.backoff(2, &mut rng), Duration::from_millis(20));
        assert_eq!(det.backoff(4, &mut rng), Duration::from_millis(50), "capped");
    }

    #[test]
    fn retry_budget_drains_and_replenishes() {
        let b = RetryBudget::new(2.0, 0.5);
        assert_eq!(b.available(), 2);
        assert!(b.try_withdraw());
        assert!(b.try_withdraw());
        assert!(!b.try_withdraw(), "drained budget must refuse");
        // Two successes buy back one retry at 0.5/success.
        b.deposit();
        assert!(!b.try_withdraw());
        b.deposit();
        assert!(b.try_withdraw());
        // Deposits cap at the bucket size.
        for _ in 0..100 {
            b.deposit();
        }
        assert_eq!(b.available(), 2);
    }

    #[test]
    fn breaker_trips_on_consecutive_failures_and_probes_half_open() {
        let br = CircuitBreaker::new(BreakerConfig {
            failure_threshold: 3,
            cooldown: Duration::from_millis(20),
            ..Default::default()
        });
        assert_eq!(br.state(), BreakerState::Closed);
        // A success in between resets the consecutive count.
        br.record_failure();
        br.record_failure();
        br.record_success(Duration::from_micros(100));
        br.record_failure();
        br.record_failure();
        assert_eq!(br.state(), BreakerState::Closed);
        br.record_failure();
        assert_eq!(br.state(), BreakerState::Open);
        assert_eq!(br.trips.load(Ordering::Relaxed), 1);
        assert!(!br.admit(), "open breaker fails fast");

        // After the cooldown exactly one caller probes half-open.
        std::thread::sleep(Duration::from_millis(25));
        assert!(br.admit());
        assert_eq!(br.state(), BreakerState::HalfOpen);
        // Probe fails → re-open immediately.
        br.record_failure();
        assert_eq!(br.state(), BreakerState::Open);
        assert_eq!(br.trips.load(Ordering::Relaxed), 2);
        std::thread::sleep(Duration::from_millis(25));
        assert!(br.admit());
        // Probe succeeds → closed again.
        br.record_success(Duration::from_micros(100));
        assert_eq!(br.state(), BreakerState::Closed);
        assert!(br.admit());
    }

    #[test]
    fn breaker_force_open_ignores_cooldown_until_force_close() {
        let br = CircuitBreaker::new(BreakerConfig {
            cooldown: Duration::from_millis(1),
            ..Default::default()
        });
        br.force_open();
        assert_eq!(br.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(5));
        assert!(!br.admit(), "forced-open never probes");
        // A stray success must not close a forced-open breaker.
        br.record_success(Duration::from_micros(50));
        assert_eq!(br.state(), BreakerState::Open);
        br.force_close();
        assert_eq!(br.state(), BreakerState::Closed);
        assert!(br.admit());
    }

    #[test]
    fn breaker_p99_breach_trips() {
        let br = CircuitBreaker::new(BreakerConfig {
            failure_threshold: 1000, // only the latency rule can trip
            p99_limit: Some(Duration::from_millis(1)),
            min_p99_samples: 10,
            ..Default::default()
        });
        for _ in 0..9 {
            br.record_success(Duration::from_millis(10));
        }
        assert_eq!(br.state(), BreakerState::Closed, "below min samples");
        br.record_success(Duration::from_millis(10));
        assert_eq!(br.state(), BreakerState::Open, "p99 breach must trip");
        assert_eq!(br.trips.load(Ordering::Relaxed), 1);
    }
}
