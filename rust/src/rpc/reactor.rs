//! Event-driven server core: epoll reactor replacing thread-per-connection.
//!
//! The threaded path in [`super::server`] spends a reader thread per
//! connection, a pacing thread per streamed job, and a hop thread per
//! simulated-delay response — at C10K that is the scaling wall, not the
//! math. This module replaces all of it with one nonblocking acceptor and a
//! small fixed set of I/O event loops (raw `libc::epoll`, no new deps):
//!
//! - **Loops.** Each loop owns an epoll instance, an eventfd for cross-
//!   thread wakeups, and a slab of connection states. Loop 0 additionally
//!   owns the nonblocking listener and round-robins accepted connections
//!   across all loops (handing a socket to another loop through its
//!   injection list + eventfd). A connection is touched only by its owning
//!   loop; producers (batcher workers, shard-pool sinks) talk to it solely
//!   through its [`Outbox`].
//!
//! - **Connection state machine.** Readable → drain the socket into a
//!   resumable [`FrameDecoder`](super::proto::FrameDecoder) and admit every
//!   completed request to the shared batcher queue (the batcher/`ShardPool`
//!   are untouched by this refactor). Completed jobs enqueue encoded
//!   response/chunk frames on the connection's outbox; the loop flushes
//!   them with nonblocking writes, arming `EPOLLOUT` only while a flush is
//!   blocked on the socket. EOF/`RDHUP`/error closes the connection and
//!   error-completes everything still queued (counted, never silent).
//!
//! - **Deferred-flush timers.** The simulated network hop and the chaos
//!   stall faults are *due-times on frames* (and on pending admissions),
//!   served by the loop's timer heap — not sleeping threads. Pacing keeps
//!   the threaded path's monotone clamp so a chunk never overtakes its
//!   predecessor; the clamp is per connection here (strictly stronger than
//!   the per-stream clamp, and what a real single-path network does).
//!
//! - **Backpressure.** Outboxes are bounded ([`BatcherConfig::
//!   write_queue_frames`](super::server::BatcherConfig)); a producer that
//!   finds one full blocks on its condvar until the loop drains it, bounded
//!   by the same `WRITE_TIMEOUT` as the threaded path — a client that stops
//!   reading costs a bounded stall and its own connection, never a wedged
//!   shard.
//!
//! Every PR 6 contract holds on this path: `deadline_us` is re-anchored at
//! admission (after the simulated inbound hop, exactly like the threaded
//! hop thread), shedding/breaker/degrade live in the untouched batcher and
//! coordinator, error frames skip pacing, and chaos faults are drawn at
//! flush time per outbound frame with the same semantics as
//! `chaos_write` (reset/truncation kill the connection, corruption flips
//! the count/status byte, stalls defer the flush).

use super::netsim::{Fault, NetSim};
use super::proto::{self, FrameDecoder, Inbound, Request, Response};
use super::server::{Job, Queue, RespOut, WRITE_TIMEOUT};
use crate::telemetry::ReactorStats;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// epoll token of a loop's wake eventfd.
const WAKE_TOKEN: u64 = u64::MAX;
/// epoll token of the listener (loop 0 only).
const LISTEN_TOKEN: u64 = u64::MAX - 1;
/// Read buffer per drain pass; connections above this per event simply get
/// another level-triggered wakeup.
const READ_CHUNK: usize = 64 * 1024;
/// Events fetched per `epoll_wait`.
const MAX_EVENTS: usize = 256;

// ---------------------------------------------------------------- syscalls

fn epoll_create() -> std::io::Result<RawFd> {
    let fd = unsafe { libc::epoll_create1(libc::EPOLL_CLOEXEC) };
    if fd < 0 {
        return Err(std::io::Error::last_os_error());
    }
    Ok(fd)
}

fn epoll_ctl(ep: RawFd, op: libc::c_int, fd: RawFd, events: u32, token: u64) -> std::io::Result<()> {
    let mut ev = libc::epoll_event { events, u64: token };
    let rc = unsafe { libc::epoll_ctl(ep, op, fd, &mut ev) };
    if rc < 0 {
        return Err(std::io::Error::last_os_error());
    }
    Ok(())
}

const IN_EVENTS: u32 = (libc::EPOLLIN | libc::EPOLLRDHUP) as u32;
const INOUT_EVENTS: u32 = (libc::EPOLLIN | libc::EPOLLRDHUP | libc::EPOLLOUT) as u32;

fn new_eventfd() -> std::io::Result<RawFd> {
    let fd = unsafe { libc::eventfd(0, libc::EFD_NONBLOCK | libc::EFD_CLOEXEC) };
    if fd < 0 {
        return Err(std::io::Error::last_os_error());
    }
    Ok(fd)
}

fn write_wake(fd: RawFd) {
    let one: u64 = 1;
    // A full eventfd counter (EAGAIN) already guarantees a pending wakeup.
    unsafe { libc::write(fd, std::ptr::addr_of!(one).cast(), 8) };
}

fn drain_wake(fd: RawFd) {
    let mut cnt: u64 = 0;
    unsafe { libc::read(fd, std::ptr::addr_of_mut!(cnt).cast(), 8) };
}

// ------------------------------------------------------------- loop handle

/// The cross-thread face of one event loop: its wake eventfd plus the
/// injection lists other threads feed. Owns the eventfd — producers hold an
/// `Arc` through their outboxes, so the fd cannot be closed (and reused by
/// the OS) while anyone might still write a wakeup to it.
pub(crate) struct LoopShared {
    wake_fd: RawFd,
    /// Connections accepted by loop 0 awaiting registration on this loop.
    new_conns: Mutex<Vec<TcpStream>>,
    /// Slots whose outbox changed (new frames, or producer-side close)
    /// since the loop last looked.
    dirty: Mutex<Vec<u32>>,
}

impl LoopShared {
    fn new() -> std::io::Result<Arc<LoopShared>> {
        Ok(Arc::new(LoopShared {
            wake_fd: new_eventfd()?,
            new_conns: Mutex::new(Vec::new()),
            dirty: Mutex::new(Vec::new()),
        }))
    }

    fn notify_dirty(&self, slot: u32) {
        self.dirty
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(slot);
        write_wake(self.wake_fd);
    }

    fn inject_conn(&self, stream: TcpStream) {
        self.new_conns
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(stream);
        write_wake(self.wake_fd);
    }

    fn wake(&self) {
        write_wake(self.wake_fd);
    }
}

impl Drop for LoopShared {
    fn drop(&mut self) {
        unsafe { libc::close(self.wake_fd) };
    }
}

// ------------------------------------------------------------------ outbox

/// One queued outbound frame. `due` is the deferred-flush timer (simulated
/// hop pacing, or an injected stall); the chaos fault is drawn exactly once
/// per frame, at first flush attempt after the due-time — the same
/// draw-at-write-after-delay ordering as the threaded `chaos_write`.
struct OutFrame {
    buf: Vec<u8>,
    written: usize,
    due: Option<Instant>,
    fault: Option<Fault>,
    drawn: bool,
}

#[derive(Default)]
struct OutboxQ {
    frames: VecDeque<OutFrame>,
    /// Producer side sees the connection as gone; sends fail fast.
    closed: bool,
    /// Monotone pacing clamp: a paced frame is never due before its
    /// predecessor, so intra-stream order holds on the wire.
    last_due: Option<Instant>,
    /// A dirty notification for this slot is already pending with the loop.
    armed: bool,
}

/// Bounded per-connection write queue. Producers enqueue encoded frames
/// (blocking briefly under backpressure); only the owning loop dequeues and
/// writes.
pub(crate) struct Outbox {
    q: Mutex<OutboxQ>,
    space: Condvar,
    cap: usize,
    slot: u32,
    owner: Arc<LoopShared>,
    netsim: Arc<NetSim>,
    stats: Arc<ReactorStats>,
}

impl Outbox {
    fn lock_q(&self) -> MutexGuard<'_, OutboxQ> {
        self.q.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// A producer's handle on one reactor connection's write queue; held by
/// jobs and stream sinks in place of the threaded path's `SharedWriter`.
#[derive(Clone)]
pub(crate) struct ConnHandle(Arc<Outbox>);

/// The connection died (client hung up, chaos killed it, or it stopped
/// reading past the write timeout); the frame was not delivered.
#[derive(Debug)]
pub(crate) struct ConnDead;

impl ConnHandle {
    /// Queue one encoded frame for the owning loop to write. `paced` frames
    /// pay the simulated outbound hop as a deferred-flush due-time (clamped
    /// monotone per connection); error frames and pings pass `false` and
    /// flush immediately, exactly like the threaded path's hop skip.
    ///
    /// Blocks while the queue is full (backpressure), bounded by
    /// `WRITE_TIMEOUT` — on timeout the connection is condemned, mirroring
    /// the threaded blocking-write timeout.
    pub(crate) fn send(&self, buf: Vec<u8>, paced: bool) -> Result<(), ConnDead> {
        let ob = &self.0;
        let mut q = ob.lock_q();
        while q.frames.len() >= ob.cap && !q.closed {
            ob.stats.backpressure_stalls.fetch_add(1, Ordering::Relaxed);
            let (guard, timeout) = ob
                .space
                .wait_timeout(q, WRITE_TIMEOUT)
                .unwrap_or_else(PoisonError::into_inner);
            q = guard;
            if timeout.timed_out() && q.frames.len() >= ob.cap && !q.closed {
                // The client stopped draining its socket: kill the
                // connection rather than wedge a compute worker.
                ob.stats
                    .dead_conn_frames
                    .fetch_add(q.frames.len() as u64, Ordering::Relaxed);
                q.frames.clear();
                q.closed = true;
                let was_armed = std::mem::replace(&mut q.armed, true);
                drop(q);
                ob.space.notify_all();
                if !was_armed {
                    ob.owner.notify_dirty(ob.slot); // loop: come close the fd
                }
                return Err(ConnDead);
            }
        }
        if q.closed {
            return Err(ConnDead);
        }
        let due = if paced {
            let d = ob.netsim.due_after(q.last_due);
            q.last_due = Some(d);
            ob.stats.deferred_flushes.fetch_add(1, Ordering::Relaxed);
            Some(d)
        } else {
            None
        };
        q.frames.push_back(OutFrame {
            buf,
            written: 0,
            due,
            fault: None,
            drawn: false,
        });
        ob.stats.note_queue_depth(q.frames.len());
        let was_armed = std::mem::replace(&mut q.armed, true);
        drop(q);
        if !was_armed {
            ob.owner.notify_dirty(ob.slot);
        }
        Ok(())
    }

    /// Loop-thread enqueue (ping/error responses): never blocks — the loop
    /// cannot wait on itself to drain the queue. A full queue condemns the
    /// connection instead (a client flooding requests without reading
    /// responses forfeits it).
    fn send_local(&self, buf: Vec<u8>) -> Result<(), ConnDead> {
        let ob = &self.0;
        let mut q = ob.lock_q();
        if q.closed {
            return Err(ConnDead);
        }
        if q.frames.len() >= ob.cap {
            condemn(&mut q, &ob.stats);
            drop(q);
            ob.space.notify_all();
            return Err(ConnDead);
        }
        q.frames.push_back(OutFrame {
            buf,
            written: 0,
            due: None,
            fault: None,
            drawn: false,
        });
        ob.stats.note_queue_depth(q.frames.len());
        let was_armed = std::mem::replace(&mut q.armed, true);
        drop(q);
        if !was_armed {
            ob.owner.notify_dirty(ob.slot);
        }
        Ok(())
    }
}

// ------------------------------------------------------------- connections

/// Per-connection state, owned exclusively by one event loop.
struct Conn {
    stream: TcpStream,
    decoder: FrameDecoder,
    outbox: Arc<Outbox>,
    /// Requests decoded but not yet admitted: the simulated inbound hop as
    /// a due-time (monotone per connection), served by the loop timer.
    /// Deadline decoding happens at admission — after the hop — preserving
    /// the threaded path's re-anchoring point.
    pending_admit: VecDeque<(Request, Instant)>,
    last_admit_due: Option<Instant>,
    /// Current epoll interest includes `EPOLLOUT`.
    want_write: bool,
}

/// Shared, immutable reactor context.
struct Ctx {
    queue: Arc<Queue>,
    netsim: Arc<NetSim>,
    stats: Arc<ReactorStats>,
    shutdown: Arc<AtomicBool>,
    loops: Vec<Arc<LoopShared>>,
    next_loop: AtomicU64,
    write_queue_frames: usize,
}

/// Result of flushing a connection's outbox as far as it will go.
enum Flush {
    /// Queue empty; no write interest needed.
    Idle,
    /// Front frame not due yet; re-flush at this instant.
    Wait(Instant),
    /// Socket buffer full; arm `EPOLLOUT`.
    Blocked,
    /// Connection condemned (chaos kill, write error, producer timeout).
    Dead,
}

// -------------------------------------------------------------------- core

/// Running reactor: the event-loop threads plus their shared handles.
/// Created by `RpcServer::start` when `BatcherConfig::reactor` is on;
/// `shutdown` (from the server's `Drop`, after the batcher workers have
/// been joined) runs each loop's final blocking flush and joins it.
pub(crate) struct ReactorCore {
    shutdown: Arc<AtomicBool>,
    loops: Vec<Arc<LoopShared>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ReactorCore {
    pub(crate) fn start(
        listener: TcpListener,
        queue: Arc<Queue>,
        netsim: Arc<NetSim>,
        stats: Arc<ReactorStats>,
        n_loops: usize,
        write_queue_frames: usize,
    ) -> std::io::Result<ReactorCore> {
        listener.set_nonblocking(true)?;
        let n_loops = n_loops.max(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut loops = Vec::with_capacity(n_loops);
        for _ in 0..n_loops {
            loops.push(LoopShared::new()?);
        }
        let ctx = Arc::new(Ctx {
            queue,
            netsim,
            stats,
            shutdown: shutdown.clone(),
            loops: loops.clone(),
            next_loop: AtomicU64::new(0),
            write_queue_frames: write_queue_frames.max(1),
        });
        let mut handles = Vec::with_capacity(n_loops);
        let mut listener = Some(listener);
        for idx in 0..n_loops {
            let ctx = ctx.clone();
            let listener = if idx == 0 { listener.take() } else { None };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rpc-loop-{idx}"))
                    .spawn(move || run_loop(idx, &ctx, listener))
                    .expect("spawn reactor loop"),
            );
        }
        Ok(ReactorCore {
            shutdown,
            loops,
            handles,
        })
    }

    /// Stop the loops: final blocking flush of every outbox (the batcher
    /// workers must already be joined so all responses have landed), close
    /// every connection, join the threads. Idempotent.
    pub(crate) fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for l in &self.loops {
            l.wake();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ReactorCore {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// -------------------------------------------------------------- event loop

/// Mutable loop-local state (slab + timers).
struct LoopState {
    conns: Vec<Option<Conn>>,
    free: Vec<u32>,
    /// Deferred work: (fire-at, slot). Entries are lazily invalidated — a
    /// fired timer just re-examines the slot, which is a no-op when stale.
    timers: BinaryHeap<Reverse<(Instant, u32)>>,
}

fn run_loop(idx: usize, ctx: &Ctx, listener: Option<TcpListener>) {
    let Ok(ep) = epoll_create() else { return };
    let shared = ctx.loops[idx].clone();
    let _ = epoll_ctl(ep, libc::EPOLL_CTL_ADD, shared.wake_fd, libc::EPOLLIN as u32, WAKE_TOKEN);
    if let Some(l) = &listener {
        let _ = epoll_ctl(ep, libc::EPOLL_CTL_ADD, l.as_raw_fd(), libc::EPOLLIN as u32, LISTEN_TOKEN);
    }
    let mut lp = LoopState {
        conns: Vec::new(),
        free: Vec::new(),
        timers: BinaryHeap::new(),
    };
    let mut events = [libc::epoll_event { events: 0, u64: 0 }; MAX_EVENTS];
    let mut rbuf = vec![0u8; READ_CHUNK];

    while !ctx.shutdown.load(Ordering::Relaxed) {
        let timeout_ms: libc::c_int = match lp.timers.peek() {
            Some(&Reverse((due, _))) => {
                let now = Instant::now();
                if due <= now {
                    0
                } else {
                    // Round up: firing a hair early would spin on a
                    // not-yet-due frame.
                    ((due - now).as_millis() as i64 + 1).min(60_000) as libc::c_int
                }
            }
            None => -1,
        };
        let n = unsafe { libc::epoll_wait(ep, events.as_mut_ptr(), MAX_EVENTS as libc::c_int, timeout_ms) };
        if n < 0 {
            if std::io::Error::last_os_error().kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            break;
        }
        ctx.stats.record_wakeup(idx);
        if ctx.shutdown.load(Ordering::Relaxed) {
            break;
        }
        let mut accept_ready = false;
        for ev in &events[..n as usize] {
            let token = ev.u64;
            let bits = ev.events;
            match token {
                WAKE_TOKEN => drain_wake(shared.wake_fd),
                LISTEN_TOKEN => accept_ready = true,
                slot64 => {
                    let slot = slot64 as u32;
                    let hup = bits & (libc::EPOLLHUP | libc::EPOLLERR | libc::EPOLLRDHUP) as u32 != 0;
                    let readable = bits & libc::EPOLLIN as u32 != 0;
                    let writable = bits & libc::EPOLLOUT as u32 != 0;
                    // Read (and thus admit) before honoring a hangup: a
                    // client that pipelines requests and closes its write
                    // half still gets its queued frames... but a HUP with
                    // nothing readable is a dead peer.
                    let mut alive = true;
                    if readable {
                        alive = handle_readable(ctx, &mut lp, slot, ep, idx, &mut rbuf);
                    }
                    if alive && writable {
                        alive = flush_slot(ctx, &mut lp, slot, ep, idx);
                    }
                    if alive && hup && !readable {
                        close_conn(ctx, &mut lp, slot, ep, idx);
                    }
                }
            }
        }
        if accept_ready {
            if let Some(l) = &listener {
                accept_loop(ctx, &mut lp, l, ep, idx);
            }
        }
        // Connections handed over by the accepting loop.
        let injected: Vec<TcpStream> =
            std::mem::take(&mut *shared.new_conns.lock().unwrap_or_else(PoisonError::into_inner));
        for stream in injected {
            register_conn(ctx, &mut lp, stream, ep, idx);
        }
        // Outboxes producers touched since we last looked.
        let dirty: Vec<u32> =
            std::mem::take(&mut *shared.dirty.lock().unwrap_or_else(PoisonError::into_inner));
        for slot in dirty {
            flush_slot(ctx, &mut lp, slot, ep, idx);
        }
        // Deferred work that came due: pending admissions + paced/stalled
        // frames.
        fire_timers(ctx, &mut lp, ep, idx);
    }

    teardown(ctx, &mut lp, &shared, idx);
    unsafe { libc::close(ep) };
}

fn accept_loop(ctx: &Ctx, lp: &mut LoopState, listener: &TcpListener, ep: RawFd, idx: usize) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let target =
                    (ctx.next_loop.fetch_add(1, Ordering::Relaxed) as usize) % ctx.loops.len();
                if target == idx {
                    register_conn(ctx, lp, stream, ep, idx);
                } else {
                    ctx.loops[target].inject_conn(stream);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                // EMFILE and friends: back off briefly so the level-
                // triggered listener event cannot spin a core.
                std::thread::sleep(Duration::from_millis(1));
                return;
            }
        }
    }
}

fn register_conn(ctx: &Ctx, lp: &mut LoopState, stream: TcpStream, ep: RawFd, idx: usize) {
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    stream.set_nodelay(true).ok();
    let slot = match lp.free.pop() {
        Some(s) => s,
        None => {
            lp.conns.push(None);
            (lp.conns.len() - 1) as u32
        }
    };
    if epoll_ctl(ep, libc::EPOLL_CTL_ADD, stream.as_raw_fd(), IN_EVENTS, slot as u64).is_err() {
        lp.free.push(slot);
        return;
    }
    let outbox = Arc::new(Outbox {
        q: Mutex::new(OutboxQ::default()),
        space: Condvar::new(),
        cap: ctx.write_queue_frames,
        slot,
        owner: ctx.loops[idx].clone(),
        netsim: ctx.netsim.clone(),
        stats: ctx.stats.clone(),
    });
    lp.conns[slot as usize] = Some(Conn {
        stream,
        decoder: FrameDecoder::new(),
        outbox,
        pending_admit: VecDeque::new(),
        last_admit_due: None,
        want_write: false,
    });
    ctx.stats.conn_opened(idx);
}

/// Drain the socket and admit every complete frame. Returns false when the
/// connection was closed.
fn handle_readable(
    ctx: &Ctx,
    lp: &mut LoopState,
    slot: u32,
    ep: RawFd,
    idx: usize,
    buf: &mut [u8],
) -> bool {
    loop {
        let Some(conn) = lp.conns.get_mut(slot as usize).and_then(Option::as_mut) else {
            return false;
        };
        match conn.stream.read(buf) {
            Ok(0) => {
                close_conn(ctx, lp, slot, ep, idx);
                return false;
            }
            Ok(k) => {
                conn.decoder.extend(&buf[..k]);
                loop {
                    let Some(conn) = lp.conns.get_mut(slot as usize).and_then(Option::as_mut)
                    else {
                        return false;
                    };
                    match conn.decoder.next_inbound() {
                        Ok(Some(Inbound::Req(req))) => {
                            if ctx.netsim.enabled() {
                                // Inbound hop as an admission due-time; the
                                // deadline is decoded when it fires.
                                let due = ctx.netsim.due_after(conn.last_admit_due);
                                conn.last_admit_due = Some(due);
                                conn.pending_admit.push_back((req, due));
                                lp.timers.push(Reverse((due, slot)));
                            } else {
                                let outbox = conn.outbox.clone();
                                if !admit(ctx, &outbox, req) {
                                    close_conn(ctx, lp, slot, ep, idx);
                                    return false;
                                }
                            }
                        }
                        Ok(Some(Inbound::Malformed { req_id })) => {
                            // Honest length, bad content: error-frame THIS
                            // id, keep the (pipelined) connection.
                            let mut out = Vec::new();
                            proto::encode_response(&Response::err(req_id), &mut out);
                            if ConnHandle(conn.outbox.clone()).send_local(out).is_err() {
                                close_conn(ctx, lp, slot, ep, idx);
                                return false;
                            }
                        }
                        Ok(None) => break,
                        Err(_) => {
                            // Unrecoverable desync (oversized length).
                            close_conn(ctx, lp, slot, ep, idx);
                            return false;
                        }
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                close_conn(ctx, lp, slot, ep, idx);
                return false;
            }
        }
    }
}

/// Admit one parsed request (post-hop): pings answer immediately, a
/// shutting-down server asks for the connection to be hung up (return
/// false), everything else parks on the batcher queue.
fn admit(ctx: &Ctx, outbox: &Arc<Outbox>, req: Request) -> bool {
    let n = req.n_rows() as usize;
    if n == 0 {
        // Ping: answer immediately, no outbound hop (the RTT probe measures
        // a single simulated hop, paid at admission).
        let mut out = Vec::new();
        proto::encode_response(&Response::ok(req.req_id, Vec::new()), &mut out);
        return ConnHandle(outbox.clone()).send_local(out).is_ok();
    }
    // The door: over-quota or over-cap requests bounce right here with a
    // `Rejected` frame — no queue slot, no batch seat (counters are bumped
    // inside `admit_rows`).
    let permit = match ctx.queue.admit_rows(req.tenant, n) {
        Ok(p) => p,
        Err(rej) => {
            let mut out = Vec::new();
            proto::encode_rejected(req.req_id, rej.retry_after_ms(), &mut out);
            return ConnHandle(outbox.clone()).send_local(out).is_ok();
        }
    };
    {
        let mut jobs = ctx.queue.lock_jobs();
        if ctx.queue.shutdown.load(Ordering::Relaxed) {
            return false;
        }
        let deadline = req.deadline();
        jobs.push_back(Job {
            req_id: req.req_id,
            rows: req.rows,
            n,
            row_len: req.row_len as usize,
            out: RespOut::Reactor(ConnHandle(outbox.clone())),
            netsim: ctx.netsim.clone(),
            deadline,
            enqueued_at: Instant::now(),
            permit,
        });
    }
    ctx.queue.avail.notify_one();
    true
}

/// Flush a slot's outbox and apply the result to its epoll interest.
/// Returns false when the connection was closed.
fn flush_slot(ctx: &Ctx, lp: &mut LoopState, slot: u32, ep: RawFd, idx: usize) -> bool {
    let Some(conn) = lp.conns.get_mut(slot as usize).and_then(Option::as_mut) else {
        return false;
    };
    match flush_outbox(ctx, conn) {
        Flush::Dead => {
            close_conn(ctx, lp, slot, ep, idx);
            false
        }
        Flush::Blocked => {
            if !conn.want_write {
                conn.want_write = true;
                let _ = epoll_ctl(ep, libc::EPOLL_CTL_MOD, conn.stream.as_raw_fd(), INOUT_EVENTS, slot as u64);
            }
            true
        }
        Flush::Wait(due) => {
            if conn.want_write {
                conn.want_write = false;
                let _ = epoll_ctl(ep, libc::EPOLL_CTL_MOD, conn.stream.as_raw_fd(), IN_EVENTS, slot as u64);
            }
            lp.timers.push(Reverse((due, slot)));
            true
        }
        Flush::Idle => {
            if conn.want_write {
                conn.want_write = false;
                let _ = epoll_ctl(ep, libc::EPOLL_CTL_MOD, conn.stream.as_raw_fd(), IN_EVENTS, slot as u64);
            }
            true
        }
    }
}

/// Write queued frames until the queue is empty, the front frame is not due
/// yet, the socket blocks, or a fault kills the connection. Chaos faults
/// are drawn once per frame at its first due flush attempt, with the same
/// semantics as the threaded `chaos_write`.
fn flush_outbox(ctx: &Ctx, conn: &mut Conn) -> Flush {
    let ob = &conn.outbox;
    let mut q = ob.lock_q();
    q.armed = false;
    if q.closed {
        return Flush::Dead;
    }
    loop {
        let Some(f) = q.frames.front_mut() else {
            return Flush::Idle;
        };
        let now = Instant::now();
        if let Some(due) = f.due {
            if due > now {
                return Flush::Wait(due);
            }
        }
        if !f.drawn {
            f.drawn = true;
            f.fault = ctx.netsim.chaos().and_then(|p| p.next_frame_fault());
            match f.fault {
                Some(Fault::Corrupt) => {
                    // Flip the count/status header byte (buf includes the
                    // 4-byte length prefix): structural corruption the peer
                    // must reject, never wrong payload bits.
                    if f.buf.len() > 12 {
                        f.buf[12] ^= 0xFF;
                    }
                }
                Some(Fault::StallMs(ms)) => {
                    // The write stall becomes a deferred-flush timer.
                    f.due = Some(now + Duration::from_millis(ms));
                    ctx.stats.deferred_flushes.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                _ => {}
            }
        }
        match f.fault {
            Some(Fault::Reset) => {
                condemn(&mut q, &ctx.stats);
                return Flush::Dead;
            }
            Some(Fault::PartialFrame) => {
                let cut = (f.buf.len() / 2).max(1);
                let _ = conn.stream.write(&f.buf[..cut]);
                let _ = conn.stream.flush();
                condemn(&mut q, &ctx.stats);
                return Flush::Dead;
            }
            _ => {}
        }
        match conn.stream.write(&f.buf[f.written..]) {
            Ok(0) => {
                condemn(&mut q, &ctx.stats);
                return Flush::Dead;
            }
            Ok(k) => {
                f.written += k;
                if f.written == f.buf.len() {
                    q.frames.pop_front();
                    ob.space.notify_all();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Flush::Blocked,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                condemn(&mut q, &ctx.stats);
                return Flush::Dead;
            }
        }
    }
}

/// Mark an outbox dead: its frames will never be written — count them, so
/// the loss is visible, then fail all future sends fast.
fn condemn(q: &mut OutboxQ, stats: &ReactorStats) {
    stats
        .dead_conn_frames
        .fetch_add(q.frames.len() as u64, Ordering::Relaxed);
    q.frames.clear();
    q.closed = true;
}

fn close_conn(ctx: &Ctx, lp: &mut LoopState, slot: u32, ep: RawFd, idx: usize) {
    let Some(conn) = lp.conns.get_mut(slot as usize).and_then(Option::take) else {
        return;
    };
    {
        let mut q = conn.outbox.lock_q();
        if !q.closed {
            condemn(&mut q, &ctx.stats);
        }
    }
    // In-flight jobs holding this outbox discover the death on their next
    // send and error-complete (ServeMetrics::dead_conn_jobs); producers
    // blocked on backpressure wake up to the same verdict.
    conn.outbox.space.notify_all();
    let _ = epoll_ctl(ep, libc::EPOLL_CTL_DEL, conn.stream.as_raw_fd(), 0, 0);
    ctx.stats.conn_closed(idx);
    lp.free.push(slot);
    // Dropping the stream closes the fd; pending (un-admitted) requests
    // die with it — their client is gone.
}

/// Pop and serve every timer that came due: pending admissions first, then
/// a re-flush (which also re-arms the next frame due-time, if any).
fn fire_timers(ctx: &Ctx, lp: &mut LoopState, ep: RawFd, idx: usize) {
    let now = Instant::now();
    while let Some(&Reverse((due, slot))) = lp.timers.peek() {
        if due > now {
            break;
        }
        lp.timers.pop();
        let Some(conn) = lp.conns.get_mut(slot as usize).and_then(Option::as_mut) else {
            continue; // stale: connection already closed
        };
        let mut hang_up = false;
        while let Some((_, adue)) = conn.pending_admit.front() {
            if *adue > now {
                break;
            }
            let (req, _) = conn.pending_admit.pop_front().unwrap();
            let outbox = conn.outbox.clone();
            if !admit(ctx, &outbox, req) {
                hang_up = true;
                break;
            }
        }
        if hang_up {
            close_conn(ctx, lp, slot, ep, idx);
            continue;
        }
        flush_slot(ctx, lp, slot, ep, idx);
    }
}

/// Final pass at shutdown: every response is already enqueued (the server
/// joins the batcher workers before stopping the reactor), so switch each
/// socket back to blocking and write everything out — the same prompt
/// error-or-answer guarantee on teardown as the threaded path — then close.
fn teardown(ctx: &Ctx, lp: &mut LoopState, shared: &LoopShared, idx: usize) {
    // Accepted-but-never-registered connections just hang up.
    drop(std::mem::take(
        &mut *shared.new_conns.lock().unwrap_or_else(PoisonError::into_inner),
    ));
    for entry in lp.conns.iter_mut() {
        let Some(mut conn) = entry.take() else {
            continue;
        };
        let _ = conn.stream.set_nonblocking(false);
        let _ = conn.stream.set_write_timeout(Some(WRITE_TIMEOUT));
        let mut q = conn.outbox.lock_q();
        if !q.closed {
            while let Some(mut f) = q.frames.pop_front() {
                // Dues are void on teardown; chaos faults still apply, with
                // the threaded (blocking) semantics.
                if !f.drawn {
                    f.drawn = true;
                    f.fault = ctx.netsim.chaos().and_then(|p| p.next_frame_fault());
                }
                match f.fault {
                    Some(Fault::Reset) | Some(Fault::PartialFrame) => {
                        condemn(&mut q, &ctx.stats);
                        break;
                    }
                    Some(Fault::StallMs(ms)) => std::thread::sleep(Duration::from_millis(ms)),
                    Some(Fault::Corrupt) => {
                        if f.buf.len() > 12 {
                            f.buf[12] ^= 0xFF;
                        }
                    }
                    _ => {}
                }
                if proto::write_frame(&mut conn.stream, &f.buf[f.written..]).is_err() {
                    condemn(&mut q, &ctx.stats);
                    break;
                }
            }
            q.closed = true;
        }
        drop(q);
        conn.outbox.space.notify_all();
        ctx.stats.conn_closed(idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::netsim::NetSimConfig;

    fn test_outbox(netsim: Arc<NetSim>, cap: usize) -> Arc<Outbox> {
        Arc::new(Outbox {
            q: Mutex::new(OutboxQ::default()),
            space: Condvar::new(),
            cap,
            slot: 0,
            owner: LoopShared::new().unwrap(),
            netsim,
            stats: Arc::new(ReactorStats::new(1)),
        })
    }

    #[test]
    fn paced_sends_get_monotone_due_times() {
        let ns = Arc::new(NetSim::new(
            NetSimConfig {
                base_us: 500.0,
                sigma: 0.5,
                max_us: 5_000.0,
            },
            7,
        ));
        let ob = test_outbox(ns, 64);
        let h = ConnHandle(ob.clone());
        for i in 0..32 {
            h.send(vec![i as u8; 8], true).unwrap();
        }
        let q = ob.lock_q();
        let mut prev: Option<Instant> = None;
        for f in &q.frames {
            let due = f.due.expect("paced frames carry a due-time");
            if let Some(p) = prev {
                assert!(due >= p, "pacing clamp must be monotone");
            }
            prev = Some(due);
        }
        assert_eq!(ob.stats.deferred_flushes.load(Ordering::Relaxed), 32);
        assert!(ob.stats.write_queue_hwm.load(Ordering::Relaxed) >= 32);
    }

    #[test]
    fn unpaced_sends_have_no_due_time_and_dirty_notifies_once() {
        let ns = Arc::new(NetSim::new(NetSimConfig::off(), 1));
        let ob = test_outbox(ns, 64);
        let h = ConnHandle(ob.clone());
        h.send(vec![1, 2, 3], false).unwrap();
        h.send(vec![4, 5, 6], false).unwrap();
        assert!(ob.lock_q().frames.iter().all(|f| f.due.is_none()));
        // Only the first send (unarmed) should have queued a dirty entry.
        let dirty = ob.owner.dirty.lock().unwrap();
        assert_eq!(dirty.len(), 1, "armed outbox must not re-notify");
    }

    #[test]
    fn closed_outbox_rejects_sends_and_counts_nothing_silently() {
        let ns = Arc::new(NetSim::new(NetSimConfig::off(), 1));
        let ob = test_outbox(ns, 64);
        let h = ConnHandle(ob.clone());
        h.send(vec![0u8; 16], false).unwrap();
        h.send(vec![1u8; 16], false).unwrap();
        {
            let mut q = ob.lock_q();
            condemn(&mut q, &ob.stats);
        }
        assert!(h.send(vec![2u8; 16], false).is_err(), "dead conn fails fast");
        assert_eq!(
            ob.stats.dead_conn_frames.load(Ordering::Relaxed),
            2,
            "queued frames on a dead connection are counted, not dropped"
        );
        assert!(ob.lock_q().frames.is_empty());
    }

    #[test]
    fn full_outbox_counts_backpressure_stall() {
        let ns = Arc::new(NetSim::new(NetSimConfig::off(), 1));
        let ob = test_outbox(ns, 2);
        let h = ConnHandle(ob.clone());
        h.send(vec![0u8; 4], false).unwrap();
        h.send(vec![1u8; 4], false).unwrap();
        // Third send blocks; a drainer thread frees a slot after a beat.
        let ob2 = ob.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            let mut q = ob2.lock_q();
            q.frames.pop_front();
            drop(q);
            ob2.space.notify_all();
        });
        h.send(vec![2u8; 4], false).unwrap();
        t.join().unwrap();
        assert!(ob.stats.backpressure_stalls.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn local_send_never_blocks_full_queue_condemns() {
        let ns = Arc::new(NetSim::new(NetSimConfig::off(), 1));
        let ob = test_outbox(ns, 2);
        let h = ConnHandle(ob.clone());
        h.send_local(vec![0u8; 4]).unwrap();
        h.send_local(vec![1u8; 4]).unwrap();
        let t0 = Instant::now();
        assert!(h.send_local(vec![2u8; 4]).is_err(), "full queue condemns");
        assert!(t0.elapsed() < Duration::from_millis(100), "must not block");
        assert!(ob.lock_q().closed);
        assert_eq!(ob.stats.dead_conn_frames.load(Ordering::Relaxed), 2);
    }
}
