//! RPC substrate: the "ML service" the product code calls for second-stage
//! inference.
//!
//! A real TCP service over a length-prefixed binary protocol (`proto`), a
//! dynamic batcher that coalesces concurrent requests into backend batches
//! (`server`), a pooled **pipelined** client (`client`) that multiplexes
//! in-flight requests over shared connections and demultiplexes responses
//! by `req_id`, and a calibrated network-latency simulator (`netsim`)
//! standing in for the datacenter hop the paper measures (DESIGN.md §6).

pub mod client;
pub mod netsim;
pub mod proto;
pub mod server;

pub use client::{PendingPredict, RpcClient};
pub use netsim::NetSim;
pub use server::{Backend, BatcherConfig, RpcServer};
