//! RPC substrate: the "ML service" the product code calls for second-stage
//! inference.
//!
//! A real TCP service over a length-prefixed binary protocol (`proto`,
//! including the streamed `CHUNK`/terminator frames and a resumable
//! [`FrameDecoder`](proto::FrameDecoder) for nonblocking reads), a dynamic
//! batcher that coalesces concurrent requests into backend batches and
//! **streams** sub-batch completions back per request (`server`), a pooled
//! **pipelined** client (`client`) that multiplexes in-flight requests over
//! shared connections, demultiplexes frames by `req_id`, and surfaces
//! streamed spans incrementally, and a calibrated network-latency simulator
//! (`netsim`) standing in for the datacenter hop the paper measures
//! (DESIGN.md §6).
//!
//! On Linux the server's I/O runs on an **epoll reactor** (`reactor`): a
//! small fixed set of event loops own every connection — incremental frame
//! decode on readable, bounded per-connection write queues flushed on
//! writable — so thread count stays flat as connections grow (the C10K
//! path). `BatcherConfig::reactor = false` selects the legacy
//! thread-per-connection path for A/B comparison; the wire protocol and
//! batcher behind both paths are identical.
//!
//! The failure model lives in `fault` (per-request [`Deadline`]s carried in
//! the request frames, [`RetryPolicy`] + retry budget, [`CircuitBreaker`])
//! and `netsim`'s chaos layer ([`ChaosPlan`]: scripted connection resets,
//! stalls, partial/corrupt frames, server pause/resume) — see the crate
//! docs §Failure model and `tests/chaos_battery.rs`.
//!
//! The overload model lives in `admission` (per-tenant token-bucket quotas,
//! the global in-flight row cap, and the CoDel sojourn-shedding control
//! law) — the server consults it at the admission edge of BOTH I/O paths
//! and inside the batcher, and answers refusals with an explicit `REJECTED`
//! frame carrying a retry-after hint (see the crate docs §Overload model).

pub mod admission;
pub mod client;
pub mod fault;
pub mod netsim;
pub mod proto;
#[cfg(target_os = "linux")]
pub(crate) mod reactor;
pub mod server;

pub use admission::{AdmissionConfig, AdmissionControl, Codel, TenantStats};
pub use client::{ClientConfig, FallbackSpan, PendingPredict, RpcClient, StreamOutcome};
pub use fault::{
    BreakerConfig, BreakerState, CircuitBreaker, Deadline, PredictOptions, RetryPolicy,
};
pub use netsim::{ChaosPlan, Fault, NetSim};
pub use server::{Backend, BatcherConfig, RpcServer};
