//! Pooled synchronous RPC client — the product-code side of the RPC API.
//!
//! Each call grabs a pooled connection (or dials a new one), writes one
//! request frame and blocks for the response; pipelining happens naturally
//! across caller threads, and the server's dynamic batcher coalesces them.

use super::proto::{self, Request};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Thread-safe pooled client.
pub struct RpcClient {
    addr: SocketAddr,
    pool: Mutex<Vec<TcpStream>>,
    next_id: AtomicU64,
    timeout: Duration,
}

impl RpcClient {
    pub fn connect(addr: SocketAddr) -> std::io::Result<RpcClient> {
        let client = RpcClient {
            addr,
            pool: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
            timeout: Duration::from_secs(30),
        };
        // Eagerly dial one connection to fail fast on a bad address.
        let s = client.dial()?;
        client.pool.lock().unwrap().push(s);
        Ok(client)
    }

    fn dial(&self) -> std::io::Result<TcpStream> {
        let s = TcpStream::connect_timeout(&self.addr, Duration::from_secs(5))?;
        s.set_nodelay(true)?;
        s.set_read_timeout(Some(self.timeout))?;
        s.set_write_timeout(Some(self.timeout))?;
        Ok(s)
    }

    fn take_stream(&self) -> std::io::Result<TcpStream> {
        if let Some(s) = self.pool.lock().unwrap().pop() {
            return Ok(s);
        }
        self.dial()
    }

    fn put_stream(&self, s: TcpStream) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < 64 {
            pool.push(s);
        }
    }

    /// Synchronous batched inference call. `rows.len() = n · row_len`.
    /// Returns one probability per row.
    pub fn predict(&self, rows: &[f32], row_len: usize) -> std::io::Result<Vec<f32>> {
        let req = Request {
            req_id: self.next_id.fetch_add(1, Ordering::Relaxed),
            row_len: row_len as u32,
            rows: rows.to_vec(),
        };
        let mut stream = self.take_stream()?;
        let mut buf = Vec::new();
        proto::encode_request(&req, &mut buf);
        if proto::write_frame(&mut stream, &buf).is_err() {
            // Stale pooled connection — retry once on a fresh dial.
            stream = self.dial()?;
            proto::write_frame(&mut stream, &buf)?;
        }
        let resp = proto::read_response(&mut stream)?.ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "server closed")
        })?;
        if resp.req_id != req.req_id {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "response id mismatch",
            ));
        }
        self.put_stream(stream);
        Ok(resp.probs)
    }

    /// Round-trip ping (health check / RTT probe).
    pub fn ping(&self) -> std::io::Result<Duration> {
        let t0 = std::time::Instant::now();
        let probs = self.predict(&[], 0)?;
        debug_assert!(probs.is_empty());
        Ok(t0.elapsed())
    }

    /// Bytes that `predict` would move over the wire for bookkeeping.
    pub fn wire_bytes(n_rows: usize, row_len: usize) -> u64 {
        let req = 4 + 8 + 4 + 4 + (n_rows * row_len * 4) as u64;
        let resp = 4 + 8 + 4 + (n_rows * 4) as u64;
        req + resp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::netsim::{NetSim, NetSimConfig};
    use crate::rpc::server::{Backend, BatcherConfig, RpcServer};
    use crate::telemetry::ServeMetrics;
    use std::sync::Arc;

    /// Echo-ish backend: prob = mean of the row (easy to verify).
    struct MeanBackend;

    impl Backend for MeanBackend {
        fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
            (0..n)
                .map(|r| {
                    let row = &rows[r * row_len..(r + 1) * row_len];
                    row.iter().sum::<f32>() / row_len as f32
                })
                .collect()
        }
        fn row_len(&self) -> usize {
            0
        }
    }

    fn start_server() -> (RpcServer, Arc<ServeMetrics>) {
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(100),
                workers: 2,
            },
            metrics.clone(),
        )
        .unwrap();
        (server, metrics)
    }

    #[test]
    fn roundtrip_single() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let probs = client.predict(&[1.0, 2.0, 3.0, 4.0], 4).unwrap();
        assert_eq!(probs, vec![2.5]);
    }

    #[test]
    fn roundtrip_batch() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let rows: Vec<f32> = (0..20).map(|i| i as f32).collect(); // 10 rows × 2
        let probs = client.predict(&rows, 2).unwrap();
        assert_eq!(probs.len(), 10);
        assert_eq!(probs[0], 0.5);
        assert_eq!(probs[9], 18.5);
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let (server, metrics) = start_server();
        let addr = server.addr;
        let mut handles = Vec::new();
        for t in 0..8 {
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::connect(addr).unwrap();
                for i in 0..50 {
                    let v = (t * 100 + i) as f32;
                    let p = client.predict(&[v, v], 2).unwrap();
                    assert_eq!(p, vec![v]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Batcher really batched (fewer backend execs than requests is
        // likely but not guaranteed; at minimum it executed something).
        assert!(metrics.backend_exec.count() > 0);
        assert!(metrics.backend_exec.count() <= 400);
    }

    #[test]
    fn ping_works() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let rtt = client.ping().unwrap();
        assert!(rtt < Duration::from_secs(1));
    }

    #[test]
    fn netsim_raises_latency() {
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(
                NetSimConfig {
                    base_us: 2000.0,
                    sigma: 0.1,
                    max_us: 10_000.0,
                },
                7,
            )),
            BatcherConfig::default(),
            metrics,
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();
        let rtt = client.ping().unwrap();
        // Pings take the inbound injection (~2ms) only.
        assert!(rtt >= Duration::from_millis(1), "rtt={rtt:?}");
        // A real request takes both hops (~4ms).
        let t0 = std::time::Instant::now();
        client.predict(&[1.0, 2.0], 2).unwrap();
        let full = t0.elapsed();
        assert!(full >= Duration::from_millis(3), "full={full:?}");
    }

    #[test]
    fn server_shutdown_clean() {
        let (server, _m) = start_server();
        let addr = server.addr;
        drop(server);
        // New connections should fail or be closed promptly.
        std::thread::sleep(Duration::from_millis(50));
        let r = RpcClient::connect(addr).and_then(|c| c.predict(&[1.0], 1));
        assert!(r.is_err());
    }
}
