//! Pipelined pooled RPC client — the product-code side of the RPC API.
//!
//! Each connection is **multiplexed**: callers write request frames onto a
//! shared pooled connection without waiting for earlier responses, and a
//! dedicated reader thread demultiplexes response frames back to the right
//! caller by `req_id`. That is what lets the coordinator keep a coalesced
//! fallback RPC in flight while it evaluates the next block's stage-1 pass
//! (and lets the server's dynamic batcher coalesce requests that share a
//! connection). The old design — one exclusively-owned connection per call
//! for its full round trip — serialized everything behind the slowest
//! outstanding request.
//!
//! [`RpcClient::predict_async`] returns a [`PendingPredict`] handle
//! immediately after the request frame is written; [`PendingPredict::wait`]
//! blocks for the demuxed response. [`RpcClient::predict`] is the blocking
//! composition of the two.
//!
//! ## Streamed responses
//!
//! The server may answer a request as a **stream** of `CHUNK` frames (one
//! per completed sub-batch, any order) closed by a terminator — see
//! `proto`. The reader thread routes chunks to the request's pending entry
//! without retiring it; [`PendingPredict::poll_spans`] drains whatever
//! sub-spans have arrived so far — fallback rows become consumable while
//! later spans are still in flight — and [`PendingPredict::wait`]
//! reassembles the full response ([`proto::StreamAssembler`]),
//! bit-identical to a monolithic answer. A failed span mid-stream surfaces
//! from `wait` as the request's error, exactly like a whole-request error
//! frame (the span data remains visible through
//! [`PendingPredict::wait_outcome`]). Callers that never poll see no
//! difference between a streamed and a monolithic response.
//!
//! ## Failure handling
//!
//! Transport failures — a stale pooled connection rejecting the write, the
//! reader thread dying mid-response (EOF/reset), a refused fresh dial —
//! are retried under a unified [`RetryPolicy`]: bounded attempts with
//! exponential backoff + jitter, gated by a shared token-bucket
//! [`RetryBudget`](super::fault::RetryBudget) so a hard-down server costs
//! a bounded number of extra dials instead of a retry storm. Every
//! attempt's outcome feeds the client's [`CircuitBreaker`]; after enough
//! consecutive failures it trips open and calls fail fast with
//! [`fault::breaker_error`] (classify via [`fault::is_breaker_open`])
//! until a cooldown's half-open probe succeeds. A response frame flagged
//! as a server-side error (backend failure) is surfaced as an error
//! without retry: it is a live answer from a healthy connection, and
//! resending would fail the same way.
//!
//! When a connection's reader thread dies, the client
//! error-completes **every** pending `req_id` on it and wakes every
//! sender blocked on the in-flight cap — nobody sleeps out an individual
//! timeout waiting on a connection that is already gone.
//!
//! ## Deadlines
//!
//! [`RpcClient::predict_async_opts`] threads a per-request [`Deadline`]
//! through the call: the remaining budget rides the request frame
//! (`deadline_us` — see `proto`), the in-flight-cap wait, backoff sleeps,
//! and the response wait are all clamped to it, and expiry surfaces as
//! [`fault::deadline_error`] (client-side shedding; the server batcher
//! and shard pool shed expired work on their side from the same wire
//! field).
//!
//! ## Backpressure
//!
//! In-flight frames are capped per connection ([`DEFAULT_MAX_IN_FLIGHT`],
//! tunable via [`RpcClient::set_max_in_flight`]): a sender that would push
//! a connection past the cap blocks until the server answers (or the
//! connection fails), and gives up with `TimedOut` at the client timeout.
//! Without the cap, a slow server would let the pending demux table — and
//! its own admission queue — grow with every pipelined call that outruns
//! the responses.

use super::fault::{
    self, BreakerConfig, CircuitBreaker, Deadline, PredictOptions, RetryBudget, RetryPolicy,
};
use super::proto::{self, ClientFrame, Request};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Connections kept per client. Requests round-robin across them so
/// per-connection frame transmission overlaps across concurrent requests.
const POOL_CONNS: usize = 4;

/// Default cap on in-flight (pipelined, unanswered) requests per
/// connection. A slow or wedged server must exert **backpressure** on
/// callers instead of letting the pending demux table — and the server's
/// admission queue — grow without bound: once a connection carries this
/// many unanswered frames, further sends on it block until a response (or
/// failure) frees a slot, and give up with `TimedOut` after the client
/// timeout.
pub const DEFAULT_MAX_IN_FLIGHT: usize = 64;

/// Frames carry the instant they arrived at the client: metrics want
/// completion time, which is earlier than the caller's join when the
/// caller overlaps other work before waiting. A request receives several
/// frames when the server streams (chunks, then the terminator).
type ReplyTx = mpsc::Sender<io::Result<(ClientFrame, Instant)>>;
type ReplyRx = mpsc::Receiver<io::Result<(ClientFrame, Instant)>>;

/// One pipelined connection: a writer half shared by callers (frames are
/// written whole under the lock) and a reader thread that routes response
/// frames to the pending table by `req_id`.
struct Conn {
    writer: Mutex<TcpStream>,
    pending: Mutex<HashMap<u64, ReplyTx>>,
    /// Signalled whenever `pending` shrinks (response demuxed, request
    /// abandoned, connection failed): senders blocked on the in-flight cap
    /// wait here.
    slot_freed: Condvar,
    dead: AtomicBool,
}

impl Conn {
    fn lock_writer(&self) -> MutexGuard<'_, TcpStream> {
        self.writer.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_pending(&self) -> MutexGuard<'_, HashMap<u64, ReplyTx>> {
        self.pending.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Remove a pending entry and wake one capped sender.
    fn release(&self, req_id: u64) -> Option<ReplyTx> {
        let tx = self.lock_pending().remove(&req_id);
        if tx.is_some() {
            self.slot_freed.notify_one();
        }
        tx
    }

    /// Mark the connection dead and wake EVERY capped sender: once a
    /// connection is retired no response will ever free another slot, so
    /// waiters must all re-check (see the `dead` condition in `send_on`)
    /// instead of sleeping out their deadlines one notify at a time.
    fn retire(&self) {
        self.dead.store(true, Ordering::Relaxed);
        let _g = self.lock_pending();
        self.slot_freed.notify_all();
    }

    /// Mark the connection dead and fail every in-flight request on it.
    fn fail_all(&self, kind: io::ErrorKind, msg: &str) {
        self.dead.store(true, Ordering::Relaxed);
        for (_, tx) in self.lock_pending().drain() {
            let _ = tx.send(Err(io::Error::new(kind, msg)));
        }
        // The table emptied: every capped sender gets to proceed (and see
        // `dead`).
        self.slot_freed.notify_all();
    }
}

/// Reader loop: demultiplex frames until the connection dies. Terminal
/// frames (monolithic/error responses, stream terminators) retire the
/// pending entry — freeing its in-flight slot; mid-stream chunks route to
/// the entry without retiring it. Any read failure (including an idle
/// timeout) retires the connection — in-flight callers get a transport
/// error and retry on a fresh dial.
fn reader_loop(conn: Arc<Conn>, mut stream: TcpStream) {
    loop {
        match proto::read_client_frame(&mut stream) {
            Ok(Some(frame)) => {
                let req_id = frame.req_id();
                if frame.is_terminal() {
                    // Unknown ids are responses to abandoned (timed-out)
                    // requests; dropping them keeps the stream in sync.
                    if let Some(tx) = conn.release(req_id) {
                        let _ = tx.send(Ok((frame, Instant::now())));
                    }
                } else {
                    // Chunks for abandoned requests are dropped the same
                    // way; their stream's terminator cleans up the slot.
                    let pending = conn.lock_pending();
                    if let Some(tx) = pending.get(&req_id) {
                        let _ = tx.send(Ok((frame, Instant::now())));
                    }
                }
            }
            Ok(None) => {
                conn.fail_all(io::ErrorKind::UnexpectedEof, "server closed connection");
                return;
            }
            Err(e) => {
                conn.fail_all(e.kind(), "connection failed mid-response");
                return;
            }
        }
    }
}

/// Client tuning: timeout/backpressure plus the failure-model knobs
/// (retry policy and circuit-breaker thresholds). `Default` gives the
/// production shape; [`RetryPolicy::none`] turns retrying off for
/// baselines.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Per-call response timeout (also the write/read socket timeout).
    pub timeout: Duration,
    /// Per-connection in-flight frame cap (see [`DEFAULT_MAX_IN_FLIGHT`]).
    pub max_in_flight: usize,
    /// Transport-failure retry policy (backoff, jitter, bounded attempts).
    pub retry: RetryPolicy,
    /// Circuit-breaker thresholds (consecutive failures, cooldown, p99).
    pub breaker: BreakerConfig,
    /// Tenant id stamped on every request frame — what the server's
    /// admission control bills quota against. 0 = the default tenant.
    pub tenant: u32,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            timeout: Duration::from_secs(30),
            max_in_flight: DEFAULT_MAX_IN_FLIGHT,
            retry: RetryPolicy::default(),
            breaker: BreakerConfig::default(),
            tenant: 0,
        }
    }
}

/// Thread-safe pipelined client.
pub struct RpcClient {
    addr: SocketAddr,
    pool: Mutex<Vec<Arc<Conn>>>,
    next_id: AtomicU64,
    rr: AtomicUsize,
    timeout: Duration,
    /// Per-connection in-flight frame cap (see [`DEFAULT_MAX_IN_FLIGHT`]).
    max_in_flight: usize,
    /// Transport-failure retry policy (see [`ClientConfig`]).
    retry: RetryPolicy,
    /// Token-bucket gate on retries, shared by every call on this client.
    budget: RetryBudget,
    /// Breaker over the whole backend as seen from this client.
    breaker: CircuitBreaker,
    /// Jitter source for backoff sleeps.
    backoff_rng: Mutex<Rng>,
    /// Retries actually performed (telemetry).
    retries: AtomicU64,
    /// Tenant id stamped on every request (see [`ClientConfig::tenant`]).
    tenant: u32,
}

/// One streamed fallback sub-span drained by [`PendingPredict::poll_spans`]:
/// the request-row range it covers, its probabilities (empty when the span
/// failed server-side), and the instant its frame arrived.
pub struct FallbackSpan {
    pub span: Range<usize>,
    pub probs: Vec<f32>,
    pub failed: bool,
    pub arrived: Instant,
}

/// Everything a completed call yields beyond the probabilities: the
/// completion instant, per-span arrival metadata (streamed responses only —
/// spans already drained by `poll_spans` are excluded), and the actual
/// request/response wire bytes moved (streamed responses carry per-chunk
/// frame overhead the up-front estimate cannot know).
pub struct StreamOutcome {
    pub probs: Vec<f32>,
    /// Arrival instant of the terminal frame — the request's completion.
    pub arrived: Instant,
    /// `(span, arrival, failed)` for chunks drained during the final join.
    pub spans: Vec<(Range<usize>, Instant, bool)>,
    pub req_bytes: u64,
    pub resp_bytes: u64,
    /// The first attempt died on a stale pooled connection and this
    /// outcome comes from the fresh-dial retry. Spans a caller drained
    /// from the FIRST attempt belong to an aborted stream and must be
    /// discarded in favor of `spans`; byte counts here already include
    /// both attempts' traffic.
    pub retried: bool,
}

/// An in-flight [`RpcClient::predict_async`] call. Dropping it abandons the
/// request (late frames are discarded by the reader thread).
pub struct PendingPredict<'a> {
    client: &'a RpcClient,
    conn: Arc<Conn>,
    req: Request,
    rx: ReplyRx,
    n_rows: usize,
    /// Per-request deadline; clamps every wait below.
    deadline: Option<Deadline>,
    /// When the request frame went out (breaker latency accounting).
    sent_at: Instant,
    /// Streamed-chunk reassembly state (None until the first chunk).
    asm: Option<proto::StreamAssembler>,
    /// Response-side wire bytes consumed so far.
    resp_bytes: u64,
    /// Terminal frame drained early by `poll_spans`, replayed by the join.
    terminal: Option<(ClientFrame, Instant)>,
    /// Fatal error discovered by `poll_spans`, replayed by the join.
    early_err: Option<io::Error>,
}

impl PendingPredict<'_> {
    /// Rows this call asked the service to score.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Wire bytes of the request frame this call sent.
    pub fn req_wire_bytes(&self) -> u64 {
        self.req.wire_size() as u64
    }

    /// Drain — without blocking — any streamed sub-spans that have arrived
    /// since the last poll: fallback rows become consumable while later
    /// spans are still on the wire. Returns an empty vec when nothing new
    /// arrived, the response is monolithic, or the stream has ended (call
    /// [`PendingPredict::wait`] to join). Failed spans are reported here
    /// with `failed = true` and surface again as the request's error at the
    /// join.
    pub fn poll_spans(&mut self) -> Vec<FallbackSpan> {
        let mut out = Vec::new();
        if self.terminal.is_some() || self.early_err.is_some() {
            return out;
        }
        while let Ok(msg) = self.rx.try_recv() {
            match msg {
                Ok((ClientFrame::Chunk(c), arrived)) => {
                    self.resp_bytes += c.wire_size() as u64;
                    let asm = self
                        .asm
                        .get_or_insert_with(|| proto::StreamAssembler::new(self.n_rows));
                    let span = c.span();
                    let failed = c.failed;
                    if let Err(e) = asm.push(&c) {
                        self.early_err = Some(e);
                        break;
                    }
                    out.push(FallbackSpan { span, probs: c.probs, failed, arrived });
                }
                Ok(terminal) => {
                    // Bytes are booked when the join consumes it.
                    self.terminal = Some(terminal);
                    break;
                }
                Err(e) => {
                    // Early stream end (reader death error-completed this
                    // request after some chunks, before `STREAM_END`):
                    // surface every not-yet-delivered row range as an
                    // explicit failed span so pollers account the whole
                    // request instead of waiting on rows that will never
                    // arrive.
                    if let Some(asm) = &self.asm {
                        let now = Instant::now();
                        for span in asm.missing_spans() {
                            out.push(FallbackSpan {
                                span,
                                probs: Vec::new(),
                                failed: true,
                                arrived: now,
                            });
                        }
                    }
                    self.early_err = Some(e);
                    break;
                }
            }
        }
        out
    }

    /// Block for the response. Transport failures retry on fresh dials
    /// under the client's [`RetryPolicy`] (see module docs).
    pub fn wait(self) -> io::Result<Vec<f32>> {
        self.wait_timed().map(|(probs, _)| probs)
    }

    /// Like [`PendingPredict::wait`], also returning the instant the
    /// terminal frame arrived at the client — completion time for latency
    /// accounting, which precedes the join when the caller overlapped
    /// other work before waiting.
    pub fn wait_timed(self) -> io::Result<(Vec<f32>, Instant)> {
        self.wait_outcome().map(|o| (o.probs, o.arrived))
    }

    /// Full join: probabilities plus the accounting metadata (per-span
    /// arrivals, actual wire bytes). Errors if the server failed the
    /// request OR any streamed span — span-level detail for the error case
    /// is visible through [`PendingPredict::poll_spans`] before the join.
    ///
    /// Transport failures are retried on fresh dials under the client's
    /// unified [`RetryPolicy`]; each failed attempt feeds the breaker, a
    /// successful join feeds its latency histogram and the retry budget.
    pub fn wait_outcome(mut self) -> io::Result<StreamOutcome> {
        let mut err = match self.drive() {
            Ok(o) => return Ok(self.client.settle_success(o, self.sent_at)),
            Err(e) => e,
        };
        // The aborted first attempt's traffic really crossed the wire:
        // fold its request frame and partial chunks into the byte
        // accounting of whichever retry succeeds.
        let mut extra_req = self.req.wire_size() as u64;
        let extra_resp = self.resp_bytes;
        let mut retry = 0u32;
        loop {
            if fault::is_deadline_exceeded(&err) {
                // Client-imposed budget expiry, not a backend failure.
                return Err(err);
            }
            // An explicit overload rejection is a HEALTHY server saying
            // "back off": it must never count toward the breaker's
            // consecutive failures, and any retry must wait out at least
            // the server's retry-after hint (on top of the normal jittered
            // backoff) — otherwise rejection becomes a retry storm.
            let overloaded = fault::is_overloaded(&err);
            if !overloaded {
                self.client.breaker.record_failure();
            }
            if !(overloaded || retryable_error(&err))
                || !self
                    .client
                    .pay_for_retry(retry + 1, self.deadline, fault::retry_after(&err))
            {
                return Err(err);
            }
            retry += 1;
            match self.client.call_on_fresh(&self.req, self.n_rows, self.deadline) {
                Ok(mut o) => {
                    o.req_bytes += extra_req;
                    o.resp_bytes += extra_resp;
                    // Flag the retry so callers discard any spans they
                    // drained from the dead stream in favor of `o.spans`.
                    o.retried = true;
                    return Ok(self.client.settle_success(o, self.sent_at));
                }
                Err(e) => {
                    extra_req += self.req.wire_size() as u64;
                    err = e;
                }
            }
        }
    }

    /// Abandon the request and retire the (possibly wedged) connection.
    fn abandon(&self) {
        self.conn.lock_pending().remove(&self.req.req_id);
        self.conn.retire();
    }

    /// Drive this call to its terminal frame — no retry policy here.
    fn drive(&mut self) -> io::Result<StreamOutcome> {
        if let Some(e) = self.early_err.take() {
            if stale_connection_error(&e) {
                return Err(e); // transport failure: entry already drained
            }
            self.abandon();
            return Err(e);
        }
        let mut spans: Vec<(Range<usize>, Instant, bool)> = Vec::new();
        loop {
            let (frame, arrived) = match self.terminal.take() {
                Some(t) => t,
                None => {
                    // Wait to the client timeout, clamped to the request's
                    // own deadline when it carries one.
                    let mut wait = self.client.timeout;
                    if let Some(d) = self.deadline {
                        let left = d.remaining();
                        if left.is_zero() {
                            self.abandon();
                            return Err(fault::deadline_error());
                        }
                        wait = wait.min(left);
                    }
                    match self.rx.recv_timeout(wait) {
                        Ok(Ok(pair)) => pair,
                        Ok(Err(e)) => return Err(e),
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            // Reader thread vanished without answering
                            // (shutdown race).
                            return Err(io::Error::new(
                                io::ErrorKind::BrokenPipe,
                                "connection reader gone",
                            ));
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            // The wait is already spent; `retire` wakes
                            // every capped sender — no response frees
                            // slots now.
                            self.abandon();
                            if self.deadline.is_some_and(|d| d.expired()) {
                                return Err(fault::deadline_error());
                            }
                            return Err(io::Error::new(
                                io::ErrorKind::TimedOut,
                                "rpc response timed out",
                            ));
                        }
                    }
                }
            };
            self.resp_bytes += frame.wire_size();
            match frame {
                ClientFrame::Rejected { req_id, retry_after_ms } => {
                    debug_assert_eq!(req_id, self.req.req_id, "demux invariant");
                    // Explicit admission/shed refusal from a healthy server:
                    // terminal for this attempt, classified overloaded so
                    // the caller backs off instead of burning the breaker.
                    return Err(fault::overloaded_error(Duration::from_millis(
                        retry_after_ms as u64,
                    )));
                }
                ClientFrame::Chunk(c) => {
                    let asm = self
                        .asm
                        .get_or_insert_with(|| proto::StreamAssembler::new(self.n_rows));
                    let span = c.span();
                    let failed = c.failed;
                    if let Err(e) = asm.push(&c) {
                        self.abandon();
                        return Err(e);
                    }
                    spans.push((span, arrived, failed));
                }
                ClientFrame::StreamEnd { req_id, n_chunks } => {
                    debug_assert_eq!(req_id, self.req.req_id, "demux invariant");
                    let asm = self
                        .asm
                        .take()
                        .unwrap_or_else(|| proto::StreamAssembler::new(self.n_rows));
                    let (probs, failed) = match asm.finish(n_chunks) {
                        Ok(ok) => ok,
                        Err(e) => {
                            // Entry already retired by the terminal frame;
                            // the connection itself lost protocol sync.
                            self.conn.retire();
                            return Err(e);
                        }
                    };
                    if !failed.is_empty() {
                        return Err(io::Error::other(format!(
                            "server failed {} sub-span(s) of the streamed response",
                            failed.len()
                        )));
                    }
                    if probs.len() != self.n_rows {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("expected {} probabilities, got {}", self.n_rows, probs.len()),
                        ));
                    }
                    return Ok(StreamOutcome {
                        probs,
                        arrived,
                        spans,
                        req_bytes: self.req.wire_size() as u64,
                        resp_bytes: self.resp_bytes,
                        retried: false,
                    });
                }
                ClientFrame::Response(resp) => {
                    if resp.req_id != self.req.req_id {
                        // The demux table makes this unreachable; keep the
                        // invariant hard.
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "response id mismatch",
                        ));
                    }
                    if resp.error {
                        // A live answer from a healthy connection — final,
                        // whether or not chunks preceded it (a panicking
                        // streamed backend error-frames the whole request).
                        return Err(io::Error::other("server reported a backend failure"));
                    }
                    if self.asm.is_some() {
                        self.conn.retire();
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "monolithic response arrived mid-stream",
                        ));
                    }
                    if resp.probs.len() != self.n_rows {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!(
                                "expected {} probabilities, got {}",
                                self.n_rows,
                                resp.probs.len()
                            ),
                        ));
                    }
                    return Ok(StreamOutcome {
                        probs: resp.probs,
                        arrived,
                        spans,
                        req_bytes: self.req.wire_size() as u64,
                        resp_bytes: self.resp_bytes,
                        retried: false,
                    });
                }
            }
        }
    }
}

/// Transport failures that indicate a stale pooled connection (the far side
/// closed it between calls) — the only errors worth a fresh-dial retry. A
/// spent deadline (`TimedOut`) and live server answers (error frames map to
/// `Other`, malformed lengths to `InvalidData`) are final.
fn stale_connection_error(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe
            | io::ErrorKind::NotConnected
    )
}

/// Errors the retry policy may spend attempts on: stale-connection
/// transport failures plus a refused fresh dial (the server may be
/// mid-restart). Breaker fast-fails also map to `ConnectionRefused` by
/// kind but never reach a retry loop — they are returned before any
/// attempt is made.
fn retryable_error(e: &io::Error) -> bool {
    stale_connection_error(e) || e.kind() == io::ErrorKind::ConnectionRefused
}

impl RpcClient {
    pub fn connect(addr: SocketAddr) -> io::Result<RpcClient> {
        RpcClient::connect_with(addr, ClientConfig::default())
    }

    /// Connect with explicit failure-model tuning.
    pub fn connect_with(addr: SocketAddr, cfg: ClientConfig) -> io::Result<RpcClient> {
        let client = RpcClient {
            addr,
            pool: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            timeout: cfg.timeout,
            max_in_flight: cfg.max_in_flight.max(1),
            retry: cfg.retry,
            budget: RetryBudget::default(),
            breaker: CircuitBreaker::new(cfg.breaker),
            backoff_rng: Mutex::new(Rng::new(0x5eed_b0ff)),
            retries: AtomicU64::new(0),
            tenant: cfg.tenant,
        };
        // Eagerly dial one connection to fail fast on a bad address.
        client.dial_into_pool()?;
        Ok(client)
    }

    /// The client's circuit breaker — observable state/trip counters, and
    /// `force_open`/`force_close` for drills and degradation tests.
    pub fn breaker(&self) -> &CircuitBreaker {
        &self.breaker
    }

    /// Transport-level retries performed so far (telemetry).
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Whole retries the budget can still pay for (telemetry).
    pub fn retry_budget_left(&self) -> u64 {
        self.budget.available()
    }

    /// Pay for retry number `retry` (1-based): bounded by the policy,
    /// charged to the shared budget, and its backoff sleep must fit inside
    /// the caller's deadline. `min_pause` (a server retry-after hint)
    /// floors the sleep — the jittered backoff may exceed it, never
    /// undercut it. Returns `false` — don't retry — otherwise sleeps out
    /// the pause and counts the retry.
    fn pay_for_retry(
        &self,
        retry: u32,
        deadline: Option<Deadline>,
        min_pause: Option<Duration>,
    ) -> bool {
        if retry > self.retry.max_retries || !self.budget.try_withdraw() {
            return false;
        }
        let mut pause = {
            let mut rng = self.backoff_rng.lock().unwrap_or_else(PoisonError::into_inner);
            self.retry.backoff(retry, &mut rng)
        };
        if let Some(hint) = min_pause {
            pause = pause.max(hint);
        }
        if deadline.is_some_and(|d| d.remaining() <= pause) {
            return false; // the remaining budget can't absorb the backoff
        }
        self.retries.fetch_add(1, Ordering::Relaxed);
        std::thread::sleep(pause);
        true
    }

    /// Book a fully-successful round trip: feeds the breaker's latency
    /// histogram (p99 rule) and replenishes the retry budget.
    fn settle_success(&self, o: StreamOutcome, sent_at: Instant) -> StreamOutcome {
        self.breaker
            .record_success(o.arrived.saturating_duration_since(sent_at));
        self.budget.deposit();
        o
    }

    fn lock_pool(&self) -> MutexGuard<'_, Vec<Arc<Conn>>> {
        self.pool.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Cap the in-flight (unanswered) frames per connection — total
    /// outstanding work is bounded by `cap ×` [`POOL_CONNS`]. Lowering it
    /// tightens backpressure against a slow server; must be set before the
    /// client is shared.
    pub fn set_max_in_flight(&mut self, cap: usize) {
        self.max_in_flight = cap.max(1);
    }

    /// Unanswered requests currently registered across the pool (the demux
    /// tables' total size — what the in-flight cap bounds).
    pub fn total_in_flight(&self) -> usize {
        self.lock_pool()
            .iter()
            .map(|c| c.lock_pending().len())
            .sum()
    }

    /// Dial a connection, spawn its reader thread, and pool it.
    fn dial_into_pool(&self) -> io::Result<Arc<Conn>> {
        let stream = TcpStream::connect_timeout(&self.addr, Duration::from_secs(5))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        let reader_half = stream.try_clone()?;
        let conn = Arc::new(Conn {
            writer: Mutex::new(stream),
            pending: Mutex::new(HashMap::new()),
            slot_freed: Condvar::new(),
            dead: AtomicBool::new(false),
        });
        let for_reader = conn.clone();
        std::thread::Builder::new()
            .name("rpc-client-reader".into())
            .spawn(move || reader_loop(for_reader, reader_half))?;
        let mut pool = self.lock_pool();
        pool.retain(|c| !c.dead.load(Ordering::Relaxed));
        if pool.len() < POOL_CONNS {
            pool.push(conn.clone());
        }
        Ok(conn)
    }

    /// A live connection for the next request: round-robin over the pool,
    /// growing it toward [`POOL_CONNS`].
    fn live_conn(&self) -> io::Result<Arc<Conn>> {
        {
            let mut pool = self.lock_pool();
            pool.retain(|c| !c.dead.load(Ordering::Relaxed));
            if pool.len() >= POOL_CONNS {
                let i = self.rr.fetch_add(1, Ordering::Relaxed) % pool.len();
                return Ok(pool[i].clone());
            }
        }
        self.dial_into_pool()
    }

    /// Register the request in `conn`'s pending table and write its frame.
    /// Blocks while the connection already carries [`RpcClient::max_in_flight`]
    /// unanswered frames (backpressure from a slow server), giving up with
    /// `TimedOut` after the client timeout — or with a deadline error at
    /// the request's own deadline, whichever is sooner.
    fn send_on(
        &self,
        conn: &Conn,
        req: &Request,
        buf: &[u8],
        deadline: Option<Deadline>,
    ) -> io::Result<ReplyRx> {
        let (tx, rx) = mpsc::channel();
        {
            let mut cap_deadline = Instant::now() + self.timeout;
            if let Some(d) = deadline {
                cap_deadline = cap_deadline.min(d.instant());
            }
            let mut pending = conn.lock_pending();
            while pending.len() >= self.max_in_flight && !conn.dead.load(Ordering::Relaxed) {
                let now = Instant::now();
                if now >= cap_deadline {
                    if deadline.is_some_and(|d| d.expired()) {
                        return Err(fault::deadline_error());
                    }
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "in-flight cap: no response freed a slot within the timeout",
                    ));
                }
                let (guard, _) = conn
                    .slot_freed
                    .wait_timeout(pending, cap_deadline - now)
                    .unwrap_or_else(PoisonError::into_inner);
                pending = guard;
            }
            // A dead connection is surfaced by the existing post-write
            // check below (the write itself may also fail); registering on
            // it is harmless — fail_all already drained or will never run
            // again, and the entry is removed right there.
            pending.insert(req.req_id, tx);
        }
        let res = proto::write_frame(&mut *conn.lock_writer(), buf);
        if let Err(e) = res {
            conn.lock_pending().remove(&req.req_id);
            conn.retire();
            return Err(e);
        }
        // The reader may have retired the connection (setting `dead`, then
        // draining `pending`) before our entry was registered — in that
        // case nobody will ever answer it. `fail_all` sets `dead` before
        // draining, so seeing it clear here means our entry either survives
        // or was drained with an error already queued on `rx`.
        if conn.dead.load(Ordering::Relaxed) && conn.release(req.req_id).is_some() {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "connection retired"));
        }
        Ok(rx)
    }

    /// Start an asynchronous batched inference call: the request frame is
    /// on the wire when this returns, and the response is collected by
    /// [`PendingPredict::wait`]. `rows.len() = n · row_len`.
    pub fn predict_async(&self, rows: &[f32], row_len: usize) -> io::Result<PendingPredict<'_>> {
        self.predict_async_opts(rows, row_len, &PredictOptions::default())
    }

    /// Like [`RpcClient::predict_async`], with per-call options: an expired
    /// deadline refuses the send outright ([`fault::deadline_error`]), an
    /// open breaker fails fast ([`fault::breaker_error`]), and the
    /// remaining budget rides the request frame so every downstream hop
    /// can shed the work once it expires.
    pub fn predict_async_opts(
        &self,
        rows: &[f32],
        row_len: usize,
        opts: &PredictOptions,
    ) -> io::Result<PendingPredict<'_>> {
        if let Some(d) = opts.deadline {
            if d.expired() {
                return Err(fault::deadline_error());
            }
        }
        if !self.breaker.admit() {
            return Err(fault::breaker_error());
        }
        let req = Request {
            req_id: self.next_id.fetch_add(1, Ordering::Relaxed),
            row_len: row_len as u32,
            rows: rows.to_vec(),
            deadline_us: opts.deadline.map_or(0, |d| d.remaining_us()),
            tenant: self.tenant,
        };
        let n_rows = req.n_rows() as usize;
        let mut buf = Vec::with_capacity(req.wire_size());
        proto::encode_request(&req, &mut buf);

        // Write-side retry loop: the first attempt uses a pooled
        // connection; every retry dials fresh, under the unified policy.
        let mut attempt = 0u32;
        loop {
            let sent = if attempt == 0 { self.live_conn() } else { self.dial_into_pool() }
                .and_then(|conn| {
                    let rx = self.send_on(&conn, &req, &buf, opts.deadline)?;
                    Ok((conn, rx))
                });
            match sent {
                Ok((conn, rx)) => return Ok(self.pending(conn, req, rx, n_rows, opts.deadline)),
                // A spent in-flight cap or deadline is final and client-side:
                // dialing fresh to dodge the cap would defeat the
                // backpressure, and it says nothing about backend health.
                Err(e) if e.kind() == io::ErrorKind::TimedOut => return Err(e),
                Err(e) => {
                    self.breaker.record_failure();
                    if retryable_error(&e) && self.pay_for_retry(attempt + 1, opts.deadline, None) {
                        attempt += 1;
                    } else {
                        return Err(e);
                    }
                }
            }
        }
    }

    fn pending(
        &self,
        conn: Arc<Conn>,
        req: Request,
        rx: ReplyRx,
        n_rows: usize,
        deadline: Option<Deadline>,
    ) -> PendingPredict<'_> {
        PendingPredict {
            client: self,
            conn,
            req,
            rx,
            n_rows,
            deadline,
            sent_at: Instant::now(),
            asm: None,
            resp_bytes: 0,
            terminal: None,
            early_err: None,
        }
    }

    /// One full round trip on a freshly dialed connection (the read-side
    /// retry path — no nested retries; the caller's loop owns the policy).
    fn call_on_fresh(
        &self,
        req: &Request,
        n_rows: usize,
        deadline: Option<Deadline>,
    ) -> io::Result<StreamOutcome> {
        let mut req = req.clone();
        if let Some(d) = deadline {
            // Re-encode the budget actually left at this (later) send.
            req.deadline_us = d.remaining_us();
        }
        let mut buf = Vec::with_capacity(req.wire_size());
        proto::encode_request(&req, &mut buf);
        let conn = self.dial_into_pool()?;
        let rx = self.send_on(&conn, &req, &buf, deadline)?;
        let mut retry = self.pending(conn, req, rx, n_rows, deadline);
        retry.drive()
    }

    /// Synchronous batched inference call. `rows.len() = n · row_len`.
    /// Returns one probability per row.
    pub fn predict(&self, rows: &[f32], row_len: usize) -> io::Result<Vec<f32>> {
        self.predict_async(rows, row_len)?.wait()
    }

    /// Synchronous call with per-call options (deadline etc.).
    pub fn predict_opts(
        &self,
        rows: &[f32],
        row_len: usize,
        opts: &PredictOptions,
    ) -> io::Result<Vec<f32>> {
        self.predict_async_opts(rows, row_len, opts)?.wait()
    }

    /// Round-trip ping (health check / RTT probe).
    pub fn ping(&self) -> io::Result<Duration> {
        let t0 = std::time::Instant::now();
        let probs = self.predict(&[], 0)?;
        debug_assert!(probs.is_empty());
        Ok(t0.elapsed())
    }

    /// Bytes that `predict` would move over the wire for bookkeeping.
    pub fn wire_bytes(n_rows: usize, row_len: usize) -> u64 {
        // Request header: len|req_id|n_rows|row_len|deadline_us|tenant
        // = 28 bytes.
        let req = 4 + 8 + 4 + 4 + 4 + 4 + (n_rows * row_len * 4) as u64;
        let resp = 4 + 8 + 4 + (n_rows * 4) as u64;
        req + resp
    }
}

impl Drop for RpcClient {
    /// Shut the sockets down so every reader thread sees EOF and exits now
    /// instead of idling until its read timeout.
    fn drop(&mut self) {
        for c in self.lock_pool().drain(..) {
            c.dead.store(true, Ordering::Relaxed);
            let _ = c.lock_writer().shutdown(std::net::Shutdown::Both);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::netsim::{NetSim, NetSimConfig};
    use crate::rpc::server::{Backend, BatcherConfig, RpcServer};
    use crate::telemetry::ServeMetrics;
    use std::sync::Arc;

    /// Echo-ish backend: prob = mean of the row (easy to verify).
    struct MeanBackend;

    impl Backend for MeanBackend {
        fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
            (0..n)
                .map(|r| {
                    let row = &rows[r * row_len..(r + 1) * row_len];
                    row.iter().sum::<f32>() / row_len as f32
                })
                .collect()
        }
        fn row_len(&self) -> usize {
            0
        }
    }

    fn start_server() -> (RpcServer, Arc<ServeMetrics>) {
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(100),
                workers: 2,
                stream: true,
                ..Default::default()
            },
            metrics.clone(),
        )
        .unwrap();
        (server, metrics)
    }

    #[test]
    fn roundtrip_single() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let probs = client.predict(&[1.0, 2.0, 3.0, 4.0], 4).unwrap();
        assert_eq!(probs, vec![2.5]);
    }

    #[test]
    fn roundtrip_batch() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let rows: Vec<f32> = (0..20).map(|i| i as f32).collect(); // 10 rows × 2
        let probs = client.predict(&rows, 2).unwrap();
        assert_eq!(probs.len(), 10);
        assert_eq!(probs[0], 0.5);
        assert_eq!(probs[9], 18.5);
    }

    #[test]
    fn pipelined_requests_demux_by_id() {
        // Many requests in flight on ONE client before any wait: responses
        // may complete out of order server-side; demux must route each to
        // its caller.
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let pendings: Vec<_> = (0..32)
            .map(|i| {
                let v = i as f32;
                client.predict_async(&[v, v + 2.0], 2).unwrap()
            })
            .collect();
        for (i, p) in pendings.into_iter().enumerate() {
            let probs = p.wait().unwrap();
            assert_eq!(probs, vec![i as f32 + 1.0], "request {i}");
        }
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let (server, metrics) = start_server();
        let addr = server.addr;
        let mut handles = Vec::new();
        for t in 0..8 {
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::connect(addr).unwrap();
                for i in 0..50 {
                    let v = (t * 100 + i) as f32;
                    let p = client.predict(&[v, v], 2).unwrap();
                    assert_eq!(p, vec![v]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Batcher really batched (fewer backend execs than requests is
        // likely but not guaranteed; at minimum it executed something).
        assert!(metrics.backend_exec.count() > 0);
        assert!(metrics.backend_exec.count() <= 400);
    }

    #[test]
    fn ping_works() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let rtt = client.ping().unwrap();
        assert!(rtt < Duration::from_secs(1));
    }

    #[test]
    fn netsim_raises_latency() {
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(
                NetSimConfig {
                    base_us: 2000.0,
                    sigma: 0.1,
                    max_us: 10_000.0,
                },
                7,
            )),
            BatcherConfig::default(),
            metrics,
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();
        let rtt = client.ping().unwrap();
        // Pings take the inbound injection (~2ms) only.
        assert!(rtt >= Duration::from_millis(1), "rtt={rtt:?}");
        // A real request takes both hops (~4ms).
        let t0 = std::time::Instant::now();
        client.predict(&[1.0, 2.0], 2).unwrap();
        let full = t0.elapsed();
        assert!(full >= Duration::from_millis(3), "full={full:?}");
    }

    #[test]
    fn stale_pooled_connection_recovers_across_server_restart() {
        // Cycle the server between calls: the pooled connection the first
        // call parked is dead for the second. Whichever side notices (the
        // write is rejected, the reader sees EOF after the write was
        // swallowed, or the reader already retired the connection), the
        // call must transparently succeed against the restarted server.
        let (server, _m) = start_server();
        let addr = server.addr;
        let client = RpcClient::connect(addr).unwrap();
        // Warm the pool to POOL_CONNS so the post-restart call is routed to
        // a POOLED (reused) connection — the only case eligible for retry.
        for i in 0..(2 * POOL_CONNS) {
            let v = i as f32;
            assert_eq!(client.predict(&[v, v + 2.0], 2).unwrap(), vec![v + 1.0]);
        }

        drop(server);
        std::thread::sleep(Duration::from_millis(50));
        let server2 = RpcServer::start(
            &addr.to_string(),
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig::default(),
            Arc::new(ServeMetrics::new()),
        )
        .expect("rebind the same address");
        assert_eq!(server2.addr, addr);

        let probs = client.predict(&[10.0, 20.0], 2).unwrap();
        assert_eq!(probs, vec![15.0]);
    }

    #[test]
    fn breaker_force_open_fails_fast_then_recovers() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        assert_eq!(client.predict(&[2.0, 4.0], 2).unwrap(), vec![3.0]);

        client.breaker().force_open();
        let t0 = Instant::now();
        let e = client.predict(&[2.0, 4.0], 2).unwrap_err();
        assert!(fault::is_breaker_open(&e), "unexpected error: {e}");
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "open breaker must fail fast, not attempt the call"
        );

        client.breaker().force_close();
        assert_eq!(client.predict(&[2.0, 4.0], 2).unwrap(), vec![3.0]);
    }

    #[test]
    fn admission_rejection_classifies_overloaded_and_spares_the_breaker() {
        // A 1-row burst with a trickle refill: the first call drains the
        // bucket, the second is refused at the door. The breaker is set
        // to trip on ONE consecutive failure, so it staying closed proves
        // explicit rejections never burn failure counts.
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig {
                admission: Some(crate::rpc::admission::AdmissionConfig {
                    tenant_rate_rows_per_s: 0.001,
                    tenant_burst_rows: 1.0,
                    global_inflight_rows: 0,
                }),
                ..Default::default()
            },
            metrics.clone(),
        )
        .unwrap();
        let client = RpcClient::connect_with(
            server.addr,
            ClientConfig {
                retry: RetryPolicy::none(),
                breaker: BreakerConfig {
                    failure_threshold: 1,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();

        assert_eq!(client.predict(&[2.0, 4.0], 2).unwrap(), vec![3.0]);
        let e = client.predict(&[2.0, 4.0], 2).unwrap_err();
        assert!(fault::is_overloaded(&e), "unexpected error: {e}");
        let hint = fault::retry_after(&e).expect("rejection carries a hint");
        assert!(hint >= Duration::from_millis(1), "hint too small: {hint:?}");
        assert_eq!(
            client.breaker().state(),
            fault::BreakerState::Closed,
            "a rejection must not count toward breaker failures"
        );
        assert_eq!(metrics.rejected_requests.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rejected_rows.load(Ordering::Relaxed), 1);
    }

    /// Backend that parks each batch until the test releases it — pins
    /// its admission permit so the global in-flight cap stays saturated.
    struct GatedBackend {
        release: Mutex<mpsc::Receiver<()>>,
    }

    impl Backend for GatedBackend {
        fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
            let _ = self
                .release
                .lock()
                .unwrap()
                .recv_timeout(Duration::from_secs(10));
            (0..n).map(|r| rows[r * row_len]).collect()
        }
        fn row_len(&self) -> usize {
            0
        }
    }

    #[test]
    fn rejection_does_not_amplify_offered_load() {
        // Retry-storm regression: saturate the global in-flight cap with
        // one parked request, then offer K calls whose every attempt is
        // refused. The retry budget (10 tokens, starts full) caps total
        // server-seen attempts at K + 10 no matter how eager the retry
        // policy is — offered load must not amplify under rejection.
        const K: u64 = 6;
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(GatedBackend {
                release: Mutex::new(rx),
            }),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig {
                admission: Some(crate::rpc::admission::AdmissionConfig {
                    global_inflight_rows: 1,
                    ..Default::default()
                }),
                ..Default::default()
            },
            metrics.clone(),
        )
        .unwrap();
        let admission = server.admission().expect("admission is on").clone();
        let client = RpcClient::connect_with(
            server.addr,
            ClientConfig {
                retry: RetryPolicy {
                    max_retries: 3,
                    base_backoff: Duration::from_millis(1),
                    max_backoff: Duration::from_millis(4),
                    jitter: 0.0,
                },
                ..Default::default()
            },
        )
        .unwrap();

        // Park one admitted request in the backend; wait until it holds
        // the whole cap before offering the storm.
        let blocker = client.predict_async(&[7.0], 1).unwrap();
        let t0 = Instant::now();
        while admission.inflight_rows() < 1 {
            assert!(t0.elapsed() < Duration::from_secs(5), "blocker never admitted");
            std::thread::sleep(Duration::from_millis(1));
        }

        let mut overloaded_errors = 0u64;
        for _ in 0..K {
            let e = client.predict(&[1.0], 1).unwrap_err();
            assert!(fault::is_overloaded(&e), "unexpected error: {e}");
            assert_eq!(fault::retry_after(&e), Some(Duration::from_millis(5)));
            overloaded_errors += 1;
        }
        assert_eq!(overloaded_errors, K);

        tx.send(()).unwrap();
        assert_eq!(blocker.wait().unwrap(), vec![7.0]);

        let attempts = admission.admitted_requests() + admission.rejected_requests();
        assert!(
            admission.rejected_requests() >= K,
            "rejections must actually have occurred"
        );
        assert!(
            attempts <= 1 + K + 10,
            "offered load amplified: {attempts} server-seen attempts from {} calls",
            1 + K
        );
        assert_eq!(
            metrics.rejected_requests.load(Ordering::Relaxed),
            admission.rejected_requests()
        );
    }

    #[test]
    fn expired_deadline_refused_before_send() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let opts = PredictOptions {
            deadline: Some(Deadline::at(Instant::now() - Duration::from_millis(1))),
            ..PredictOptions::default()
        };
        let e = client.predict_opts(&[1.0, 1.0], 2, &opts).unwrap_err();
        assert!(fault::is_deadline_exceeded(&e), "unexpected error: {e}");
    }

    #[test]
    fn deadline_bounds_the_wait_against_a_slow_server() {
        // SlowBackend takes ~10ms per batch; a 3ms budget must surface as
        // a deadline error at ~3ms, not ride out the server's pace (nor
        // the client's 30s timeout).
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(SlowBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig::default(),
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();
        let t0 = Instant::now();
        let e = client
            .predict_opts(&[1.0, 2.0], 2, &PredictOptions::with_budget(Duration::from_millis(3)))
            .unwrap_err();
        assert!(fault::is_deadline_exceeded(&e), "unexpected error: {e}");
        assert!(t0.elapsed() < Duration::from_secs(2), "deadline must bound the wait");
    }

    /// Backend slow enough that pipelined senders outrun the responses.
    struct SlowBackend;

    impl Backend for SlowBackend {
        fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
            std::thread::sleep(Duration::from_millis(10));
            (0..n).map(|r| rows[r * row_len]).collect()
        }
        fn row_len(&self) -> usize {
            0
        }
    }

    #[test]
    fn in_flight_cap_bounds_pending_against_slow_server() {
        use std::sync::atomic::AtomicBool;
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(SlowBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::ZERO,
                workers: 1, // one slow lane: responses trail far behind sends
                stream: true,
                ..Default::default()
            },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let mut client = RpcClient::connect(server.addr).unwrap();
        const CAP: usize = 2;
        client.set_max_in_flight(CAP);

        // 4 producers × 6 pipelined calls = 24 requests, far past the
        // bound of CAP × POOL_CONNS = 8 — without the cap the pending
        // tables would grow to ~24; with it, senders block instead.
        let done = AtomicBool::new(false);
        let max_seen = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            let client = &client;
            let done = &done;
            let max_seen = &max_seen;
            s.spawn(move || {
                let mut max = 0;
                while !done.load(Ordering::Relaxed) {
                    max = max.max(client.total_in_flight());
                    std::thread::sleep(Duration::from_micros(300));
                }
                max_seen.store(max, Ordering::Relaxed);
            });
            let producers: Vec<_> = (0..4)
                .map(|t| {
                    s.spawn(move || {
                        let pendings: Vec<_> = (0..6)
                            .map(|i| {
                                let v = (t * 100 + i) as f32;
                                client.predict_async(&[v, 0.0], 2).unwrap()
                            })
                            .collect();
                        for (i, p) in pendings.into_iter().enumerate() {
                            let v = (t * 100 + i) as f32;
                            assert_eq!(p.wait().unwrap(), vec![v], "producer {t} call {i}");
                        }
                    })
                })
                .collect();
            for h in producers {
                h.join().unwrap();
            }
            // Producers done: release the sampler (joined on scope exit).
            done.store(true, Ordering::Relaxed);
        });
        // The structural invariant (insert only under the cap check) keeps
        // every connection at ≤ CAP; the sampler must never have observed
        // more than CAP × POOL_CONNS across the pool.
        assert!(
            max_seen.load(Ordering::Relaxed) <= CAP * POOL_CONNS,
            "pending grew past the cap: {} > {}",
            max_seen.load(Ordering::Relaxed),
            CAP * POOL_CONNS
        );
        assert_eq!(client.total_in_flight(), 0, "all slots released");
    }

    /// Backend that streams 8-row sub-spans front to back with a pause
    /// between them — deterministic incremental arrival for the client
    /// tests. Rows whose first value is ≥ 1000 fail their whole span.
    struct TrickleBackend;

    const TRICKLE_SPAN: usize = 8;

    impl Backend for TrickleBackend {
        fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
            (0..n).map(|r| rows[r * row_len]).collect()
        }
        fn predict_streamed(
            &self,
            rows: &[f32],
            n: usize,
            row_len: usize,
            sink: &(dyn Fn(std::ops::Range<usize>, &[f32], bool) + Sync),
        ) -> bool {
            if n < 2 * TRICKLE_SPAN {
                return false;
            }
            let mut at = 0;
            while at < n {
                let hi = (at + TRICKLE_SPAN).min(n);
                let probs: Vec<f32> = (at..hi).map(|r| rows[r * row_len]).collect();
                if probs.iter().any(|&v| v >= 1000.0) {
                    sink(at..hi, &[], true);
                } else {
                    sink(at..hi, &probs, false);
                }
                at = hi;
                std::thread::sleep(Duration::from_millis(5));
            }
            true
        }
        fn row_len(&self) -> usize {
            0
        }
    }

    fn trickle_server() -> RpcServer {
        RpcServer::start(
            "127.0.0.1:0",
            Arc::new(TrickleBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig::default(),
            Arc::new(ServeMetrics::new()),
        )
        .unwrap()
    }

    #[test]
    fn poll_spans_consumes_fallback_rows_while_stream_in_flight() {
        let server = trickle_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let n = 32; // 4 trickle spans, ~5ms apart
        let rows: Vec<f32> = (0..n * 2).map(|i| (i / 2) as f32).collect();
        let mut pending = client.predict_async(&rows, 2).unwrap();

        // Drain incrementally: the FIRST span must be consumable well
        // before the stream ends (the tail spans are still being slept
        // out server-side).
        let t0 = Instant::now();
        let mut got: Vec<FallbackSpan> = Vec::new();
        let mut first_at = None;
        while got.iter().map(|s| s.span.len()).sum::<usize>() < n {
            for s in pending.poll_spans() {
                if first_at.is_none() {
                    first_at = Some(t0.elapsed());
                }
                got.push(s);
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "stream stalled");
            std::thread::sleep(Duration::from_micros(200));
        }
        let first_at = first_at.expect("at least one span");
        let all_at = t0.elapsed();
        assert!(
            first_at < all_at,
            "first span ({first_at:?}) must beat stream completion ({all_at:?})"
        );
        // Spans carry the right rows (prob = first value of the row).
        got.sort_by_key(|s| s.span.start);
        for s in &got {
            assert!(!s.failed);
            for (k, &p) in s.probs.iter().enumerate() {
                assert_eq!(p, (s.span.start + k) as f32, "span {:?}", s.span);
            }
        }
        // The join returns the full reassembled response.
        let probs = pending.wait().unwrap();
        let expect: Vec<f32> = (0..n).map(|r| r as f32).collect();
        assert_eq!(probs, expect);
    }

    #[test]
    fn streamed_failed_span_errors_the_join_but_polls_good_spans() {
        let server = trickle_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let n = 24; // spans 0..8 ok, 8..16 poisoned, 16..24 ok
        let mut rows: Vec<f32> = (0..n * 2).map(|i| (i / 2) as f32).collect();
        rows[10 * 2] = 2000.0;
        let mut pending = client.predict_async(&rows, 2).unwrap();
        let t0 = Instant::now();
        let mut seen = Vec::new();
        while seen.iter().map(|s: &FallbackSpan| s.span.len()).sum::<usize>() < n {
            seen.extend(pending.poll_spans());
            assert!(t0.elapsed() < Duration::from_secs(5), "stream stalled");
            std::thread::sleep(Duration::from_micros(200));
        }
        seen.sort_by_key(|s| s.span.start);
        assert_eq!(seen.len(), 3);
        assert!(!seen[0].failed && !seen[2].failed);
        assert!(seen[1].failed, "the poisoned span reports failed");
        assert!(seen[1].probs.is_empty());
        // Good spans still delivered their rows...
        assert_eq!(seen[2].probs[0], 16.0);
        // ...but the join surfaces the failure, like a whole-request error.
        assert!(pending.wait().is_err());
    }

    #[test]
    fn wait_outcome_reports_streamed_spans_and_actual_bytes() {
        let server = trickle_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let n = 16; // exactly 2 spans
        let rows: Vec<f32> = (0..n * 2).map(|i| (i / 2) as f32).collect();
        let pending = client.predict_async(&rows, 2).unwrap();
        let req_bytes = pending.req_wire_bytes();
        let outcome = pending.wait_outcome().unwrap();
        assert_eq!(outcome.probs.len(), n);
        assert_eq!(outcome.spans.len(), 2, "un-polled spans surface at the join");
        assert_eq!(outcome.req_bytes, req_bytes);
        // Actual bytes: 2 chunk frames (header 28 + 8×4 payload) + end (20).
        let expected_resp = 2 * (4 + 8 + 4 + 4 + 4 + 4 + TRICKLE_SPAN * 4) as u64 + 20;
        assert_eq!(outcome.resp_bytes, expected_resp);
        // Monolithic comparison: a tiny request (backend declines to
        // stream) books exactly the classic estimate.
        let pending = client.predict_async(&rows[..4 * 2], 2).unwrap();
        let outcome = pending.wait_outcome().unwrap();
        assert!(outcome.spans.is_empty());
        assert_eq!(
            outcome.req_bytes + outcome.resp_bytes,
            RpcClient::wire_bytes(4, 2),
            "monolithic path matches the wire_bytes estimate"
        );
    }

    #[test]
    fn server_shutdown_clean() {
        let (server, _m) = start_server();
        let addr = server.addr;
        drop(server);
        // New connections should fail or be closed promptly.
        std::thread::sleep(Duration::from_millis(50));
        let r = RpcClient::connect(addr).and_then(|c| c.predict(&[1.0], 1));
        assert!(r.is_err());
    }
}
