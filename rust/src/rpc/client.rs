//! Pipelined pooled RPC client — the product-code side of the RPC API.
//!
//! Each connection is **multiplexed**: callers write request frames onto a
//! shared pooled connection without waiting for earlier responses, and a
//! dedicated reader thread demultiplexes response frames back to the right
//! caller by `req_id`. That is what lets the coordinator keep a coalesced
//! fallback RPC in flight while it evaluates the next block's stage-1 pass
//! (and lets the server's dynamic batcher coalesce requests that share a
//! connection). The old design — one exclusively-owned connection per call
//! for its full round trip — serialized everything behind the slowest
//! outstanding request.
//!
//! [`RpcClient::predict_async`] returns a [`PendingPredict`] handle
//! immediately after the request frame is written; [`PendingPredict::wait`]
//! blocks for the demuxed response. [`RpcClient::predict`] is the blocking
//! composition of the two.
//!
//! ## Failure handling
//!
//! A pooled connection can go stale between calls (server restarted, idle
//! reap on the far side). Both failure sides are retried **once** on a
//! fresh dial, but only when the failed connection was *pooled* — a
//! connection dialed by this very call failing means the server is really
//! gone:
//! * write side: `write_frame` fails (stale socket rejects the send);
//! * read side: the response never arrives because the reader saw
//!   EOF/reset — the stale socket *accepted* the write into a dead buffer.
//!
//! A response frame flagged as a server-side error (backend failure) is
//! surfaced as an error without retry: it is a live answer from a healthy
//! connection, and resending would fail the same way.
//!
//! ## Backpressure
//!
//! In-flight frames are capped per connection ([`DEFAULT_MAX_IN_FLIGHT`],
//! tunable via [`RpcClient::set_max_in_flight`]): a sender that would push
//! a connection past the cap blocks until the server answers (or the
//! connection fails), and gives up with `TimedOut` at the client timeout.
//! Without the cap, a slow server would let the pending demux table — and
//! its own admission queue — grow with every pipelined call that outruns
//! the responses.

use super::proto::{self, Request, Response};
use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Connections kept per client. Requests round-robin across them so
/// per-connection frame transmission overlaps across concurrent requests.
const POOL_CONNS: usize = 4;

/// Default cap on in-flight (pipelined, unanswered) requests per
/// connection. A slow or wedged server must exert **backpressure** on
/// callers instead of letting the pending demux table — and the server's
/// admission queue — grow without bound: once a connection carries this
/// many unanswered frames, further sends on it block until a response (or
/// failure) frees a slot, and give up with `TimedOut` after the client
/// timeout.
pub const DEFAULT_MAX_IN_FLIGHT: usize = 64;

/// Responses carry the instant their frame arrived at the client: metrics
/// want completion time, which is earlier than the caller's join when the
/// caller overlaps other work before waiting.
type ReplyTx = mpsc::Sender<io::Result<(Response, Instant)>>;

/// One pipelined connection: a writer half shared by callers (frames are
/// written whole under the lock) and a reader thread that routes response
/// frames to the pending table by `req_id`.
struct Conn {
    writer: Mutex<TcpStream>,
    pending: Mutex<HashMap<u64, ReplyTx>>,
    /// Signalled whenever `pending` shrinks (response demuxed, request
    /// abandoned, connection failed): senders blocked on the in-flight cap
    /// wait here.
    slot_freed: Condvar,
    dead: AtomicBool,
}

impl Conn {
    fn lock_writer(&self) -> MutexGuard<'_, TcpStream> {
        self.writer.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_pending(&self) -> MutexGuard<'_, HashMap<u64, ReplyTx>> {
        self.pending.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Remove a pending entry and wake one capped sender.
    fn release(&self, req_id: u64) -> Option<ReplyTx> {
        let tx = self.lock_pending().remove(&req_id);
        if tx.is_some() {
            self.slot_freed.notify_one();
        }
        tx
    }

    /// Mark the connection dead and wake EVERY capped sender: once a
    /// connection is retired no response will ever free another slot, so
    /// waiters must all re-check (see the `dead` condition in `send_on`)
    /// instead of sleeping out their deadlines one notify at a time.
    fn retire(&self) {
        self.dead.store(true, Ordering::Relaxed);
        let _g = self.lock_pending();
        self.slot_freed.notify_all();
    }

    /// Mark the connection dead and fail every in-flight request on it.
    fn fail_all(&self, kind: io::ErrorKind, msg: &str) {
        self.dead.store(true, Ordering::Relaxed);
        for (_, tx) in self.lock_pending().drain() {
            let _ = tx.send(Err(io::Error::new(kind, msg)));
        }
        // The table emptied: every capped sender gets to proceed (and see
        // `dead`).
        self.slot_freed.notify_all();
    }
}

/// Reader loop: demultiplex response frames until the connection dies.
/// Any read failure (including an idle timeout) retires the connection —
/// in-flight callers get a transport error and retry on a fresh dial.
fn reader_loop(conn: Arc<Conn>, mut stream: TcpStream) {
    loop {
        match proto::read_response(&mut stream) {
            Ok(Some(resp)) => {
                // Unknown ids are responses to abandoned (timed-out)
                // requests; dropping them keeps the stream in sync.
                if let Some(tx) = conn.release(resp.req_id) {
                    let _ = tx.send(Ok((resp, Instant::now())));
                }
            }
            Ok(None) => {
                conn.fail_all(io::ErrorKind::UnexpectedEof, "server closed connection");
                return;
            }
            Err(e) => {
                conn.fail_all(e.kind(), "connection failed mid-response");
                return;
            }
        }
    }
}

/// Thread-safe pipelined client.
pub struct RpcClient {
    addr: SocketAddr,
    pool: Mutex<Vec<Arc<Conn>>>,
    next_id: AtomicU64,
    rr: AtomicUsize,
    timeout: Duration,
    /// Per-connection in-flight frame cap (see [`DEFAULT_MAX_IN_FLIGHT`]).
    max_in_flight: usize,
}

/// An in-flight [`RpcClient::predict_async`] call. Dropping it abandons the
/// request (a late response is discarded by the reader thread).
pub struct PendingPredict<'a> {
    client: &'a RpcClient,
    conn: Arc<Conn>,
    /// The connection was dialed by this call (so a failure on it is not a
    /// stale-pool artifact and must not be retried).
    fresh: bool,
    req: Request,
    rx: mpsc::Receiver<io::Result<(Response, Instant)>>,
    n_rows: usize,
}

impl PendingPredict<'_> {
    /// Rows this call asked the service to score.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Block for the response. Retries exactly once on a fresh dial when a
    /// *pooled* connection failed at the transport level (see module docs).
    pub fn wait(self) -> io::Result<Vec<f32>> {
        self.wait_timed().map(|(probs, _)| probs)
    }

    /// Like [`PendingPredict::wait`], also returning the instant the
    /// response frame arrived at the client — completion time for latency
    /// accounting, which precedes the join when the caller overlapped
    /// other work before waiting.
    pub fn wait_timed(self) -> io::Result<(Vec<f32>, Instant)> {
        match recv_result(self.client, &self.conn, &self.req, &self.rx, self.n_rows) {
            Err(e) if !self.fresh && stale_connection_error(&e) => {
                self.client.call_on_fresh(&self.req, self.n_rows)
            }
            other => other,
        }
    }
}

/// Transport failures that indicate a stale pooled connection (the far side
/// closed it between calls) — the only errors worth a fresh-dial retry. A
/// spent deadline (`TimedOut`) and live server answers (error frames map to
/// `Other`, malformed lengths to `InvalidData`) are final.
fn stale_connection_error(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe
            | io::ErrorKind::NotConnected
    )
}

/// One receive attempt for `req` on `conn` — no retry policy here.
fn recv_result(
    client: &RpcClient,
    conn: &Conn,
    req: &Request,
    rx: &mpsc::Receiver<io::Result<(Response, Instant)>>,
    n_rows: usize,
) -> io::Result<(Vec<f32>, Instant)> {
    match rx.recv_timeout(client.timeout) {
        Ok(Ok((resp, arrived))) => finish(req, n_rows, resp).map(|probs| (probs, arrived)),
        Ok(Err(e)) => Err(e),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            // Reader thread vanished without answering (shutdown race).
            Err(io::Error::new(io::ErrorKind::BrokenPipe, "connection reader gone"))
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            // Abandon the request and retire the (possibly wedged)
            // connection; the deadline is already spent. `retire` wakes
            // every capped sender — no response will free slots now.
            conn.lock_pending().remove(&req.req_id);
            conn.retire();
            Err(io::Error::new(io::ErrorKind::TimedOut, "rpc response timed out"))
        }
    }
}

/// Map a decoded response to the caller-visible result.
fn finish(req: &Request, n_rows: usize, resp: Response) -> io::Result<Vec<f32>> {
    if resp.req_id != req.req_id {
        // The demux table makes this unreachable; keep the invariant hard.
        return Err(io::Error::new(io::ErrorKind::InvalidData, "response id mismatch"));
    }
    if resp.error {
        return Err(io::Error::other("server reported a backend failure"));
    }
    if resp.probs.len() != n_rows {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("expected {n_rows} probabilities, got {}", resp.probs.len()),
        ));
    }
    Ok(resp.probs)
}

impl RpcClient {
    pub fn connect(addr: SocketAddr) -> io::Result<RpcClient> {
        let client = RpcClient {
            addr,
            pool: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            timeout: Duration::from_secs(30),
            max_in_flight: DEFAULT_MAX_IN_FLIGHT,
        };
        // Eagerly dial one connection to fail fast on a bad address.
        client.dial_into_pool()?;
        Ok(client)
    }

    fn lock_pool(&self) -> MutexGuard<'_, Vec<Arc<Conn>>> {
        self.pool.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Cap the in-flight (unanswered) frames per connection — total
    /// outstanding work is bounded by `cap ×` [`POOL_CONNS`]. Lowering it
    /// tightens backpressure against a slow server; must be set before the
    /// client is shared.
    pub fn set_max_in_flight(&mut self, cap: usize) {
        self.max_in_flight = cap.max(1);
    }

    /// Unanswered requests currently registered across the pool (the demux
    /// tables' total size — what the in-flight cap bounds).
    pub fn total_in_flight(&self) -> usize {
        self.lock_pool()
            .iter()
            .map(|c| c.lock_pending().len())
            .sum()
    }

    /// Dial a connection, spawn its reader thread, and pool it.
    fn dial_into_pool(&self) -> io::Result<Arc<Conn>> {
        let stream = TcpStream::connect_timeout(&self.addr, Duration::from_secs(5))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        let reader_half = stream.try_clone()?;
        let conn = Arc::new(Conn {
            writer: Mutex::new(stream),
            pending: Mutex::new(HashMap::new()),
            slot_freed: Condvar::new(),
            dead: AtomicBool::new(false),
        });
        let for_reader = conn.clone();
        std::thread::Builder::new()
            .name("rpc-client-reader".into())
            .spawn(move || reader_loop(for_reader, reader_half))?;
        let mut pool = self.lock_pool();
        pool.retain(|c| !c.dead.load(Ordering::Relaxed));
        if pool.len() < POOL_CONNS {
            pool.push(conn.clone());
        }
        Ok(conn)
    }

    /// A live connection for the next request: round-robin over the pool,
    /// growing it toward [`POOL_CONNS`]. The `bool` is true if the
    /// connection was freshly dialed by this call.
    fn live_conn(&self) -> io::Result<(Arc<Conn>, bool)> {
        {
            let mut pool = self.lock_pool();
            pool.retain(|c| !c.dead.load(Ordering::Relaxed));
            if pool.len() >= POOL_CONNS {
                let i = self.rr.fetch_add(1, Ordering::Relaxed) % pool.len();
                return Ok((pool[i].clone(), false));
            }
        }
        Ok((self.dial_into_pool()?, true))
    }

    /// Register the request in `conn`'s pending table and write its frame.
    /// Blocks while the connection already carries [`RpcClient::max_in_flight`]
    /// unanswered frames (backpressure from a slow server), giving up with
    /// `TimedOut` after the client timeout.
    fn send_on(
        &self,
        conn: &Conn,
        req: &Request,
        buf: &[u8],
    ) -> io::Result<mpsc::Receiver<io::Result<(Response, Instant)>>> {
        let (tx, rx) = mpsc::channel();
        {
            let deadline = Instant::now() + self.timeout;
            let mut pending = conn.lock_pending();
            while pending.len() >= self.max_in_flight && !conn.dead.load(Ordering::Relaxed) {
                let now = Instant::now();
                if now >= deadline {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "in-flight cap: no response freed a slot within the timeout",
                    ));
                }
                let (guard, _) = conn
                    .slot_freed
                    .wait_timeout(pending, deadline - now)
                    .unwrap_or_else(PoisonError::into_inner);
                pending = guard;
            }
            // A dead connection is surfaced by the existing post-write
            // check below (the write itself may also fail); registering on
            // it is harmless — fail_all already drained or will never run
            // again, and the entry is removed right there.
            pending.insert(req.req_id, tx);
        }
        let res = proto::write_frame(&mut *conn.lock_writer(), buf);
        if let Err(e) = res {
            conn.lock_pending().remove(&req.req_id);
            conn.retire();
            return Err(e);
        }
        // The reader may have retired the connection (setting `dead`, then
        // draining `pending`) before our entry was registered — in that
        // case nobody will ever answer it. `fail_all` sets `dead` before
        // draining, so seeing it clear here means our entry either survives
        // or was drained with an error already queued on `rx`.
        if conn.dead.load(Ordering::Relaxed) && conn.release(req.req_id).is_some() {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "connection retired"));
        }
        Ok(rx)
    }

    /// Start an asynchronous batched inference call: the request frame is
    /// on the wire when this returns, and the response is collected by
    /// [`PendingPredict::wait`]. `rows.len() = n · row_len`.
    pub fn predict_async(&self, rows: &[f32], row_len: usize) -> io::Result<PendingPredict<'_>> {
        let req = Request {
            req_id: self.next_id.fetch_add(1, Ordering::Relaxed),
            row_len: row_len as u32,
            rows: rows.to_vec(),
        };
        let n_rows = req.n_rows() as usize;
        let mut buf = Vec::with_capacity(req.wire_size());
        proto::encode_request(&req, &mut buf);

        let (conn, fresh) = self.live_conn()?;
        match self.send_on(&conn, &req, &buf) {
            Ok(rx) => Ok(PendingPredict { client: self, conn, fresh, req, rx, n_rows }),
            // A spent in-flight-cap deadline is final: dialing a fresh
            // connection to dodge the cap would defeat the backpressure.
            Err(e) if fresh || e.kind() == io::ErrorKind::TimedOut => Err(e),
            Err(_) => {
                // Stale pooled connection rejected the write — retry once
                // on a fresh dial.
                let conn = self.dial_into_pool()?;
                let rx = self.send_on(&conn, &req, &buf)?;
                Ok(PendingPredict { client: self, conn, fresh: true, req, rx, n_rows })
            }
        }
    }

    /// One full round trip on a freshly dialed connection (the read-side
    /// retry path — no further retries).
    fn call_on_fresh(&self, req: &Request, n_rows: usize) -> io::Result<(Vec<f32>, Instant)> {
        let mut buf = Vec::with_capacity(req.wire_size());
        proto::encode_request(req, &mut buf);
        let conn = self.dial_into_pool()?;
        let rx = self.send_on(&conn, req, &buf)?;
        recv_result(self, &conn, req, &rx, n_rows)
    }

    /// Synchronous batched inference call. `rows.len() = n · row_len`.
    /// Returns one probability per row.
    pub fn predict(&self, rows: &[f32], row_len: usize) -> io::Result<Vec<f32>> {
        self.predict_async(rows, row_len)?.wait()
    }

    /// Round-trip ping (health check / RTT probe).
    pub fn ping(&self) -> io::Result<Duration> {
        let t0 = std::time::Instant::now();
        let probs = self.predict(&[], 0)?;
        debug_assert!(probs.is_empty());
        Ok(t0.elapsed())
    }

    /// Bytes that `predict` would move over the wire for bookkeeping.
    pub fn wire_bytes(n_rows: usize, row_len: usize) -> u64 {
        let req = 4 + 8 + 4 + 4 + (n_rows * row_len * 4) as u64;
        let resp = 4 + 8 + 4 + (n_rows * 4) as u64;
        req + resp
    }
}

impl Drop for RpcClient {
    /// Shut the sockets down so every reader thread sees EOF and exits now
    /// instead of idling until its read timeout.
    fn drop(&mut self) {
        for c in self.lock_pool().drain(..) {
            c.dead.store(true, Ordering::Relaxed);
            let _ = c.lock_writer().shutdown(std::net::Shutdown::Both);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::netsim::{NetSim, NetSimConfig};
    use crate::rpc::server::{Backend, BatcherConfig, RpcServer};
    use crate::telemetry::ServeMetrics;
    use std::sync::Arc;

    /// Echo-ish backend: prob = mean of the row (easy to verify).
    struct MeanBackend;

    impl Backend for MeanBackend {
        fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
            (0..n)
                .map(|r| {
                    let row = &rows[r * row_len..(r + 1) * row_len];
                    row.iter().sum::<f32>() / row_len as f32
                })
                .collect()
        }
        fn row_len(&self) -> usize {
            0
        }
    }

    fn start_server() -> (RpcServer, Arc<ServeMetrics>) {
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(100),
                workers: 2,
            },
            metrics.clone(),
        )
        .unwrap();
        (server, metrics)
    }

    #[test]
    fn roundtrip_single() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let probs = client.predict(&[1.0, 2.0, 3.0, 4.0], 4).unwrap();
        assert_eq!(probs, vec![2.5]);
    }

    #[test]
    fn roundtrip_batch() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let rows: Vec<f32> = (0..20).map(|i| i as f32).collect(); // 10 rows × 2
        let probs = client.predict(&rows, 2).unwrap();
        assert_eq!(probs.len(), 10);
        assert_eq!(probs[0], 0.5);
        assert_eq!(probs[9], 18.5);
    }

    #[test]
    fn pipelined_requests_demux_by_id() {
        // Many requests in flight on ONE client before any wait: responses
        // may complete out of order server-side; demux must route each to
        // its caller.
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let pendings: Vec<_> = (0..32)
            .map(|i| {
                let v = i as f32;
                client.predict_async(&[v, v + 2.0], 2).unwrap()
            })
            .collect();
        for (i, p) in pendings.into_iter().enumerate() {
            let probs = p.wait().unwrap();
            assert_eq!(probs, vec![i as f32 + 1.0], "request {i}");
        }
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let (server, metrics) = start_server();
        let addr = server.addr;
        let mut handles = Vec::new();
        for t in 0..8 {
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::connect(addr).unwrap();
                for i in 0..50 {
                    let v = (t * 100 + i) as f32;
                    let p = client.predict(&[v, v], 2).unwrap();
                    assert_eq!(p, vec![v]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Batcher really batched (fewer backend execs than requests is
        // likely but not guaranteed; at minimum it executed something).
        assert!(metrics.backend_exec.count() > 0);
        assert!(metrics.backend_exec.count() <= 400);
    }

    #[test]
    fn ping_works() {
        let (server, _m) = start_server();
        let client = RpcClient::connect(server.addr).unwrap();
        let rtt = client.ping().unwrap();
        assert!(rtt < Duration::from_secs(1));
    }

    #[test]
    fn netsim_raises_latency() {
        let metrics = Arc::new(ServeMetrics::new());
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(
                NetSimConfig {
                    base_us: 2000.0,
                    sigma: 0.1,
                    max_us: 10_000.0,
                },
                7,
            )),
            BatcherConfig::default(),
            metrics,
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();
        let rtt = client.ping().unwrap();
        // Pings take the inbound injection (~2ms) only.
        assert!(rtt >= Duration::from_millis(1), "rtt={rtt:?}");
        // A real request takes both hops (~4ms).
        let t0 = std::time::Instant::now();
        client.predict(&[1.0, 2.0], 2).unwrap();
        let full = t0.elapsed();
        assert!(full >= Duration::from_millis(3), "full={full:?}");
    }

    #[test]
    fn stale_pooled_connection_recovers_across_server_restart() {
        // Cycle the server between calls: the pooled connection the first
        // call parked is dead for the second. Whichever side notices (the
        // write is rejected, the reader sees EOF after the write was
        // swallowed, or the reader already retired the connection), the
        // call must transparently succeed against the restarted server.
        let (server, _m) = start_server();
        let addr = server.addr;
        let client = RpcClient::connect(addr).unwrap();
        // Warm the pool to POOL_CONNS so the post-restart call is routed to
        // a POOLED (reused) connection — the only case eligible for retry.
        for i in 0..(2 * POOL_CONNS) {
            let v = i as f32;
            assert_eq!(client.predict(&[v, v + 2.0], 2).unwrap(), vec![v + 1.0]);
        }

        drop(server);
        std::thread::sleep(Duration::from_millis(50));
        let server2 = RpcServer::start(
            &addr.to_string(),
            Arc::new(MeanBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig::default(),
            Arc::new(ServeMetrics::new()),
        )
        .expect("rebind the same address");
        assert_eq!(server2.addr, addr);

        let probs = client.predict(&[10.0, 20.0], 2).unwrap();
        assert_eq!(probs, vec![15.0]);
    }

    /// Backend slow enough that pipelined senders outrun the responses.
    struct SlowBackend;

    impl Backend for SlowBackend {
        fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
            std::thread::sleep(Duration::from_millis(10));
            (0..n).map(|r| rows[r * row_len]).collect()
        }
        fn row_len(&self) -> usize {
            0
        }
    }

    #[test]
    fn in_flight_cap_bounds_pending_against_slow_server() {
        use std::sync::atomic::AtomicBool;
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(SlowBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::ZERO,
                workers: 1, // one slow lane: responses trail far behind sends
            },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let mut client = RpcClient::connect(server.addr).unwrap();
        const CAP: usize = 2;
        client.set_max_in_flight(CAP);

        // 4 producers × 6 pipelined calls = 24 requests, far past the
        // bound of CAP × POOL_CONNS = 8 — without the cap the pending
        // tables would grow to ~24; with it, senders block instead.
        let done = AtomicBool::new(false);
        let max_seen = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            let client = &client;
            let done = &done;
            let max_seen = &max_seen;
            s.spawn(move || {
                let mut max = 0;
                while !done.load(Ordering::Relaxed) {
                    max = max.max(client.total_in_flight());
                    std::thread::sleep(Duration::from_micros(300));
                }
                max_seen.store(max, Ordering::Relaxed);
            });
            let producers: Vec<_> = (0..4)
                .map(|t| {
                    s.spawn(move || {
                        let pendings: Vec<_> = (0..6)
                            .map(|i| {
                                let v = (t * 100 + i) as f32;
                                client.predict_async(&[v, 0.0], 2).unwrap()
                            })
                            .collect();
                        for (i, p) in pendings.into_iter().enumerate() {
                            let v = (t * 100 + i) as f32;
                            assert_eq!(p.wait().unwrap(), vec![v], "producer {t} call {i}");
                        }
                    })
                })
                .collect();
            for h in producers {
                h.join().unwrap();
            }
            // Producers done: release the sampler (joined on scope exit).
            done.store(true, Ordering::Relaxed);
        });
        // The structural invariant (insert only under the cap check) keeps
        // every connection at ≤ CAP; the sampler must never have observed
        // more than CAP × POOL_CONNS across the pool.
        assert!(
            max_seen.load(Ordering::Relaxed) <= CAP * POOL_CONNS,
            "pending grew past the cap: {} > {}",
            max_seen.load(Ordering::Relaxed),
            CAP * POOL_CONNS
        );
        assert_eq!(client.total_in_flight(), 0, "all slots released");
    }

    #[test]
    fn server_shutdown_clean() {
        let (server, _m) = start_server();
        let addr = server.addr;
        drop(server);
        // New connections should fail or be closed promptly.
        std::thread::sleep(Duration::from_millis(50));
        let r = RpcClient::connect(addr).and_then(|c| c.predict(&[1.0], 1));
        assert!(r.is_err());
    }
}
