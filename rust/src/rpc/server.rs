//! Second-stage inference service: TCP server + dynamic batcher.
//!
//! Connection threads parse requests and park them on a shared queue; a
//! pool of batcher workers coalesces concurrent requests into backend
//! batches (up to `max_batch` rows or `max_wait`, whichever first) — the
//! standard dynamic-batching pattern of model servers (vLLM/Triton style),
//! which is what makes the RPC side a realistic baseline for Table 3.

use super::netsim::NetSim;
use super::proto::{self, Request, Response};
use crate::telemetry::ServeMetrics;
use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Backend model abstraction: PJRT artifact or native GBDT.
pub trait Backend: Send + Sync {
    /// Predict probabilities for `n` rows of width `row_len` (row-major).
    fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32>;
    /// Expected row width (0 = any).
    fn row_len(&self) -> usize;
}

/// Native GBDT backend (no PJRT) — used in tests and as an ablation.
/// Serves from a [`FlatForest`](crate::gbdt::FlatForest) image of the model
/// (contiguous arena, tree-major row-blocked traversal) and shards large
/// batches across scoped threads.
pub struct NativeBackend {
    pub model: crate::gbdt::GbdtModel,
    flat: crate::gbdt::FlatForest,
}

/// Minimum rows per shard thread: below this the per-thread spawn cost
/// outweighs the parallel traversal. Sharding engages from 2 shards up, so
/// it is reachable at the default batcher `max_batch` (128).
const NATIVE_SHARD_ROWS: usize = 64;

impl NativeBackend {
    pub fn new(model: crate::gbdt::GbdtModel) -> NativeBackend {
        let flat = model.flatten();
        NativeBackend { model, flat }
    }
}

impl Backend for NativeBackend {
    fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
        if row_len < self.model.n_features {
            // Degenerate narrow rows: preserve the scalar path's semantics
            // (panics if a tree references a missing feature).
            let mut out = Vec::with_capacity(n);
            for r in 0..n {
                let row = &rows[r * row_len..(r + 1) * row_len];
                out.push(self.model.predict_one(row));
            }
            return out;
        }
        let mut out = vec![0f32; n];
        // Shard so every thread gets at least NATIVE_SHARD_ROWS rows.
        let threads = crate::util::threadpool::default_threads().min(n / NATIVE_SHARD_ROWS);
        if threads > 1 {
            let chunk = n.div_ceil(threads);
            std::thread::scope(|s| {
                for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
                    let start = ci * chunk;
                    let flat = &self.flat;
                    let shard = &rows[start * row_len..(start + out_chunk.len()) * row_len];
                    s.spawn(move || {
                        let mut scratch = crate::gbdt::ForestScratch::default();
                        flat.predict_flat_rows(shard, row_len, &mut scratch, out_chunk);
                    });
                }
            });
        } else {
            let mut scratch = crate::gbdt::ForestScratch::default();
            self.flat
                .predict_flat_rows(&rows[..n * row_len], row_len, &mut scratch, &mut out);
        }
        out
    }

    fn row_len(&self) -> usize {
        0
    }
}

/// PJRT backend executing the AOT second-stage artifact (via the dedicated
/// engine thread — see `runtime::worker`). A small pool of staging buffers
/// cycles through the engine thread instead of allocating a fresh row copy
/// per batch — a pool (not a single slot) because the server's batcher
/// workers call `predict` concurrently.
pub struct PjrtBackend {
    pub worker: Arc<crate::runtime::EngineWorker>,
    staging: Mutex<Vec<Vec<f32>>>,
}

/// Staging buffers kept for reuse; more concurrent batches than this just
/// allocate (and the extras are dropped on return).
const PJRT_STAGING_POOL: usize = 8;

impl PjrtBackend {
    pub fn new(worker: Arc<crate::runtime::EngineWorker>) -> PjrtBackend {
        PjrtBackend {
            worker,
            staging: Mutex::new(Vec::new()),
        }
    }
}

impl Backend for PjrtBackend {
    fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
        assert_eq!(row_len, self.worker.f_max, "PJRT backend needs padded rows");
        let mut buf = self.staging.lock().unwrap().pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(rows);
        let (probs, buf) = self
            .worker
            .second_stage_with_buf(buf, n)
            .expect("PJRT execution failed");
        let mut pool = self.staging.lock().unwrap();
        if pool.len() < PJRT_STAGING_POOL {
            pool.push(buf);
        }
        probs
    }

    fn row_len(&self) -> usize {
        self.worker.f_max
    }
}

/// Dynamic batcher configuration.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Max rows per backend batch.
    pub max_batch: usize,
    /// Max time the first request in a batch waits for company.
    pub max_wait: Duration,
    /// Batcher worker threads.
    pub workers: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 128,
            // Immediate dispatch: batching still emerges under load because
            // execution occupies the workers while new requests queue
            // (§Perf L3-backend — a 200µs window added 40% to single-request
            // RTT for no concurrent-throughput gain).
            max_wait: Duration::ZERO,
            workers: 2,
        }
    }
}

struct Job {
    rows: Vec<f32>,
    n: usize,
    row_len: usize,
    resp: mpsc::Sender<Vec<f32>>,
}

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    avail: Condvar,
    shutdown: AtomicBool,
}

/// Running RPC server; shuts down on drop.
pub struct RpcServer {
    pub addr: std::net::SocketAddr,
    queue: Arc<Queue>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl RpcServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") and start serving.
    pub fn start(
        addr: &str,
        backend: Arc<dyn Backend>,
        netsim: Arc<NetSim>,
        cfg: BatcherConfig,
        metrics: Arc<ServeMetrics>,
    ) -> std::io::Result<RpcServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            avail: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let shutdown = Arc::new(AtomicBool::new(false));

        // Batcher workers.
        let mut worker_handles = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let backend = backend.clone();
            let cfg = cfg.clone();
            let metrics = metrics.clone();
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("batcher-{w}"))
                    .spawn(move || batcher_loop(queue, backend, cfg, metrics))
                    .expect("spawn batcher"),
            );
        }

        // Accept loop.
        let accept_handle = {
            let queue = queue.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("rpc-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::Relaxed) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let queue = queue.clone();
                        let netsim = netsim.clone();
                        std::thread::Builder::new()
                            .name("rpc-conn".into())
                            .spawn(move || connection_loop(stream, queue, netsim))
                            .ok();
                    }
                })
                .expect("spawn accept")
        };

        Ok(RpcServer {
            addr: local,
            queue,
            accept_handle: Some(accept_handle),
            worker_handles,
            shutdown,
        })
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.queue.shutdown.store(true, Ordering::Relaxed);
        // Drop queued jobs: their reply senders close, so connection
        // threads waiting on recv() error out and hang up promptly.
        self.queue.jobs.lock().unwrap().clear();
        self.queue.avail.notify_all();
        // Unblock accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn connection_loop(mut stream: TcpStream, queue: Arc<Queue>, netsim: Arc<NetSim>) {
    stream.set_nodelay(true).ok();
    let mut out_buf = Vec::new();
    loop {
        let req: Request = match proto::read_request(&mut stream) {
            Ok(Some(r)) => r,
            Ok(None) => return, // client closed
            Err(_) => return,
        };
        // Inbound network hop (simulated datacenter latency).
        netsim.inject();
        let n = req.n_rows() as usize;
        if n == 0 {
            // Ping.
            proto::encode_response(&Response { req_id: req.req_id, probs: vec![] }, &mut out_buf);
            if proto::write_frame(&mut stream, &out_buf).is_err() {
                return;
            }
            continue;
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut jobs = queue.jobs.lock().unwrap();
            if queue.shutdown.load(Ordering::Relaxed) {
                return; // server stopping: hang up so the client errors fast
            }
            jobs.push_back(Job {
                rows: req.rows,
                n,
                row_len: req.row_len as usize,
                resp: tx,
            });
        }
        queue.avail.notify_one();
        let Ok(probs) = rx.recv() else { return };
        // Outbound network hop.
        netsim.inject();
        proto::encode_response(&Response { req_id: req.req_id, probs }, &mut out_buf);
        if proto::write_frame(&mut stream, &out_buf).is_err() {
            return;
        }
    }
}

fn batcher_loop(
    queue: Arc<Queue>,
    backend: Arc<dyn Backend>,
    cfg: BatcherConfig,
    metrics: Arc<ServeMetrics>,
) {
    loop {
        // Collect a batch: block for the first job, then wait up to
        // max_wait for more (or until max_batch rows).
        let mut batch: Vec<Job> = Vec::new();
        let mut total_rows = 0usize;
        {
            let mut jobs = queue.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.pop_front() {
                    total_rows += j.n;
                    batch.push(j);
                    break;
                }
                if queue.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                jobs = queue.avail.wait(jobs).unwrap();
            }
            let deadline = Instant::now() + cfg.max_wait;
            while total_rows < cfg.max_batch {
                if let Some(j) = jobs.front() {
                    if total_rows + j.n > cfg.max_batch && !batch.is_empty() {
                        break;
                    }
                    let j = jobs.pop_front().unwrap();
                    total_rows += j.n;
                    batch.push(j);
                    continue;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = queue
                    .avail
                    .wait_timeout(jobs, deadline - now)
                    .unwrap();
                jobs = guard;
                if timeout.timed_out() && jobs.is_empty() {
                    break;
                }
            }
        }

        // All jobs in a batch must share row_len (they do: one model per
        // service); split by row_len defensively.
        batch.sort_by_key(|j| j.row_len);
        let mut i = 0;
        while i < batch.len() {
            let row_len = batch[i].row_len;
            let mut j = i;
            let mut rows: Vec<f32> = Vec::new();
            let mut n = 0usize;
            while j < batch.len() && batch[j].row_len == row_len {
                rows.extend_from_slice(&batch[j].rows);
                n += batch[j].n;
                j += 1;
            }
            let t0 = Instant::now();
            let probs = backend.predict(&rows, n, row_len);
            metrics.backend_exec.record_duration(t0.elapsed());
            debug_assert_eq!(probs.len(), n);
            let mut off = 0;
            for job in &batch[i..j] {
                let slice = probs[off..off + job.n].to_vec();
                off += job.n;
                let _ = job.resp.send(slice);
            }
            i = j;
        }
    }
}
