//! Second-stage inference service: TCP server + dynamic batcher.
//!
//! Connection threads parse requests and park them on a shared queue; a
//! pool of batcher workers coalesces concurrent requests into backend
//! batches (up to `max_batch` rows or `max_wait`, whichever first) — the
//! standard dynamic-batching pattern of model servers (vLLM/Triton style),
//! which is what makes the RPC side a realistic baseline for Table 3.
//!
//! Connections are **pipelined**: the per-connection reader keeps parsing
//! and admitting requests without waiting for earlier responses, and each
//! completed job writes its own response frame through the connection's
//! shared write half — possibly out of request order; the client
//! demultiplexes by `req_id`. Simulated network hops (`NetSim`) model
//! propagation delay, so they run off-thread and overlap instead of
//! stacking behind one another. A panicking [`Backend::predict`] is
//! contained to its batch: the worker catches the unwind, answers the
//! batch's requests with error frames, and keeps serving (queue locks are
//! poison-tolerant throughout).

use super::netsim::NetSim;
use super::proto::{self, Request, Response};
use crate::telemetry::ServeMetrics;
use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Backend model abstraction: PJRT artifact or native GBDT.
pub trait Backend: Send + Sync {
    /// Predict probabilities for `n` rows of width `row_len` (row-major).
    fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32>;
    /// Expected row width (0 = any).
    fn row_len(&self) -> usize;
}

/// Native GBDT backend (no PJRT) — used in tests and as an ablation.
/// Serves from a [`FlatForest`](crate::gbdt::FlatForest) image of the model
/// (contiguous arena, tree-major row-blocked traversal) and shards large
/// batches across scoped threads.
pub struct NativeBackend {
    pub model: crate::gbdt::GbdtModel,
    flat: crate::gbdt::FlatForest,
}

/// Minimum rows per shard thread: below this the per-thread spawn cost
/// outweighs the parallel traversal. Sharding engages from 2 shards up, so
/// it is reachable at the default batcher `max_batch` (128).
const NATIVE_SHARD_ROWS: usize = 64;

impl NativeBackend {
    pub fn new(model: crate::gbdt::GbdtModel) -> NativeBackend {
        let flat = model.flatten();
        NativeBackend { model, flat }
    }
}

impl Backend for NativeBackend {
    fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
        if row_len < self.model.n_features {
            // Degenerate narrow rows: preserve the scalar path's semantics
            // (panics if a tree references a missing feature).
            let mut out = Vec::with_capacity(n);
            for r in 0..n {
                let row = &rows[r * row_len..(r + 1) * row_len];
                out.push(self.model.predict_one(row));
            }
            return out;
        }
        let mut out = vec![0f32; n];
        // Shard so every thread gets at least NATIVE_SHARD_ROWS rows.
        let threads = crate::util::threadpool::default_threads().min(n / NATIVE_SHARD_ROWS);
        if threads > 1 {
            let chunk = n.div_ceil(threads);
            std::thread::scope(|s| {
                for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
                    let start = ci * chunk;
                    let flat = &self.flat;
                    let shard = &rows[start * row_len..(start + out_chunk.len()) * row_len];
                    s.spawn(move || {
                        let mut scratch = crate::gbdt::ForestScratch::default();
                        flat.predict_flat_rows(shard, row_len, &mut scratch, out_chunk);
                    });
                }
            });
        } else {
            let mut scratch = crate::gbdt::ForestScratch::default();
            self.flat
                .predict_flat_rows(&rows[..n * row_len], row_len, &mut scratch, &mut out);
        }
        out
    }

    fn row_len(&self) -> usize {
        0
    }
}

/// PJRT backend executing the AOT second-stage artifact (via the dedicated
/// engine thread — see `runtime::worker`). A small pool of staging buffers
/// cycles through the engine thread instead of allocating a fresh row copy
/// per batch — a pool (not a single slot) because the server's batcher
/// workers call `predict` concurrently.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    pub worker: Arc<crate::runtime::EngineWorker>,
    staging: Mutex<Vec<Vec<f32>>>,
}

/// Staging buffers kept for reuse; more concurrent batches than this just
/// allocate (and the extras are dropped on return).
#[cfg(feature = "pjrt")]
const PJRT_STAGING_POOL: usize = 8;

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(worker: Arc<crate::runtime::EngineWorker>) -> PjrtBackend {
        PjrtBackend {
            worker,
            staging: Mutex::new(Vec::new()),
        }
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
        assert_eq!(row_len, self.worker.f_max, "PJRT backend needs padded rows");
        let mut buf = self.staging.lock().unwrap().pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(rows);
        let (probs, buf) = self
            .worker
            .second_stage_with_buf(buf, n)
            .expect("PJRT execution failed");
        let mut pool = self.staging.lock().unwrap();
        if pool.len() < PJRT_STAGING_POOL {
            pool.push(buf);
        }
        probs
    }

    fn row_len(&self) -> usize {
        self.worker.f_max
    }
}

/// Dynamic batcher configuration.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Max rows per backend batch.
    pub max_batch: usize,
    /// Max time the first request in a batch waits for company.
    pub max_wait: Duration,
    /// Batcher worker threads.
    pub workers: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 128,
            // Immediate dispatch: batching still emerges under load because
            // execution occupies the workers while new requests queue
            // (§Perf L3-backend — a 200µs window added 40% to single-request
            // RTT for no concurrent-throughput gain).
            max_wait: Duration::ZERO,
            workers: 2,
        }
    }
}

/// Write half of a connection, shared by every response path; frames are
/// written whole under the lock, so responses from different batches can
/// never interleave on the wire.
type SharedWriter = Arc<Mutex<TcpStream>>;

struct Job {
    req_id: u64,
    rows: Vec<f32>,
    n: usize,
    row_len: usize,
    out: SharedWriter,
    netsim: Arc<NetSim>,
}

impl Job {
    /// Answer this job: `Some(probs)` served, `None` = error frame.
    fn respond(&self, result: Option<Vec<f32>>) {
        respond(&self.out, &self.netsim, self.req_id, result);
    }
}

/// Deliver one response to a client. Successful non-ping responses pay the
/// simulated outbound network hop; when the sim is on, the delay runs on
/// its own thread — hops are propagation, not transmission, so concurrent
/// responses must overlap rather than queue behind one another's sleeps.
/// Error frames and pings skip the hop (failure notifications are cheap;
/// the RTT probe measures a single simulated hop).
fn respond(out: &SharedWriter, netsim: &Arc<NetSim>, req_id: u64, result: Option<Vec<f32>>) {
    let resp = match result {
        Some(probs) => Response::ok(req_id, probs),
        None => Response::err(req_id),
    };
    if netsim.enabled() && !resp.error && !resp.probs.is_empty() {
        let out = out.clone();
        let netsim = netsim.clone();
        // A spawn failure (total resource collapse) drops the frame and
        // surfaces as a client-side timeout — the sim-only thread cost is
        // bounded by the in-flight request count.
        std::thread::Builder::new()
            .name("netsim-hop".into())
            .spawn(move || {
                netsim.inject();
                write_response(&out, &resp);
            })
            .ok();
    } else {
        write_response(out, &resp);
    }
}

fn write_response(out: &SharedWriter, resp: &Response) {
    let mut buf = Vec::new();
    proto::encode_response(resp, &mut buf);
    let mut stream = out.lock().unwrap_or_else(PoisonError::into_inner);
    // A write failure means the client hung up; it will be rediscovered by
    // the connection reader, so it is ignorable here.
    let _ = proto::write_frame(&mut *stream, &buf);
}

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    avail: Condvar,
    shutdown: AtomicBool,
}

impl Queue {
    /// Jobs are self-contained (a poisoning panic cannot leave one half
    /// mutated), so a poisoned lock must not take the service down.
    fn lock_jobs(&self) -> MutexGuard<'_, VecDeque<Job>> {
        self.jobs.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Running RPC server; shuts down on drop.
pub struct RpcServer {
    pub addr: std::net::SocketAddr,
    queue: Arc<Queue>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl RpcServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") and start serving.
    pub fn start(
        addr: &str,
        backend: Arc<dyn Backend>,
        netsim: Arc<NetSim>,
        cfg: BatcherConfig,
        metrics: Arc<ServeMetrics>,
    ) -> std::io::Result<RpcServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            avail: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let shutdown = Arc::new(AtomicBool::new(false));

        // Batcher workers.
        let mut worker_handles = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let backend = backend.clone();
            let cfg = cfg.clone();
            let metrics = metrics.clone();
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("batcher-{w}"))
                    .spawn(move || batcher_loop(queue, backend, cfg, metrics))
                    .expect("spawn batcher"),
            );
        }

        // Accept loop.
        let accept_handle = {
            let queue = queue.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("rpc-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::Relaxed) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let queue = queue.clone();
                        let netsim = netsim.clone();
                        std::thread::Builder::new()
                            .name("rpc-conn".into())
                            .spawn(move || connection_loop(stream, queue, netsim))
                            .ok();
                    }
                })
                .expect("spawn accept")
        };

        Ok(RpcServer {
            addr: local,
            queue,
            accept_handle: Some(accept_handle),
            worker_handles,
            shutdown,
        })
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.queue.shutdown.store(true, Ordering::Relaxed);
        // Answer queued jobs with error frames so pipelined clients get a
        // prompt failure instead of waiting out their response timeout.
        for job in self.queue.lock_jobs().drain(..) {
            job.respond(None);
        }
        self.queue.avail.notify_all();
        // Unblock accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-connection reader: parse frames and admit requests, never waiting
/// for responses — completed jobs write their own frames (possibly out of
/// request order; the client demultiplexes by `req_id`).
fn connection_loop(mut stream: TcpStream, queue: Arc<Queue>, netsim: Arc<NetSim>) {
    stream.set_nodelay(true).ok();
    let Ok(write_half) = stream.try_clone() else { return };
    let out: SharedWriter = Arc::new(Mutex::new(write_half));
    loop {
        let req: Request = match proto::read_request(&mut stream) {
            Ok(Some(r)) => r,
            Ok(None) | Err(_) => break, // client closed / protocol error
        };
        // Inbound network hop (simulated datacenter latency). Like the
        // outbound side, the hop is propagation delay: pipelined frames
        // travel the network concurrently, so the sleep must not block the
        // reader from parsing (or admitting) the frames behind this one —
        // when the sim is on, delay-then-admit runs on its own thread.
        if netsim.enabled() {
            let queue = queue.clone();
            let netsim = netsim.clone();
            let out = out.clone();
            std::thread::Builder::new()
                .name("netsim-hop".into())
                .spawn(move || {
                    netsim.inject();
                    admit(req, queue, out, netsim);
                })
                .ok();
        } else {
            admit(req, queue.clone(), out.clone(), netsim.clone());
        }
    }
    // Reader exit closes the read half; in-flight responses keep the write
    // half alive through `out` and fail harmlessly once the client is gone.
}

/// Admit one parsed request: pings answer immediately, a shutting-down
/// server hangs the connection up (so pooled clients fail over to a fresh
/// dial), everything else parks on the batcher queue.
fn admit(req: Request, queue: Arc<Queue>, out: SharedWriter, netsim: Arc<NetSim>) {
    let n = req.n_rows() as usize;
    if n == 0 {
        respond(&out, &netsim, req.req_id, Some(Vec::new()));
        return;
    }
    {
        let mut jobs = queue.lock_jobs();
        if queue.shutdown.load(Ordering::Relaxed) {
            drop(jobs);
            let _ = out
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .shutdown(std::net::Shutdown::Both);
            return;
        }
        jobs.push_back(Job {
            req_id: req.req_id,
            rows: req.rows,
            n,
            row_len: req.row_len as usize,
            out,
            netsim,
        });
    }
    queue.avail.notify_one();
}

fn batcher_loop(
    queue: Arc<Queue>,
    backend: Arc<dyn Backend>,
    cfg: BatcherConfig,
    metrics: Arc<ServeMetrics>,
) {
    loop {
        // Collect a batch: block for the first job, then wait up to
        // max_wait for more (or until max_batch rows).
        let mut batch: Vec<Job> = Vec::new();
        let mut total_rows = 0usize;
        {
            let mut jobs = queue.lock_jobs();
            loop {
                if let Some(j) = jobs.pop_front() {
                    total_rows += j.n;
                    batch.push(j);
                    break;
                }
                if queue.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                jobs = queue
                    .avail
                    .wait(jobs)
                    .unwrap_or_else(PoisonError::into_inner);
            }
            let deadline = Instant::now() + cfg.max_wait;
            while total_rows < cfg.max_batch {
                if let Some(j) = jobs.front() {
                    if total_rows + j.n > cfg.max_batch && !batch.is_empty() {
                        break;
                    }
                    let j = jobs.pop_front().unwrap();
                    total_rows += j.n;
                    batch.push(j);
                    continue;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = queue
                    .avail
                    .wait_timeout(jobs, deadline - now)
                    .unwrap_or_else(PoisonError::into_inner);
                jobs = guard;
                if timeout.timed_out() && jobs.is_empty() {
                    break;
                }
            }
        }

        // All jobs in a batch must share row_len (they do: one model per
        // service); split by row_len defensively.
        batch.sort_by_key(|j| j.row_len);
        let mut i = 0;
        while i < batch.len() {
            let row_len = batch[i].row_len;
            let mut j = i;
            let mut rows: Vec<f32> = Vec::new();
            let mut n = 0usize;
            while j < batch.len() && batch[j].row_len == row_len {
                rows.extend_from_slice(&batch[j].rows);
                n += batch[j].n;
                j += 1;
            }
            let t0 = Instant::now();
            // A panicking backend must not kill the worker (with every
            // worker dead the queue grows unserved forever — the service is
            // bricked). Contain the unwind to this batch and answer its
            // requests with error frames.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                backend.predict(&rows, n, row_len)
            }));
            metrics.backend_exec.record_duration(t0.elapsed());
            match result {
                Ok(probs) => {
                    debug_assert_eq!(probs.len(), n);
                    let mut off = 0;
                    for job in &batch[i..j] {
                        let slice = probs[off..off + job.n].to_vec();
                        off += job.n;
                        job.respond(Some(slice));
                    }
                }
                Err(_) => {
                    for job in &batch[i..j] {
                        job.respond(None);
                    }
                }
            }
            i = j;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::netsim::NetSimConfig;
    use crate::rpc::RpcClient;

    /// Backend that panics on any NaN input (a stand-in for a model bug on
    /// a poison row) and otherwise echoes the first value of each row.
    struct PanickyBackend;

    impl Backend for PanickyBackend {
        fn predict(&self, rows: &[f32], n: usize, row_len: usize) -> Vec<f32> {
            assert!(!rows.iter().any(|v| v.is_nan()), "poison row reached the backend");
            (0..n).map(|r| rows[r * row_len]).collect()
        }
        fn row_len(&self) -> usize {
            0
        }
    }

    #[test]
    fn backend_panic_answers_batch_and_keeps_serving() {
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(PanickyBackend),
            Arc::new(NetSim::new(NetSimConfig::off(), 1)),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::ZERO,
                // A single worker: if the panic killed it, every later
                // request would hang instead of being served.
                workers: 1,
            },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let client = RpcClient::connect(server.addr).unwrap();

        // Sanity: the happy path works.
        assert_eq!(client.predict(&[7.0, 0.0], 2).unwrap(), vec![7.0]);

        // Poison batch: must surface as an error, not a hang or a crash.
        let err = client.predict(&[f32::NAN, 1.0], 2);
        assert!(err.is_err(), "panicking backend must report failure");

        // The worker survived: subsequent requests are still answered.
        for i in 0..5 {
            let v = 10.0 + i as f32;
            assert_eq!(client.predict(&[v, 0.0], 2).unwrap(), vec![v], "request {i}");
        }
    }
}
